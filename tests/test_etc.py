"""Embedding Training Cache: residency, eviction writeback, flush, and a
full train-loop integration where the cache is much smaller than the
tables (the paper's TB-scale-training claim, scaled down)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import EmbeddingTableConfig
from repro.core.etc.cache import EmbeddingTrainingCache, cached_lookup
from repro.core.etc.parameter_server import CachedPS, StagedPS


def _tables(n=2, vocab=100, dim=8):
    return [EmbeddingTableConfig(f"t{i}", vocab, dim, hotness=2)
            for i in range(n)]


def test_prepare_makes_ids_resident():
    tabs = _tables()
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=16, ps=ps)
    params = etc.init_params()
    cat = np.asarray([[[3, 5], [7, -1]], [[3, 9], [2, 2]]], np.int32)
    params, remapped = etc.prepare(params, cat)
    # every valid id got a slot, padding stayed -1
    assert (remapped[cat >= 0] >= 0).all()
    assert (remapped[cat < 0] == -1).all()
    # lookup through the cache equals pulling rows from the PS directly
    out = np.asarray(cached_lookup(params, jnp.asarray(remapped)))
    for b in range(2):
        for t in range(2):
            want = np.zeros(8)
            for h in range(2):
                v = cat[b, t, h]
                if v >= 0:
                    want = want + ps.pull(tabs[t].name, np.asarray([v]))[0]
            np.testing.assert_allclose(out[b, t], want, rtol=1e-5)


def test_eviction_writes_back_to_ps():
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    # fill the cache with ids 0..3
    cat = np.arange(4, dtype=np.int32).reshape(4, 1, 1)
    params, rm = etc.prepare(params, cat)
    # mutate the cached rows (simulating a train step)
    params = dict(params)
    params["cache"] = params["cache"] + 1.0
    # now demand 4 new ids -> all old rows must be evicted + written back
    cat2 = (np.arange(4, dtype=np.int32) + 50).reshape(4, 1, 1)
    params, rm2 = etc.prepare(params, cat2)
    assert etc.evictions == 4
    # the PS must hold the *mutated* values for the evicted ids
    rows = ps.pull("t0", np.arange(4))
    base = np.asarray([ps._store["t0"][0][i] for i in range(4)])
    assert (rows == base).all()
    # mutated rows are +1 vs their original pull
    # (the original value was what prepare() pulled; after +1 and evict,
    #  the PS sees original + 1)
    # verify via a fresh cache: pulling id 0 gives the written-back value
    assert etc.pulls == 8


def test_capacity_exceeded_in_one_batch_raises_or_survives():
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    cat = np.arange(4, dtype=np.int32).reshape(4, 1, 1)
    params, _ = etc.prepare(params, cat)
    assert etc.pulls == 4


def test_current_batch_ids_survive_eviction():
    """Eviction must never evict ids needed by the batch being staged."""
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    # make ids 0..3 resident (0 is oldest in LRU order)
    cat = np.arange(4, dtype=np.int32).reshape(4, 1, 1)
    params, _ = etc.prepare(params, cat)
    # now a batch that needs OLD id 0 plus 3 new ids: id 0 must be
    # protected even though it is the LRU candidate
    cat2 = np.asarray([0, 50, 51, 52], np.int32).reshape(4, 1, 1)
    params, rm = etc.prepare(params, cat2)
    assert (rm >= 0).all()


def test_batch_exceeding_capacity_raises():
    tabs = _tables(n=1, vocab=100)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=StagedPS(tabs))
    params = etc.init_params()
    cat = np.arange(8, dtype=np.int32).reshape(8, 1, 1)
    import pytest as _pytest
    with _pytest.raises(ValueError, match="capacity"):
        etc.prepare(params, cat)


def test_flush_persists_everything():
    tabs = _tables(n=1, vocab=50)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=8, ps=ps)
    params = etc.init_params()
    cat = np.asarray([1, 2, 3], np.int32).reshape(3, 1, 1)
    params, rm = etc.prepare(params, cat)
    params = dict(params)
    params["cache"] = params["cache"] * 0 + 42.0
    etc.flush(params)
    for i in (1, 2, 3):
        np.testing.assert_allclose(ps.pull("t0", np.asarray([i]))[0], 42.0)


def test_cached_ps_disk_roundtrip(tmp_path):
    tabs = _tables(n=2, vocab=64, dim=4)
    ps = CachedPS(tabs, str(tmp_path / "ps"))
    rows = ps.pull("t0", np.asarray([3, 5]))
    ps.push("t0", np.asarray([3]), np.ones((1, 4), np.float32) * 7)
    ps.flush()
    # reopen from disk
    ps2 = CachedPS(tabs, str(tmp_path / "ps"))
    np.testing.assert_allclose(ps2.pull("t0", np.asarray([3]))[0], 7.0)
    np.testing.assert_allclose(ps2.pull("t0", np.asarray([5]))[0], rows[1])


def test_etc_training_integration():
    """Train with cache capacity << vocab; final PS state reflects training."""
    from repro.configs.base import TrainConfig
    from repro.optim.sparse import rowwise_adagrad

    tabs = _tables(n=2, vocab=200, dim=4)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=32, ps=ps)
    params = etc.init_params()
    opt = rowwise_adagrad(TrainConfig(learning_rate=0.5))
    # row-wise opt state lives beside the cache rows
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, remapped, target):
        def loss_fn(p):
            out = cached_lookup(p, remapped)
            return ((out - target) ** 2).mean()
        loss, g = jax.value_and_grad(loss_fn)(
            {"cache": params["cache"], "acc": params["acc"]})
        new_cache, acc_state = opt.update(
            {"c": g["cache"].reshape(-1, 4)},
            {"acc": {"c": params["acc"].reshape(-1)}},
            {"c": params["cache"].reshape(-1, 4)})
        return {"cache": new_cache["c"].reshape(params["cache"].shape),
                "acc": acc_state["acc"]["c"].reshape(params["acc"].shape)
                }, loss

    losses = []
    for i in range(20):
        cat = rng.integers(0, 200, (8, 2, 2)).astype(np.int32)
        params, remapped = etc.prepare(params, cat)
        params, loss = step(params, jnp.asarray(remapped),
                            jnp.ones((8, 2, 4)))
        losses.append(float(loss))
    etc.flush(params)
    assert etc.pulls > 32          # cache thrashed (capacity << working set)
    assert etc.evictions > 0
    assert losses[-1] < losses[0]  # learning happened through the cache
