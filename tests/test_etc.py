"""Embedding Training Cache: residency, eviction writeback, flush, and a
full train-loop integration where the cache is much smaller than the
tables (the paper's TB-scale-training claim, scaled down)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import EmbeddingTableConfig
from repro.core.etc.cache import EmbeddingTrainingCache, cached_lookup
from repro.core.etc.parameter_server import CachedPS, StagedPS


def _tables(n=2, vocab=100, dim=8):
    return [EmbeddingTableConfig(f"t{i}", vocab, dim, hotness=2)
            for i in range(n)]


def test_prepare_makes_ids_resident():
    tabs = _tables()
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=16, ps=ps)
    params = etc.init_params()
    cat = np.asarray([[[3, 5], [7, -1]], [[3, 9], [2, 2]]], np.int32)
    params, remapped = etc.prepare(params, cat)
    # every valid id got a slot, padding stayed -1
    assert (remapped[cat >= 0] >= 0).all()
    assert (remapped[cat < 0] == -1).all()
    # lookup through the cache equals pulling rows from the PS directly
    out = np.asarray(cached_lookup(params, jnp.asarray(remapped)))
    for b in range(2):
        for t in range(2):
            want = np.zeros(8)
            for h in range(2):
                v = cat[b, t, h]
                if v >= 0:
                    want = want + ps.pull(tabs[t].name, np.asarray([v]))[0]
            np.testing.assert_allclose(out[b, t], want, rtol=1e-5)


def test_eviction_writes_back_to_ps():
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    # fill the cache with ids 0..3
    cat = np.arange(4, dtype=np.int32).reshape(4, 1, 1)
    params, rm = etc.prepare(params, cat)
    orig = ps.pull("t0", np.arange(4))   # what prepare() staged
    # mutate the cached rows (simulating a train step)
    params = dict(params)
    params["cache"] = params["cache"] + 1.0
    # now demand 4 new ids -> all old rows must be evicted + written back
    cat2 = (np.arange(4, dtype=np.int32) + 50).reshape(4, 1, 1)
    params, rm2 = etc.prepare(params, cat2)
    assert etc.evictions == 4
    # the PS must hold the *mutated* values for the evicted ids
    rows = ps.pull("t0", np.arange(4))
    np.testing.assert_allclose(rows, orig + 1.0, rtol=1e-6)
    assert etc.pulls == 8


def test_capacity_exceeded_in_one_batch_raises_or_survives():
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    cat = np.arange(4, dtype=np.int32).reshape(4, 1, 1)
    params, _ = etc.prepare(params, cat)
    assert etc.pulls == 4


def test_current_batch_ids_survive_eviction():
    """Eviction must never evict ids needed by the batch being staged."""
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    # make ids 0..3 resident (0 is oldest in LRU order)
    cat = np.arange(4, dtype=np.int32).reshape(4, 1, 1)
    params, _ = etc.prepare(params, cat)
    # now a batch that needs OLD id 0 plus 3 new ids: id 0 must be
    # protected even though it is the LRU candidate
    cat2 = np.asarray([0, 50, 51, 52], np.int32).reshape(4, 1, 1)
    params, rm = etc.prepare(params, cat2)
    assert (rm >= 0).all()


def test_batch_exceeding_capacity_raises():
    tabs = _tables(n=1, vocab=100)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=StagedPS(tabs))
    params = etc.init_params()
    cat = np.arange(8, dtype=np.int32).reshape(8, 1, 1)
    import pytest as _pytest
    with _pytest.raises(ValueError, match="capacity"):
        etc.prepare(params, cat)


def test_flush_persists_everything():
    tabs = _tables(n=1, vocab=50)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=8, ps=ps)
    params = etc.init_params()
    cat = np.asarray([1, 2, 3], np.int32).reshape(3, 1, 1)
    params, rm = etc.prepare(params, cat)
    params = dict(params)
    params["cache"] = params["cache"] * 0 + 42.0
    etc.flush(params)
    for i in (1, 2, 3):
        np.testing.assert_allclose(ps.pull("t0", np.asarray([i]))[0], 42.0)


def test_cached_ps_disk_roundtrip(tmp_path):
    tabs = _tables(n=2, vocab=64, dim=4)
    ps = CachedPS(tabs, str(tmp_path / "ps"))
    rows = ps.pull("t0", np.asarray([3, 5]))
    ps.push("t0", np.asarray([3]), np.ones((1, 4), np.float32) * 7)
    ps.flush()
    # reopen from disk
    ps2 = CachedPS(tabs, str(tmp_path / "ps"))
    np.testing.assert_allclose(ps2.pull("t0", np.asarray([3]))[0], 7.0)
    np.testing.assert_allclose(ps2.pull("t0", np.asarray([5]))[0], rows[1])


@pytest.mark.parametrize("shards", [1, 3])
def test_staged_ps_churn_roundtrip(shards):
    """Vectorized pull/push must round-trip under churn: interleaved
    batched pushes (with duplicate ids) and pulls across shards."""
    tabs = _tables(n=1, vocab=1000, dim=6)
    ps = StagedPS(tabs, shards=shards)
    rng = np.random.default_rng(3)
    oracle = {}
    for _ in range(20):
        ids = rng.integers(0, 1000, 64).astype(np.int64)
        rows = rng.normal(size=(64, 6)).astype(np.float32)
        ps.push("t0", ids, rows)
        for j, i in enumerate(ids):      # keep-last duplicate semantics
            oracle[int(i)] = rows[j]
        probe = np.asarray(sorted(oracle), np.int64)
        got = ps.pull("t0", probe)
        want = np.stack([oracle[int(i)] for i in probe])
        np.testing.assert_array_equal(got, want)


def test_staged_ps_state_roundtrip():
    tabs = _tables(n=1, vocab=100, dim=4)
    ps = StagedPS(tabs)
    ids = np.asarray([7, 3, 7, 50], np.int64)       # dup keeps last
    ps.push_state("t0", ids, np.asarray([1., 2., 3., 4.], np.float32))
    got = ps.pull_state("t0", np.asarray([3, 7, 50, 99]))
    np.testing.assert_array_equal(got, [2., 3., 4., 0.])


def test_cached_ps_state_survives_reopen(tmp_path):
    tabs = _tables(n=1, vocab=32, dim=4)
    ps = CachedPS(tabs, str(tmp_path / "ps"))
    ps.push_state("t0", np.asarray([5]), np.asarray([9.0], np.float32))
    ps.flush()
    ps2 = CachedPS(tabs, str(tmp_path / "ps"))
    np.testing.assert_allclose(ps2.pull_state("t0", np.asarray([5])),
                               [9.0])


def test_pull_after_push_is_deterministic_per_id():
    """A never-pushed id pulls the SAME default row every time (lazy
    defaults are inserted on first pull, then served from the store)."""
    tabs = _tables(n=1, vocab=100, dim=4)
    ps = StagedPS(tabs)
    a = ps.pull("t0", np.asarray([11, 13]))
    b = ps.pull("t0", np.asarray([13, 11]))
    np.testing.assert_array_equal(a[0], b[1])
    np.testing.assert_array_equal(a[1], b[0])


def test_capacity_clamps_to_largest_vocab_with_warning():
    tabs = _tables(n=1, vocab=10)
    with pytest.warns(RuntimeWarning, match="clamping"):
        etc = EmbeddingTrainingCache(tabs, capacity=64, ps=StagedPS(tabs))
    assert etc.capacity == 10
    # a table smaller than capacity (but not all) warns without clamping
    mixed = [tabs[0], EmbeddingTableConfig("big", 100, 8, hotness=2)]
    with pytest.warns(RuntimeWarning, match="fit entirely"):
        etc = EmbeddingTrainingCache(mixed, capacity=64,
                                     ps=StagedPS(mixed))
    assert etc.capacity == 64


def test_drain_touched_includes_evicted_ids():
    """The online-update feed must cover rows evicted mid-pass, not just
    the resident set (a pass's updates would otherwise go missing)."""
    tabs = _tables(n=1, vocab=100)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=4, ps=ps)
    params = etc.init_params()
    params, _ = etc.prepare(
        params, np.arange(4, dtype=np.int32).reshape(4, 1, 1))
    # evict 0..3 by demanding 50..53
    params, _ = etc.prepare(
        params, (np.arange(4, dtype=np.int32) + 50).reshape(4, 1, 1))
    touched = etc.drain_touched(0)
    np.testing.assert_array_equal(touched, [0, 1, 2, 3, 50, 51, 52, 53])
    assert etc.drain_touched(0).size == 0    # drained


def test_etc_training_integration():
    """Train with cache capacity << vocab; final PS state reflects training."""
    from repro.configs.base import TrainConfig
    from repro.optim.sparse import rowwise_adagrad

    tabs = _tables(n=2, vocab=200, dim=4)
    ps = StagedPS(tabs)
    etc = EmbeddingTrainingCache(tabs, capacity=32, ps=ps)
    params = etc.init_params()
    opt = rowwise_adagrad(TrainConfig(learning_rate=0.5))
    # row-wise opt state lives beside the cache rows
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, remapped, target):
        def loss_fn(p):
            out = cached_lookup(p, remapped)
            return ((out - target) ** 2).mean()
        loss, g = jax.value_and_grad(loss_fn)(
            {"cache": params["cache"], "acc": params["acc"]})
        new_cache, acc_state = opt.update(
            {"c": g["cache"].reshape(-1, 4)},
            {"acc": {"c": params["acc"].reshape(-1)}},
            {"c": params["cache"].reshape(-1, 4)})
        return {"cache": new_cache["c"].reshape(params["cache"].shape),
                "acc": acc_state["acc"]["c"].reshape(params["acc"].shape)
                }, loss

    losses = []
    for i in range(20):
        cat = rng.integers(0, 200, (8, 2, 2)).astype(np.int32)
        params, remapped = etc.prepare(params, cat)
        params, loss = step(params, jnp.asarray(remapped),
                            jnp.ones((8, 2, 4)))
        losses.append(float(loss))
    etc.flush(params)
    assert etc.pulls > 32          # cache thrashed (capacity << working set)
    assert etc.evictions > 0
    assert losses[-1] < losses[0]  # learning happened through the cache
