"""The vectorized HPS lookup path: Pallas gather kernel vs oracle,
batched-query equivalence against ground truth, batch-aware eviction,
overflow handling, refresh-vs-query thread safety, VDB copy semantics,
and the validated ``HPS.lookup`` query shapes (hotness, mean combiner)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import EmbeddingTableConfig
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB
from repro.kernels import ops, ref


def _store(vocab=200, dim=8, seed=0):
    return np.random.default_rng(seed).normal(
        size=(vocab, dim)).astype(np.float32)


def _pdb_with_table(tmp_path, model="m", table="t0", vocab=100, dim=4):
    pdb = PersistentDB(str(tmp_path / "pdb"))
    rows = np.arange(vocab * dim, dtype=np.float32).reshape(vocab, dim)
    pdb.create_table(model, table, vocab, dim, initial=rows)
    return pdb, rows


# ---------------------------------------------------------------------------
# Pallas gather kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,d", [(7, 24, 8), (64, 512, 32), (200, 100, 4)])
def test_gather_kernel_matches_ref(n, c, d):
    rng = np.random.default_rng(c)
    payload = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    slots = rng.integers(-1, c, size=n)
    got = ops.cache_gather(payload, slots, use_kernel=True)  # interpret mode
    want = ref.cache_gather_ref(payload, jnp.asarray(slots))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_gather_native_path_matches_kernel():
    payload = jnp.asarray(_store(50, 8))
    slots = np.asarray([0, 49, -1, 7, 7])
    a = ops.cache_gather(payload, slots, use_kernel=True)
    b = ops.cache_gather(payload, slots, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# batched cache vs ground truth (with eviction churn)
# ---------------------------------------------------------------------------

def test_batched_query_matches_store_under_churn():
    store = _store(vocab=200, dim=8)
    c = DeviceEmbeddingCache(16, 8, fetch_fn=lambda ids: store[ids])
    rng = np.random.default_rng(3)
    for _ in range(20):
        ids = rng.integers(0, 200, size=rng.integers(1, 40))
        np.testing.assert_allclose(np.asarray(c.query(ids)), store[ids],
                                   rtol=1e-5, atol=1e-6)
    assert len(c.resident_ids()) <= 16


def test_single_fetch_and_scatter_per_query(monkeypatch):
    store = _store()
    fetches, scatters = [], []
    c = DeviceEmbeddingCache(
        8, 8, fetch_fn=lambda ids: fetches.append(len(ids)) or store[ids])
    orig = DeviceEmbeddingCache._scatter_locked
    monkeypatch.setattr(
        DeviceEmbeddingCache, "_scatter_locked",
        lambda self, s, r: scatters.append(len(s)) or orig(self, s, r))
    c.query(np.asarray([5, 1, 5, 9, 1, 3]))       # 4 unique misses
    assert fetches == [4] and scatters == [4]
    fetches.clear(); scatters.clear()
    c.query(np.asarray([5, 1, 9, 3]))             # all hits: no device write
    assert fetches == [] and scatters == []


# ---------------------------------------------------------------------------
# batch-aware eviction
# ---------------------------------------------------------------------------

def test_same_batch_insertions_never_evict_each_other():
    store = _store()
    c = DeviceEmbeddingCache(4, 8, fetch_fn=lambda ids: store[ids])
    c.query(np.asarray([0, 1, 2, 3]))             # fill
    out = np.asarray(c.query(np.asarray([10, 11, 12, 13])))
    np.testing.assert_allclose(out, store[[10, 11, 12, 13]], rtol=1e-5)
    # ALL four new ids are resident — the batch displaced the old ids,
    # not its own insertions (the seed's per-id argmin evicted rows it
    # had inserted moments earlier in the same query)
    assert set(c.resident_ids()) == {10, 11, 12, 13}


def test_eviction_protects_current_batch_hits():
    store = _store()
    c = DeviceEmbeddingCache(2, 8, fetch_fn=lambda ids: store[ids])
    c.query(np.asarray([1]))
    c.query(np.asarray([2, 2, 2]))                # 2 is now the LFU-hottest
    out = np.asarray(c.query(np.asarray([1, 9])))
    np.testing.assert_allclose(out, store[[1, 9]], rtol=1e-5)
    # 9 needed a victim; 1 is a hit of this very query so despite 2's
    # higher frequency the cache must not corrupt the row it returns
    assert 1 in c.resident_ids() and 9 in c.resident_ids()


def test_overflow_batch_larger_than_capacity():
    store = _store()
    c = DeviceEmbeddingCache(2, 8, fetch_fn=lambda ids: store[ids])
    ids = np.asarray([7, 3, 9, 11, 3, 20])        # 5 unique > capacity 2
    np.testing.assert_allclose(np.asarray(c.query(ids)), store[ids],
                               rtol=1e-5, atol=1e-6)
    res = c.resident_ids()
    assert len(res) == 2 and set(res) <= {7, 3, 9, 11, 20}
    # the duplicated id (hottest miss) must be among the cached ones
    assert 3 in res
    # and the cache still serves correctly afterwards
    np.testing.assert_allclose(np.asarray(c.query(np.asarray([3]))),
                               store[[3]], rtol=1e-5)


# ---------------------------------------------------------------------------
# refresh vs query thread safety
# ---------------------------------------------------------------------------

def test_refresh_vs_query_thread_safety():
    store = _store(vocab=64, dim=4)
    c = DeviceEmbeddingCache(16, 4, fetch_fn=lambda ids: store[ids])
    stop = threading.Event()
    errors = []

    def refresher():
        while not stop.is_set():
            c.refresh_once()

    def querier(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(30):
                ids = rng.integers(0, 64, size=8)
                np.testing.assert_allclose(np.asarray(c.query(ids)),
                                           store[ids], rtol=1e-5)
        except Exception as e:  # surfaced in the main thread below
            errors.append(e)

    threads = [threading.Thread(target=querier, args=(i,)) for i in range(3)]
    rt = threading.Thread(target=refresher)
    rt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rt.join()
    assert not errors


# ---------------------------------------------------------------------------
# VolatileDB: vectorized + copy semantics
# ---------------------------------------------------------------------------

def test_vdb_never_aliases_caller_arrays():
    vdb = VolatileDB()
    rows = np.ones((2, 4), np.float32)
    vdb.insert("t", np.asarray([1, 2]), rows)
    rows[:] = 777.0                               # caller mutates its buffer
    _, got = vdb.query("t", np.asarray([1, 2]))
    np.testing.assert_allclose(got, 1.0)          # store unaffected
    got[:] = 555.0                                # caller mutates the result
    _, again = vdb.query("t", np.asarray([1, 2]))
    np.testing.assert_allclose(again, 1.0)


def test_vdb_batched_roundtrip_sharded():
    vdb = VolatileDB(shards=3, capacity_per_shard=50)
    store = _store(vocab=100, dim=6)
    ids = np.random.default_rng(5).permutation(100)[:60]
    vdb.insert("t", ids, store[ids])
    mask, rows = vdb.query("t", ids)
    assert mask.all()
    np.testing.assert_allclose(rows, store[ids], rtol=1e-6)
    mask, _ = vdb.query("t", np.asarray([101, 102]) % 101)
    assert not mask[0] or not mask[1]             # at least one true miss


def test_vdb_duplicate_ids_last_write_wins():
    # batched online updates concatenate chronologically (Producer.flush),
    # so a duplicated id in one insert must keep the NEWEST row
    vdb = VolatileDB()
    ids = np.asarray([5, 5, 7])
    rows = np.stack([np.full(2, 1.0), np.full(2, 2.0),
                     np.full(2, 3.0)]).astype(np.float32)
    vdb.insert("t", ids, rows)
    _, got = vdb.query("t", np.asarray([5, 7]))
    np.testing.assert_allclose(got[0], 2.0)
    np.testing.assert_allclose(got[1], 3.0)


def test_vdb_update_in_place():
    vdb = VolatileDB()
    vdb.insert("t", np.asarray([4]), np.ones((1, 2), np.float32))
    vdb.insert("t", np.asarray([4]), np.full((1, 2), 9.0, np.float32))
    _, rows = vdb.query("t", np.asarray([4]))
    np.testing.assert_allclose(rows[0], 9.0)
    assert vdb.size("t") == 1


# ---------------------------------------------------------------------------
# HPS.lookup: shape validation, hotness, combiners
# ---------------------------------------------------------------------------

def test_lookup_rejects_table_mismatch(tmp_path):
    pdb, _ = _pdb_with_table(tmp_path)
    hps = HPS("m", [EmbeddingTableConfig("t0", 100, 4)], pdb)
    with pytest.raises(ValueError, match="does not match"):
        hps.lookup(np.zeros((2, 3, 1), np.int32))
    with pytest.raises(ValueError, match="hotness"):
        hps.lookup(np.zeros((2, 2), np.int32))    # 2-D needs hotness
    with pytest.raises(ValueError, match="hotness"):
        hps.lookup(np.zeros((2, 1, 2), np.int32), hotness=[1, 1])


def test_lookup_empty_batch(tmp_path):
    pdb, _ = _pdb_with_table(tmp_path)
    hps = HPS("m", [EmbeddingTableConfig("t0", 100, 4)], pdb)
    out = np.asarray(hps.lookup(np.zeros((0, 1, 2), np.int32)))
    assert out.shape == (0, 1, 4)


def test_lookup_honors_hotness_mask(tmp_path):
    pdb, rows = _pdb_with_table(tmp_path)
    hps = HPS("m", [EmbeddingTableConfig("t0", 100, 4)], pdb)
    cat = np.asarray([[[3, 7]]], np.int32)
    out = np.asarray(hps.lookup(cat, hotness=[1]))  # col 1 masked off
    np.testing.assert_allclose(out[0, 0], rows[3])


def test_lookup_2d_hotness_split(tmp_path):
    pdb = PersistentDB(str(tmp_path / "pdb"))
    dim = 4
    stores = {}
    for name in ("a", "b"):
        stores[name] = _store(50, dim, seed=ord(name))
        pdb.create_table("m", name, 50, dim, initial=stores[name])
    tabs = [EmbeddingTableConfig("a", 50, dim, hotness=2),
            EmbeddingTableConfig("b", 50, dim, hotness=1)]
    hps = HPS("m", tabs, pdb)
    cat = np.asarray([[1, 2, 5], [3, -1, 6]], np.int32)  # a:[:2], b:[2:]
    out = np.asarray(hps.lookup(cat, hotness=[2, 1]))
    np.testing.assert_allclose(out[0, 0], stores["a"][1] + stores["a"][2],
                               rtol=1e-5)
    np.testing.assert_allclose(out[1, 0], stores["a"][3], rtol=1e-5)
    np.testing.assert_allclose(out[:, 1], stores["b"][[5, 6]], rtol=1e-5)


def test_lookup_mean_combiner(tmp_path):
    pdb, rows = _pdb_with_table(tmp_path)
    hps = HPS("m", [EmbeddingTableConfig("t0", 100, 4, hotness=3,
                                         combiner="mean")], pdb)
    cat = np.asarray([[[2, 4, -1]], [[6, -1, -1]]], np.int32)
    out = np.asarray(hps.lookup(cat))
    np.testing.assert_allclose(out[0, 0], (rows[2] + rows[4]) / 2, rtol=1e-5)
    np.testing.assert_allclose(out[1, 0], rows[6], rtol=1e-5)


def test_lookup_overflow_path_exact(tmp_path):
    pdb, rows = _pdb_with_table(tmp_path)
    hps = HPS("m", [EmbeddingTableConfig("t0", 100, 4, hotness=8,
                                         combiner="mean")], pdb,
              cache_capacity=2)
    cat = np.arange(8, dtype=np.int32).reshape(1, 1, 8) * 3
    out = np.asarray(hps.lookup(cat))
    np.testing.assert_allclose(out[0, 0], rows[::3][:8].mean(axis=0),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# compressed L1 payloads: parity-tolerance tiers (f32 bit-exact;
# f16/int8 bounded max-abs error against the f32 oracle)
# ---------------------------------------------------------------------------

# max-abs tolerance per pooled output element for normal(0,1) rows with
# hotness <= 4: f16 keeps ~3 decimal digits; int8 per-element error is
# bounded by absmax/254 per row, summed over the pool
_PAYLOAD_TOL = {"f16": 2e-2, "int8": 1e-1}


def test_quantize_rows_roundtrip_bound():
    from repro.core.hps.payload_store import quantize_rows
    rng = np.random.default_rng(11)
    rows = rng.normal(size=(50, 8)).astype(np.float32)
    rows[7] = 0.0                                  # zero row edge case
    q, scales = quantize_rows(rows, "int8")
    assert q.dtype == np.int8 and scales.dtype == np.float32
    assert scales[7] == 1.0 and not q[7].any()
    deq = q.astype(np.float32) * scales[:, None]
    bound = np.abs(rows).max(axis=1) / 254.0 + 1e-7
    assert (np.abs(deq - rows).max(axis=1) <= bound).all()
    h, none = quantize_rows(rows, "f16")
    assert h.dtype == np.float16 and none is None
    f, none = quantize_rows(rows, "f32")
    np.testing.assert_array_equal(f, rows)
    assert none is None


@pytest.mark.parametrize("n,c,d", [(7, 24, 8), (64, 512, 32), (200, 100, 4)])
def test_dequant_gather_kernel_matches_ref(n, c, d):
    """The fused dequantize-gather Pallas kernel (scale folded into the
    one-hot before the MXU pass) vs the plain take-then-scale oracle."""
    rng = np.random.default_rng(c + 1)
    payload = jnp.asarray(
        rng.integers(-127, 128, size=(c, d)).astype(np.int8))
    scales = jnp.asarray(rng.uniform(0.01, 2.0, size=c).astype(np.float32))
    slots = rng.integers(-1, c, size=n)
    got = ops.cache_gather(payload, slots, scales=scales, use_kernel=True)
    want = ref.dequant_gather_ref(payload, scales, jnp.asarray(slots))
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", ["f32", "f16", "int8"])
def test_payload_dtype_cache_parity(dtype):
    """DeviceEmbeddingCache in each storage mode vs the backing store:
    f32 stays bit-exact; compressed modes stay within the tier bound —
    across hits, misses, eviction churn and overflow batches."""
    store = _store(vocab=200, dim=8)
    c = DeviceEmbeddingCache(16, 8, fetch_fn=lambda ids: store[ids],
                             payload_dtype=dtype)
    rng = np.random.default_rng(13)
    for _ in range(15):
        ids = rng.integers(0, 200, size=rng.integers(1, 40))
        got = np.asarray(c.query(ids))
        if dtype == "f32":
            np.testing.assert_array_equal(got, store[ids])
        else:
            assert np.abs(got - store[ids]).max() <= _PAYLOAD_TOL[dtype]


@pytest.mark.parametrize("dtype", ["f32", "f16", "int8"])
def test_payload_dtype_lookup_parity(tmp_path, dtype):
    """End-to-end HPS.lookup (multi-table, multi-hot, pooled) in each
    payload mode vs the f32 oracle."""
    pdb = PersistentDB(str(tmp_path / "pdb"))
    dim, vocab = 8, 80
    tabs = []
    for i, name in enumerate(("x", "y")):
        pdb.create_table("m", name, vocab, dim,
                         initial=_store(vocab, dim, seed=20 + i))
    tabs = [EmbeddingTableConfig(n, vocab, dim, hotness=4)
            for n in ("x", "y")]
    hps = HPS("m", tabs, pdb, cache_capacity=32, payload_dtype=dtype)
    oracle = HPS("m", tabs, pdb, cache_capacity=32)
    rng = np.random.default_rng(17)
    for _ in range(4):
        cat = rng.integers(-1, vocab, size=(6, 2, 4)).astype(np.int32)
        got = np.asarray(hps.lookup(cat))
        want = np.asarray(oracle.lookup(cat))
        if dtype == "f32":
            np.testing.assert_array_equal(got, want)
        else:
            assert np.abs(got - want).max() <= _PAYLOAD_TOL[dtype]


@pytest.mark.parametrize("dtype", ["f16", "int8"])
def test_payload_dtype_online_update_refresh(tmp_path, dtype):
    """A dirty-row refresh requantizes from the full-precision lower
    levels: after an online update the compressed L1 serves the NEW
    value within the mode's bound, not the stale cached row."""
    from repro.core.hps.message_bus import MessageBus, Producer
    pdb, _ = _pdb_with_table(tmp_path)
    bus = MessageBus()
    hps = HPS("m", [EmbeddingTableConfig("t0", 100, 4)], pdb,
              cache_capacity=64, bus=bus, payload_dtype=dtype)
    cat = np.full((1, 1, 2), -1, np.int32)
    cat[0, 0, 0] = 5
    hps.lookup(cat)                                # cache id 5
    new_row = np.linspace(-9.0, 21.0, 4).astype(np.float32)
    prod = Producer(bus, "m")
    prod.send("t0", np.asarray([5]), new_row[None, :])
    prod.flush()
    assert hps.apply_updates() == 1
    hps.refresh_caches()
    after = np.asarray(hps.lookup(cat))[0, 0]
    tol = (np.abs(new_row).max() / 254.0 + 1e-6 if dtype == "int8"
           else np.abs(new_row).max() * 1e-3)
    assert np.abs(after - new_row).max() <= tol


def test_lookup_batched_matches_reference(tmp_path):
    """Multi-table, multi-hot batched lookup vs a direct numpy oracle."""
    pdb = PersistentDB(str(tmp_path / "pdb"))
    dim, vocab = 8, 80
    stores = {}
    tabs = []
    for i, name in enumerate(("x", "y", "z")):
        stores[name] = _store(vocab, dim, seed=10 + i)
        pdb.create_table("m", name, vocab, dim, initial=stores[name])
        tabs.append(EmbeddingTableConfig(name, vocab, dim, hotness=4))
    hps = HPS("m", tabs, pdb, cache_capacity=32)
    rng = np.random.default_rng(7)
    for _ in range(5):
        cat = rng.integers(-1, vocab, size=(6, 3, 4)).astype(np.int32)
        out = np.asarray(hps.lookup(cat))
        for ti, name in enumerate(("x", "y", "z")):
            ids = cat[:, ti, :]
            want = np.zeros((6, dim), np.float32)
            for b in range(6):
                for h in range(4):
                    if ids[b, h] >= 0:
                        want[b] += stores[name][ids[b, h]]
            np.testing.assert_allclose(out[:, ti], want, rtol=1e-4,
                                       atol=1e-5)
