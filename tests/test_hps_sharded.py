"""The sharded, pipelined HPS serving engine: striped payload store
equivalence (N>1 host shards == N=1, bit-exact), the sharded gather
kernel entry points (flat remap + shard_map over real devices), the
hotness-scheduled refresh (hot-before-cold, per-cycle budget), and the
double-buffered lookup pipeline (pipelined == sequential, stream ==
sequential, server-loop-driven refresh)."""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import EmbeddingTableConfig
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.hps import HPS
from repro.core.hps.payload_store import ShardedPayloadStore
from repro.core.hps.persistent_db import PersistentDB
from repro.kernels import ops, ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _store(vocab=200, dim=8, seed=0):
    return np.random.default_rng(seed).normal(
        size=(vocab, dim)).astype(np.float32)


def _hps(tmp_path, tag, vocab=120, dim=8, n_tables=3, hotness=4, **kw):
    pdb = PersistentDB(str(tmp_path / f"pdb_{tag}"))
    tabs = []
    for i in range(n_tables):
        rows = _store(vocab, dim, seed=50 + i)
        pdb.create_table("m", f"t{i}", vocab, dim, initial=rows)
        tabs.append(EmbeddingTableConfig(
            f"t{i}", vocab, dim, hotness=hotness,
            combiner="mean" if i % 2 else "sum"))
    return HPS("m", tabs, pdb, **kw)


# ---------------------------------------------------------------------------
# sharded gather entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_stripes,cl,d,n", [(2, 24, 8, 7), (4, 16, 32, 64),
                                              (8, 8, 4, 200)])
def test_sharded_gather_matches_ref(n_stripes, cl, d, n):
    rng = np.random.default_rng(n_stripes * 100 + n)
    stripes = jnp.asarray(rng.normal(size=(n_stripes, cl, d))
                          .astype(np.float32))
    slots = rng.integers(-1, n_stripes * cl, size=n)
    want = ref.sharded_gather_ref(stripes, jnp.asarray(slots))
    got = ops.sharded_cache_gather(stripes, slots)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_k = ops.sharded_cache_gather(stripes, slots, use_kernel=True)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_sharded_pooled_matches_ref():
    rng = np.random.default_rng(3)
    stripes = jnp.asarray(rng.normal(size=(4, 16, 8)).astype(np.float32))
    slots = rng.integers(-1, 64, size=(6, 5))
    rows = np.asarray(ref.sharded_gather_ref(
        stripes, jnp.asarray(slots.reshape(-1)))).reshape(6, 5, 8)
    got = ops.sharded_pooled_lookup(stripes, jnp.asarray(slots))
    np.testing.assert_allclose(np.asarray(got), rows.sum(axis=1),
                               rtol=1e-5, atol=1e-5)


def test_sharded_store_scatter_gather_roundtrip():
    rng = np.random.default_rng(4)
    for shards in (1, 3, 4):
        st = ShardedPayloadStore(60, 8, shards=shards)
        slots = np.arange(0, 60, 3, dtype=np.int64)
        rows = rng.normal(size=(len(slots), 8)).astype(np.float32)
        st.scatter(slots, rows)
        probe = np.concatenate([slots, [-1]])
        out = np.asarray(st.gather(st.snapshot(), jnp.asarray(probe)))
        np.testing.assert_array_equal(out[:-1], rows)
        assert (out[-1] == 0).all()


@pytest.mark.parametrize("n_stripes,cl,d,n", [(2, 24, 8, 7), (4, 16, 32, 64)])
def test_sharded_dequant_gather_matches_ref(n_stripes, cl, d, n):
    """Striped int8 stripes + per-row scales through the same flat-remap
    and kernel entry points, vs the dequantizing oracle."""
    rng = np.random.default_rng(n_stripes * 10 + n)
    stripes = jnp.asarray(
        rng.integers(-127, 128, size=(n_stripes, cl, d)).astype(np.int8))
    scales = jnp.asarray(
        rng.uniform(0.01, 2.0, size=(n_stripes, cl)).astype(np.float32))
    slots = rng.integers(-1, n_stripes * cl, size=n)
    want = ref.dequant_sharded_gather_ref(stripes, scales,
                                          jnp.asarray(slots))
    got = ops.sharded_cache_gather(stripes, slots, scales=scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    got_k = ops.sharded_cache_gather(stripes, slots, scales=scales,
                                     use_kernel=True)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", ["f16", "int8"])
def test_sharded_store_compressed_roundtrip(dtype):
    """Striped compressed store: scatter quantizes, gather dequantizes
    in-kernel; every shard count serves the same values within the
    mode's bound, and the -1 sentinel row stays exactly zero."""
    rng = np.random.default_rng(6)
    slots = np.arange(0, 60, 3, dtype=np.int64)
    rows = rng.normal(size=(len(slots), 8)).astype(np.float32)
    bound = 1e-2 if dtype == "f16" else \
        float(np.abs(rows).max()) / 254.0 + 1e-6
    for shards in (1, 3, 4):
        st = ShardedPayloadStore(60, 8, shards=shards, payload_dtype=dtype)
        st.scatter(slots, rows)
        probe = np.concatenate([slots, [-1]])
        out = np.asarray(st.gather(st.snapshot(), jnp.asarray(probe)))
        assert out.dtype == np.float32
        assert np.abs(out[:-1] - rows).max() <= bound
        assert (out[-1] == 0).all()


def test_sharded_hps_compressed_matches_f32_oracle(tmp_path):
    """Striped + compressed end-to-end: HPS with cache_shards=4 and an
    int8 L1 vs the same-stream f32 striped oracle."""
    h32 = _hps(tmp_path, "c32", cache_capacity=32, cache_shards=4)
    h8 = _hps(tmp_path, "c8", cache_capacity=32, cache_shards=4,
              payload_dtype="int8")
    rng = np.random.default_rng(14)
    for _ in range(6):
        cat = rng.integers(-1, 120, size=(8, 3, 4)).astype(np.int32)
        a = np.asarray(h32.lookup(cat))
        b = np.asarray(h8.lookup(cat))
        assert np.abs(a - b).max() <= 1e-1
    # identical index decisions: compression changes bytes, not policy
    assert {k: c.hits for k, c in h32.caches.items()} == \
        {k: c.hits for k, c in h8.caches.items()}


def test_sharded_store_validation():
    with pytest.raises(ValueError, match="shards"):
        ShardedPayloadStore(4, 8, shards=8)
    with pytest.raises(ValueError, match="shards"):
        ShardedPayloadStore(16, 8, shards=0)


def test_sharded_gather_over_real_devices():
    """The shard_map path: stripes distributed over 4 virtual CPU
    devices, per-device gather + one psum, vs the oracle (subprocess so
    the main pytest process keeps its single real device)."""
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.kernels import ops, ref
from repro.core.hps.payload_store import ShardedPayloadStore
from repro.launch.mesh import make_cache_mesh
assert len(jax.devices()) == 4
rng = np.random.default_rng(1)
stripes = jnp.asarray(rng.normal(size=(8, 16, 8)).astype(np.float32))
slots = rng.integers(-1, 128, size=37)
want = np.asarray(ref.sharded_gather_ref(stripes, jnp.asarray(slots)))
mesh = make_cache_mesh(8)
assert mesh.shape["cache"] == 4
for kw in ({}, {"use_kernel": True}):
    got = np.asarray(ops.sharded_cache_gather(stripes, slots, mesh=mesh,
                                              **kw))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
s2 = jnp.asarray(rng.integers(-1, 128, size=(6, 5)))
pw = np.asarray(ref.sharded_gather_ref(
    stripes, s2.reshape(-1))).reshape(6, 5, 8).sum(1)
pg = np.asarray(ops.sharded_pooled_lookup(stripes, s2, mesh=mesh))
np.testing.assert_allclose(pg, pw, rtol=1e-5, atol=1e-5)
st = ShardedPayloadStore(120, 8, shards=8, mesh=mesh)
sl = np.arange(0, 120, 3, dtype=np.int64)
rows = rng.normal(size=(len(sl), 8)).astype(np.float32)
st.scatter(sl, rows)
out = np.asarray(st.gather(st.snapshot(), jnp.asarray(sl)))
np.testing.assert_array_equal(out, rows)
# compressed stripes over the same mesh: scales shard with their
# stripes through the one-psum path, values stay within the int8 bound
sq = ShardedPayloadStore(120, 8, shards=8, mesh=mesh,
                         payload_dtype="int8")
sq.scatter(sl, rows)
qout = np.asarray(sq.gather(sq.snapshot(), jnp.asarray(sl)))
assert qout.dtype == np.float32
assert np.abs(qout - rows).max() <= np.abs(rows).max() / 254.0 + 1e-6
print("multi-device striped gather OK")
"""
    code = ("import os\nos.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=4'\n" + body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    assert "multi-device striped gather OK" in proc.stdout


# ---------------------------------------------------------------------------
# (a) striped cache == single-payload cache on the same query stream
# ---------------------------------------------------------------------------

def test_sharded_cache_matches_unsharded_under_churn():
    store = _store(vocab=300, dim=8)
    caches = {n: DeviceEmbeddingCache(32, 8, shards=n,
                                      fetch_fn=lambda ids: store[ids])
              for n in (1, 4)}
    rng = np.random.default_rng(11)
    for _ in range(25):
        ids = rng.integers(-1, 300, size=rng.integers(1, 64))
        outs = {n: np.asarray(c.query(ids)) for n, c in caches.items()}
        # same stream, same index decisions -> bit-identical rows
        np.testing.assert_array_equal(outs[1], outs[4])
    assert caches[1].hits == caches[4].hits
    np.testing.assert_array_equal(caches[1].resident_ids(),
                                  caches[4].resident_ids())


def test_sharded_hps_matches_unsharded_pooled(tmp_path):
    h1 = _hps(tmp_path, "n1", cache_capacity=32)
    h4 = _hps(tmp_path, "n4", cache_capacity=32, cache_shards=4)
    rng = np.random.default_rng(12)
    for _ in range(6):
        cat = rng.integers(-1, 120, size=(8, 3, 4)).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(h1.lookup(cat)),
                                      np.asarray(h4.lookup(cat)))


# ---------------------------------------------------------------------------
# (b) hotness-scheduled refresh
# ---------------------------------------------------------------------------

def test_refresh_hot_row_before_cold_row_within_budget():
    store = _store(vocab=20, dim=4)
    c = DeviceEmbeddingCache(8, 4, fetch_fn=lambda ids: store[ids])
    for _ in range(5):
        c.query(np.asarray([3]))              # id 3 becomes hot
    c.query(np.asarray([7]))                  # id 7 stays cold
    orig7 = store[7].copy()
    store[3] = 111.0                          # both rows go stale below
    store[7] = 222.0
    assert c.mark_dirty(np.asarray([3, 7])) == 2
    assert c.refresh_backlog() == 2

    assert c.refresh_chunk(budget=1) == 1     # budget respected
    # the HOT dirty row was refreshed first; the cold one still stale
    np.testing.assert_allclose(np.asarray(c.query(np.asarray([3])))[0],
                               111.0)
    np.testing.assert_allclose(np.asarray(c.query(np.asarray([7])))[0],
                               orig7)
    assert c.refresh_backlog() == 1
    assert c.refresh_chunk(budget=4) == 1     # drains the cold row
    np.testing.assert_allclose(np.asarray(c.query(np.asarray([7])))[0],
                               222.0)
    assert c.refresh_backlog() == 0
    assert c.rows_refreshed == 2 and c.refresh_chunks == 2


def test_refresh_chunk_never_exceeds_budget():
    store = _store(vocab=64, dim=4)
    c = DeviceEmbeddingCache(32, 4, fetch_fn=lambda ids: store[ids])
    c.query(np.arange(32))
    fetched = []
    orig = c.fetch_fn
    c.fetch_fn = lambda ids: fetched.append(len(ids)) or orig(ids)
    c.mark_all_dirty()
    while c.refresh_backlog():
        c.refresh_chunk(budget=5)
    assert max(fetched) <= 5                  # per-cycle fetch bounded
    assert sum(fetched) == 32                 # every resident row covered
    assert c.rows_refreshed == 32


def test_mark_dirty_only_touches_resident():
    store = _store(vocab=30, dim=4)
    c = DeviceEmbeddingCache(8, 4, fetch_fn=lambda ids: store[ids])
    c.query(np.asarray([1, 2]))
    assert c.mark_dirty(np.asarray([1, 25, 26])) == 1
    assert c.refresh_backlog() == 1


def test_insertion_clears_dirty():
    """A slot reused by a fresh insertion must not inherit the old
    row's dirty bit (the new row just came from the lower levels)."""
    store = _store(vocab=30, dim=4)
    c = DeviceEmbeddingCache(2, 4, fetch_fn=lambda ids: store[ids])
    c.query(np.asarray([1, 2]))
    c.mark_all_dirty()
    c.query(np.asarray([3, 3, 3]))            # evicts one dirty slot
    assert c.refresh_backlog() == 1           # only the survivor is dirty


def test_refresh_once_still_full_repull():
    store = _store(vocab=10, dim=4)
    c = DeviceEmbeddingCache(8, 4, fetch_fn=lambda ids: store[ids],
                             refresh_chunk_rows=2)   # forces chunking
    c.query(np.asarray([0, 1, 2, 3, 4]))
    store[:5] = 77.0
    assert c.refresh_once() == 5
    np.testing.assert_allclose(
        np.asarray(c.query(np.arange(5))), 77.0)


def test_hps_refresh_step_and_stats(tmp_path):
    hps = _hps(tmp_path, "rs", n_tables=2, cache_capacity=16)
    cat = np.asarray([[[1, -1, -1, -1], [2, -1, -1, -1]]], np.int32)
    hps.lookup(cat)
    assert hps.schedule_refresh() == 2        # one resident row per table
    assert hps.refresh_backlog() == 2
    assert hps.refresh_step(budget=8) == 2
    st = hps.stats()
    assert st["refresh"]["rows_refreshed"] == 2
    assert st["refresh"]["backlog"] == 0
    assert st["refresh"]["chunks"] == 2
    assert sum(st["l3_fetches"]["calls"].values()) >= 2
    assert "tables" in st["l2"]


# ---------------------------------------------------------------------------
# (c) pipelined lookup == sequential lookup
# ---------------------------------------------------------------------------

def test_pipelined_matches_sequential_randomized(tmp_path):
    """Mixed combiners + hotness + eviction churn + overflow, two
    instances fed the identical stream: the double-buffered path must be
    bit-identical to the sequential one."""
    h_seq = _hps(tmp_path, "seq", cache_capacity=24)
    h_pipe = _hps(tmp_path, "pipe", cache_capacity=24)
    rng = np.random.default_rng(21)
    for step in range(10):
        b = int(rng.integers(1, 12))
        cat = rng.integers(-1, 120, size=(b, 3, 4)).astype(np.int32)
        hot = [int(x) for x in rng.integers(1, 5, size=3)] \
            if step % 2 else None
        a = np.asarray(h_seq.lookup(cat, hot, pipelined=False))
        p = np.asarray(h_pipe.lookup(cat, hot, pipelined=True))
        np.testing.assert_array_equal(a, p)
    assert {k: c.hits for k, c in h_seq.caches.items()} == \
        {k: c.hits for k, c in h_pipe.caches.items()}


def test_lookup_stream_matches_sequential(tmp_path):
    h_seq = _hps(tmp_path, "sseq", cache_capacity=24)
    h_str = _hps(tmp_path, "sstr", cache_capacity=24)
    rng = np.random.default_rng(22)
    queries = [rng.integers(-1, 120, size=(6, 3, 4)).astype(np.int32)
               for _ in range(8)]
    outs = list(h_str.lookup_stream(iter(queries)))
    assert len(outs) == len(queries)
    for q, o in zip(queries, outs):
        np.testing.assert_array_equal(np.asarray(h_seq.lookup(q)), o)


def test_lookup_stream_autotunes_depth_in_deep_rtt(tmp_path):
    """The ROADMAP open item: the stream lookahead is no longer a
    hard-coded 2 — in a deep-RTT regime (every coalesced miss fetch
    pays a remote-L2-style round trip) the auto-tuner admits MORE
    in-flight queries (bounded by the cap), and a warm fetch-free
    stream stays at the classic double buffer."""
    hps = _hps(tmp_path, "auto", cache_capacity=16)   # tiny L1: misses
    for c in hps.caches.values():                     # every fetch pays
        orig = c.fetch_fn                             # an RTT

        def slow(ids, _orig=orig):
            time.sleep(0.02)
            return _orig(ids)

        c.fetch_fn = slow
    rng = np.random.default_rng(7)
    queries = [rng.integers(0, 120, size=(4, 3, 4)).astype(np.int32)
               for _ in range(12)]
    outs = list(hps.lookup_stream(iter(queries)))
    assert len(outs) == len(queries)
    assert hps.stream_depth_peak > 2        # deepened past the classic 2
    assert hps.stream_depth_peak <= 8       # ...within the bounded cap
    assert hps.stats()["stream"]["depth_peak"] == hps.stream_depth_peak
    # results stay bit-identical to the unpipelined path under the
    # deepened lookahead
    ref_hps = _hps(tmp_path, "auto_ref", cache_capacity=16)
    for q, o in zip(queries, outs):
        np.testing.assert_array_equal(np.asarray(ref_hps.lookup(q)), o)

    # warm regime: resident ids, near-zero fetch -> classic depth
    warm_hps = _hps(tmp_path, "warm", cache_capacity=200)
    warm = [np.full((4, 3, 4), 5, np.int32) for _ in range(10)]
    list(warm_hps.lookup_stream(iter(warm)))
    assert warm_hps.stream_depth == 2


def test_lookup_stream_explicit_depth_is_pinned(tmp_path):
    """Passing depth=<int> disables the auto-tuner (the pre-redesign
    contract) even when fetches are slow."""
    hps = _hps(tmp_path, "pin", cache_capacity=16)
    for c in hps.caches.values():
        orig = c.fetch_fn

        def slow(ids, _orig=orig):
            time.sleep(0.01)
            return _orig(ids)

        c.fetch_fn = slow
    rng = np.random.default_rng(8)
    queries = [rng.integers(0, 120, size=(4, 3, 4)).astype(np.int32)
               for _ in range(6)]
    list(hps.lookup_stream(iter(queries), depth=2))
    assert hps.stream_depth_peak == 2


def test_lookup_stream_propagates_errors(tmp_path):
    hps = _hps(tmp_path, "err", cache_capacity=16)
    bad = [np.zeros((2, 2), np.int32)]        # 2-D without hotness
    with pytest.raises(ValueError, match="hotness"):
        list(hps.lookup_stream(bad))


def test_lookup_stream_validates_dims_like_lookup(tmp_path):
    """Mismatched table dims must fail with the same clear error on the
    streamed path as on lookup(), not deep inside the pooled stack."""
    pdb = PersistentDB(str(tmp_path / "pdb_dims"))
    tabs = []
    for name, dim in (("a", 4), ("b", 8)):
        pdb.create_table("m", name, 20, dim,
                         initial=np.zeros((20, dim), np.float32))
        tabs.append(EmbeddingTableConfig(name, 20, dim, hotness=1))
    hps = HPS("m", tabs, pdb)
    cat = np.zeros((2, 2, 1), np.int32)
    with pytest.raises(ValueError, match="equal table dims"):
        hps.lookup(cat)
    with pytest.raises(ValueError, match="equal table dims"):
        list(hps.lookup_stream([cat]))


def test_hps_close_releases_and_recreates_workers(tmp_path):
    hps = _hps(tmp_path, "close", cache_capacity=24)
    rng = np.random.default_rng(30)
    cat = rng.integers(-1, 120, size=(4, 3, 4)).astype(np.int32)
    a = np.asarray(hps.lookup(cat, pipelined=True))
    hps.close()
    hps.close()                               # idempotent
    b = np.asarray(hps.lookup(cat, pipelined=True))   # workers recreated
    np.testing.assert_array_equal(a, b)       # second pass: all hits


def test_pipelined_sharded_combined(tmp_path):
    """The full tentpole stack at once: striped payload + pipelined
    two-stage lookup, against the plain sequential single-payload HPS."""
    h_base = _hps(tmp_path, "base", cache_capacity=24)
    h_full = _hps(tmp_path, "full", cache_capacity=24, cache_shards=3)
    rng = np.random.default_rng(23)
    for _ in range(8):
        cat = rng.integers(-1, 120, size=(6, 3, 4)).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(h_base.lookup(cat, pipelined=False)),
            np.asarray(h_full.lookup(cat, pipelined=True)))


# ---------------------------------------------------------------------------
# serve-loop-driven refresh (no bare timer thread)
# ---------------------------------------------------------------------------

def test_server_loop_drives_refresh(tmp_path):
    from repro.core.hps.message_bus import MessageBus, Producer

    bus = MessageBus()
    hps = _hps(tmp_path, "srv", n_tables=2, cache_capacity=16, bus=bus)

    class _Model:
        def apply_dense(self, p, d, e, w):
            return e.sum(axis=(1, 2))

    from repro.serve.server import InferenceServer
    server = InferenceServer(_Model(), {}, hps, refresh_budget=8)
    cat = np.asarray([[[5, -1, -1, -1], [6, -1, -1, -1]]], np.int32)
    before = server.predict(np.zeros((1, 1), np.float32), cat)

    prod = Producer(bus, "m")
    prod.send("t0", np.asarray([5]), np.full((1, 8), 42.0, np.float32))
    prod.flush()
    server.start()
    try:
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if server.updates_applied and server.rows_refreshed:
                break
            time.sleep(0.05)
    finally:
        server.stop()
    assert server.updates_applied >= 1        # bus polled by the loop
    assert server.rows_refreshed >= 1         # dirty row drained by loop
    after = server.predict(np.zeros((1, 1), np.float32), cat)
    assert not np.allclose(before, after)     # update reached serving


# ---------------------------------------------------------------------------
# refresh / stream / update concurrency stress
# ---------------------------------------------------------------------------

def test_refresh_concurrent_with_stream_under_update_hammer(tmp_path):
    """``refresh_chunk`` driven concurrently with ``lookup_stream``
    while a third thread hammers ``apply_updates``: no deadlock, and
    every materialized row binds a CONSISTENT id->slot view — each
    returned row is exactly one published version of exactly the id
    that was queried (value = id + version*VSTEP, constant across the
    row), never a torn row and never another id's slot."""
    import threading
    from repro.core.hps.message_bus import MessageBus, Producer

    vocab, dim, T, VSTEP = 64, 8, 2, 100000.0
    bus = MessageBus()
    pdb = PersistentDB(str(tmp_path / "pdb_stress"))
    tabs = []
    for t in range(T):
        init = np.repeat(np.arange(vocab, dtype=np.float32)[:, None],
                         dim, axis=1)           # version 0: value == id
        pdb.create_table("m", f"t{t}", vocab, dim, initial=init)
        tabs.append(EmbeddingTableConfig(f"t{t}", vocab, dim, hotness=1))
    hps = HPS("m", tabs, pdb, cache_capacity=32, bus=bus)
    from repro.analysis import LockOrderRecorder
    rec = LockOrderRecorder()
    rec.instrument_hps(hps)         # record every lock the hammer takes
    stop = threading.Event()
    failures = []

    def updater():
        try:
            prod = Producer(bus, "m")
            rng = np.random.default_rng(5)
            v = 0
            while not stop.is_set():
                v = (v % 99) + 1                # keep values f32-exact
                ids = np.unique(rng.integers(0, vocab, size=8))
                rows = np.broadcast_to(
                    ids.astype(np.float32)[:, None] + v * VSTEP,
                    (len(ids), dim)).copy()
                for t in range(T):
                    prod.send(f"t{t}", ids, rows)
                prod.flush()
                hps.apply_updates()             # L2/L3 writes + marks
        except Exception as e:                  # pragma: no cover
            failures.append(e)

    def refresher():
        try:
            while not stop.is_set():
                hps.refresh_step(budget=8)
                hps.schedule_refresh()          # keep the backlog alive
        except Exception as e:                  # pragma: no cover
            failures.append(e)

    threads = [threading.Thread(target=updater, daemon=True),
               threading.Thread(target=refresher, daemon=True)]
    for t in threads:
        t.start()
    rng = np.random.default_rng(7)
    queries = [rng.integers(0, vocab, size=(6, T, 1)).astype(np.int32)
               for _ in range(50)]
    try:
        for q, out in zip(queries, hps.lookup_stream(iter(queries))):
            out = np.asarray(out)
            for b in range(q.shape[0]):
                for t in range(T):
                    row = out[b, t]
                    assert np.all(row == row[0]), f"torn row: {row}"
                    assert row[0] % VSTEP == q[b, t, 0], \
                        f"wrong id's slot: {row[0]} for id {q[b, t, 0]}"
                    assert 0 <= row[0] // VSTEP <= 99
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "deadlocked threads"
    assert not failures, failures
    # the OBSERVED global lock-acquisition graph must be a DAG: the
    # stream/refresh/update hammer really contended (edges exist), and
    # no two threads ever ordered any pair of locks both ways
    assert rec.edges(), "hammer never held two locks at once"
    rec.assert_acyclic()
    hps.close()
