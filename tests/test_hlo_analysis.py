"""HLO analyzer: unit tests on hand-written HLO snippets + a consistency
check against a real lowered program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def test_type_bytes():
    assert ha.type_bytes("f32[4,8]") == 128
    assert ha.type_bytes("bf16[10]") == 20
    assert ha.type_bytes("pred[3]") == 3
    assert ha.type_bytes("(f32[2], s32[4])") == 24
    assert ha.type_bytes("token[]") == 0


HLO_DOT = """
ENTRY %main (a: f32[8,16], b: f32[16,32]) -> f32[8,32] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,32] parameter(1)
  ROOT %dot.1 = f32[8,32] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_flops():
    st = ha.HloAnalyzer(HLO_DOT).analyze()
    assert st.flops == 2 * 16 * 8 * 32
    # memory: read a (512B) + b (2048B) + write out (1024B)
    assert st.mem_bytes == 512 + 2048 + 1024


HLO_WHILE = """
%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64] get-tuple-element(%p), index=1
  %y = f32[64] multiply(%x, %x)
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64]) tuple(%zero, %x)
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[64] get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies():
    st = ha.HloAnalyzer(HLO_WHILE).analyze()
    # multiply: 64 flops, add: 1 flop, per iteration × 7
    assert st.flops == 7 * 65
    assert st.unknown_trip_counts == 0


def test_while_trip_count_from_condition_constant():
    hlo = HLO_WHILE.replace(
        ', backend_config={"known_trip_count":{"n":"7"}}', "")
    st = ha.HloAnalyzer(hlo).analyze()
    assert st.flops == 7 * 65          # parsed from %n = constant(7)


HLO_COLL = """
ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024] parameter(0)
  %ar = f32[1024] all-reduce(%x), replica_groups={}, to_apply=%sum
  %ag = f32[1024] all-gather(%ar), dimensions={0}
  ROOT %out = f32[1024] add(%ar, %ag)
}
"""


def test_collective_bytes_by_kind():
    st = ha.HloAnalyzer(HLO_COLL).analyze()
    assert st.coll_by_kind["all-reduce"] == 4096
    assert st.coll_by_kind["all-gather"] == 4096    # result bytes
    assert st.coll_bytes == 8192


def test_real_program_consistency():
    """Analyzer FLOPs on a simple jit matmul ~= the analytic count."""
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    st = ha.HloAnalyzer(hlo).analyze()
    analytic = 2 * 128 * 256 * 64
    assert analytic <= st.flops <= analytic * 1.2


def test_scan_trip_count_on_real_program():
    """A lax.scan over 11 steps must multiply the body tally 11x."""
    def f(x):
        def body(c, _):
            return c @ w, ()
        w = jnp.eye(32)
        out, _ = jax.lax.scan(body, x, None, length=11)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    hlo = jax.jit(f).lower(x).compile().as_text()
    st = ha.HloAnalyzer(hlo).analyze()
    per_iter = 2 * 32 * 32 * 32
    assert st.flops >= 11 * per_iter
    assert st.flops < 11 * per_iter * 1.5
    assert st.unknown_trip_counts == 0


def test_roofline_terms_dominance():
    st = ha.Stats(flops=197e12, mem_bytes=1.0, coll_bytes=1.0)
    t = ha.roofline_terms(st)
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    st = ha.Stats(flops=1.0, mem_bytes=819e9 * 2, coll_bytes=1.0)
    assert ha.roofline_terms(st)["dominant"] == "memory"
    st = ha.Stats(flops=1.0, mem_bytes=1.0, coll_bytes=50e9 * 3)
    t = ha.roofline_terms(st)
    assert t["dominant"] == "collective"
    assert t["step_s_lower_bound"] == pytest.approx(3.0)
