"""Fault-tolerant trainer: checkpoint/restart, failure injection with
replay determinism, straggler accounting, and elastic (N -> M shard)
restore of embedding tables."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.train.trainer import Trainer


def _setup(tmp_path, ckpt_interval=2, batch=16):
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    model = RecsysModel(cfg, mesh, global_batch=batch)
    data = SyntheticCTR(cfg, batch)
    tcfg = TrainConfig(learning_rate=1e-2)
    tr = Trainer(model, tcfg, mesh, data.batch,
                 ckpt_dir=str(tmp_path / "ckpt"),
                 ckpt_interval=ckpt_interval)
    return cfg, mesh, model, tr


def test_loss_decreases(tmp_path):
    cfg, mesh, model, tr = _setup(tmp_path)
    with mesh:
        out = tr.train(30)
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_restart_resumes_from_checkpoint(tmp_path):
    cfg, mesh, model, tr = _setup(tmp_path)
    with mesh:
        out1 = tr.train(6)
    # fresh trainer, same dir -> resumes (history starts past step 5)
    cfg, mesh, model2, tr2 = _setup(tmp_path)
    with mesh:
        out2 = tr2.train(10)
    steps2 = [h["step"] for h in out2["history"]]
    assert steps2[0] == 6          # resumed, not restarted
    assert steps2[-1] == 9


def test_failure_injection_recovers_and_replays(tmp_path):
    cfg, mesh, model, tr = _setup(tmp_path, ckpt_interval=3)
    fails = {"armed": True}

    def inject(step):
        if step == 7 and fails["armed"]:
            fails["armed"] = False
            raise RuntimeError("injected node failure")

    tr.failure_injector = inject
    with mesh:
        out = tr.train(12)
    steps = [h["step"] for h in out["history"]]
    # step 7 appears exactly once in the final history *after* recovery
    assert steps.count(7) >= 1
    assert steps[-1] == 11
    # deterministic replay: rerunning from scratch with no failure gives
    # the same final loss (stateless data pipeline => same batches)
    cfg, mesh, model3, tr3 = _setup(tmp_path, ckpt_interval=3)
    import shutil
    shutil.rmtree(tr3.ckpt_dir)
    with mesh:
        out_clean = tr3.train(12)
    np.testing.assert_allclose(out["history"][-1]["loss"],
                               out_clean["history"][-1]["loss"], rtol=1e-4)


def test_straggler_accounting():
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["wdl-criteo"])
    mesh = make_test_mesh((1, 1))
    model = RecsysModel(cfg, mesh, global_batch=8)
    data = SyntheticCTR(cfg, 8)
    tr = Trainer(model, TrainConfig(), mesh, data.batch)
    tr.step_times = [0.01] * 10
    tr._watch_stragglers(0.5)      # 50x median
    assert tr.stragglers == 1
    tr._watch_stragglers(0.011)
    assert tr.stragglers == 1


def test_elastic_reshard_roundtrip():
    """Embedding checkpoints written logically restore onto another mesh
    size with identical lookup semantics (subprocess provides 8 devices)."""
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    body = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import DISTRIBUTED, HYBRID, EmbeddingTableConfig
from repro.core.embedding import EmbeddingCollection
from repro.launch.mesh import make_test_mesh

tabs = [EmbeddingTableConfig("a", 100, 8, hotness=2, strategy=DISTRIBUTED,
                             hot_fraction=0.2),
        EmbeddingTableConfig("b", 64, 8, hotness=2, strategy=HYBRID,
                             hot_fraction=0.2)]
ids = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 2), -1, 64)

mesh8 = make_test_mesh((4, 2))
with mesh8:
    c8 = EmbeddingCollection(tabs, mesh8, comm="all_to_all",
                             capacity_factor=4.0)
    p8 = c8.init(jax.random.PRNGKey(0))
    want = np.asarray(c8.lookup_reference(p8, ids))
    logical = {k: np.asarray(v) for k, v in c8.export_logical(p8).items()}

mesh2 = make_test_mesh((2, 1))
with mesh2:
    c2 = EmbeddingCollection(tabs, mesh2, comm="all_to_all",
                             capacity_factor=4.0)
    p2 = c2.import_logical({k: jnp.asarray(v) for k, v in logical.items()})
    got = np.asarray(jax.jit(c2.lookup)(p2, ids))
np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
