"""Regression guard: every ``repro.*`` module must import on the
*installed* JAX. The seed shipped call sites against ``jax.sharding.
AxisType`` / ``jax.shard_map`` that do not exist in JAX 0.4.x, so tier-1
collection died with an ImportError before a single test ran; anything
version-sensitive now goes through ``repro.compat`` (see its docstring
for the policy), and this test fails the moment a new module regresses."""
import importlib
import pkgutil

import pytest

import repro


def _all_modules():
    mods = ["repro"]
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        mods.append(info.name)
    return mods


@pytest.mark.parametrize("mod", _all_modules())
def test_module_imports(mod):
    importlib.import_module(mod)


def test_compat_surface():
    from repro import compat
    assert callable(compat.shard_map)
    assert callable(compat.make_mesh)
    assert hasattr(compat.AxisType, "Auto")
