"""The hot-path sanitizer (SYNC001/SYNC002) and the serving contract it
exists to pin: after warmup the stream serve engine performs exactly ONE
device->host sync per delivered request group and ZERO recompiles — for
a canonical recipe (dlrm) and a novel graph arch (twotower) — while the
no-overlap ``stage_sync`` reference engine, by construction, syncs far
more (the positive control proving the monitor actually measures)."""
import ast
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import HotPathMonitor, active_monitor
from repro.api import Solver
from repro.data.synthetic import SyntheticCTR
from repro.serve.server import InferenceServer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# hook mechanics: zero overhead when disarmed, exact restore, no nesting
# ---------------------------------------------------------------------------

def test_hooks_are_noops_when_disarmed():
    orig_asarray = np.asarray
    orig_block = jax.block_until_ready
    assert active_monitor() is None
    assert not hasattr(orig_asarray, "_hotpath_orig")
    with HotPathMonitor() as mon:
        assert active_monitor() is mon
        assert np.asarray is not orig_asarray
        assert jax.block_until_ready is not orig_block
    # restored to the SAME function objects: disarmed cost is zero
    assert np.asarray is orig_asarray
    assert jax.block_until_ready is orig_block
    assert active_monitor() is None


def test_monitor_does_not_nest():
    with HotPathMonitor():
        with pytest.raises(RuntimeError, match="does not nest"):
            HotPathMonitor().__enter__()
    assert active_monitor() is None


def test_counts_d2h_only_for_device_values():
    x = jnp.arange(4.0)
    host = np.ones(4)
    with HotPathMonitor() as mon:
        np.asarray(host)               # host->host: free, not counted
        np.asarray(x)                  # device->host: counted
        np.array(x)                    # counted (the other entry point)
    evs = mon.events()
    assert [e.kind for e in evs] == ["d2h", "d2h"]
    assert {e.via for e in evs} == {"numpy.asarray", "numpy.array"}


def test_counts_blocking_sync():
    x = jnp.arange(4.0)
    with HotPathMonitor() as mon:
        jax.block_until_ready(x)
    assert mon.summary()["block"] == 1 and mon.summary()["d2h"] == 0


def test_counts_fresh_compiles_not_cache_hits():
    f = jax.jit(lambda v: v * 2.0 + 1.0)
    x = jnp.arange(8.0)
    with HotPathMonitor() as warm:
        np.asarray(f(x))
    assert warm.compiles >= 1          # fresh lowering happened armed
    with HotPathMonitor() as again:
        np.asarray(f(x))               # same shape: jit cache hit
    assert again.compiles == 0
    assert again.sync_count == 1


def test_hidden_sync_fixture_leaky_vs_clean():
    spec = importlib.util.spec_from_file_location(
        "bad_hidden_sync",
        os.path.join(ROOT, "tests", "analysis_fixtures",
                     "bad_hidden_sync.py"))
    fx = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(fx)
    fx.leaky_pipeline(1)               # warm both jit paths unarmed
    fx.clean_pipeline(1)
    with HotPathMonitor() as leaky:
        fx.leaky_pipeline(3)
    with HotPathMonitor() as clean:
        fx.clean_pipeline(3)
    assert leaky.sync_count == 3       # one hidden d2h per step
    assert clean.sync_count == 1       # the one final materialization


# ---------------------------------------------------------------------------
# the serving contract
# ---------------------------------------------------------------------------

def _build(arch):
    import importlib
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_"))
    m = mod.build_model(smoke=True,
                        solver=Solver(batch_size=16, lr=1e-2))
    m.compile()
    m.fit(steps=2)
    return m


@pytest.fixture(scope="module",
                params=["dlrm-criteo", "twotower-criteo"])
def served(request, tmp_path_factory):
    """A deployed stream-engine server for a canonical recipe AND a
    novel graph arch — the pipeline contract must hold for both."""
    m = _build(request.param)
    dep = str(tmp_path_factory.mktemp("san_" + request.param))
    server = m.deploy(dep, cache_capacity=256, max_batch=8)
    assert server.engine == "stream"
    return m, server


def test_stream_engine_one_sync_per_group_zero_recompiles(served):
    m, server = served
    rows, warm_rounds, k = 8, 3, 5
    server.start()
    try:
        for i in range(warm_rounds):   # warm jit + L1 over the loop path
            d = SyntheticCTR(m.cfg, rows, seed=500 + i).batch(i)
            server.submit(d["dense"], d["cat"]).get(timeout=120)
        server.reset_latencies()
        with HotPathMonitor("stream") as mon:
            for i in range(k):
                d = SyntheticCTR(m.cfg, rows, seed=900 + i).batch(i)
                out = server.submit(d["dense"], d["cat"]).get(timeout=120)
                assert not isinstance(out, Exception)
    finally:
        server.stop()
    assert server.counters()["groups_served"] == k
    summ = mon.summary()
    assert summ["syncs"] == k, summ    # ONE host sync per group
    assert summ["compiles"] == 0, summ  # ZERO post-warmup recompiles


def test_admission_control_preserves_hotpath_contract(served):
    """Arming the admission controller (bounded queue + declared SLO +
    deadline batching) must not change the hot path: the batch-cut
    decision is pure host arithmetic, so the one-sync-per-group /
    zero-recompile contract holds with admission ON. Request rows are
    pinned to ``max_batch`` so every delivered group keeps one shape."""
    m, server = served
    ctl = InferenceServer(m.model, m.dense_params(), server.hps,
                          wide_hps=server.wide_hps, max_batch=8,
                          engine="stream", queue_depth=64,
                          slo_ms=10_000.0, deadline_batching=True)
    rows, k = 8, 5
    ctl.start()
    try:
        for i in range(3):             # warm THIS server's jit wrappers
            d = SyntheticCTR(m.cfg, rows, seed=600 + i).batch(i)
            out = ctl.submit(d["dense"], d["cat"]).get(timeout=120)
            assert not isinstance(out, Exception)
        ctl.reset_serving_stats()
        with HotPathMonitor("stream+admission") as mon:
            for i in range(k):
                d = SyntheticCTR(m.cfg, rows, seed=950 + i).batch(i)
                out = ctl.submit(d["dense"], d["cat"]).get(timeout=120)
                assert not isinstance(out, Exception)
    finally:
        ctl.stop()
    c = ctl.counters()
    assert c["groups_served"] == k and c["requests_delivered"] == k
    assert c["requests_shed"] == 0 and c["requests_expired"] == 0
    summ = mon.summary()
    assert summ["syncs"] == k, summ     # ONE host sync per group
    assert summ["compiles"] == 0, summ  # ZERO recompiles, admission on


def test_stage_sync_reference_syncs_more(served):
    """Positive control: the no-overlap engine blocks every device
    stage, so the monitor must see MANY more syncs than groups — proof
    the one-sync result above is measurement, not a dead monitor."""
    m, server = served
    ref = InferenceServer(m.model, m.dense_params(), server.hps,
                          wide_hps=server.wide_hps, max_batch=8,
                          engine="stage_sync")
    k, rows = 3, 8
    d = SyntheticCTR(m.cfg, rows, seed=77)
    ref._predict_stage_sync(d.batch(0)["dense"], d.batch(0)["cat"])
    with HotPathMonitor("stage_sync") as mon:
        for i in range(1, k + 1):
            ref._predict_stage_sync(d.batch(i)["dense"],
                                    d.batch(i)["cat"])
    assert mon.sync_count > k          # per-table blocks + final asarray


def test_benchmark_arms_run_uninstrumented():
    """The speedup benchmark's timed arms must not import the sanitizer:
    monitoring overhead is opt-in and never taxes reported numbers."""
    path = os.path.join(ROOT, "benchmarks", "hps_speedup.py")
    with open(path) as f:
        tree = ast.parse(f.read())
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            assert not any(a.name.startswith("repro.analysis")
                           for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            assert not (node.module or "").startswith("repro.analysis")
