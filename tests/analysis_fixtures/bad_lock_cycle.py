"""Fixture: AB-BA lock order between two classes (LOCK003)."""
import threading


class Left:

    _GUARDED_BY = {"value": "_lock"}

    def __init__(self, peer: "Right"):
        self._lock = threading.Lock()
        self.peer = peer
        self.value = 0

    def receive(self, v: int) -> None:
        with self._lock:
            self.value = v

    def push(self) -> None:
        with self._lock:            # Left._lock -> Right._lock
            self.peer.receive(self.value)


class Right:

    _GUARDED_BY = {"value": "_lock"}

    def __init__(self, peer: Left):
        self._lock = threading.Lock()
        self.peer = peer
        self.value = 0

    def receive(self, v: int) -> None:
        with self._lock:
            self.value = v

    def push(self) -> None:
        with self._lock:            # Right._lock -> Left._lock: cycle
            self.peer.receive(self.value)
