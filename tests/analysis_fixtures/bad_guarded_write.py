"""Fixture: guarded attribute touched outside the lock (LOCK001 x2)."""
import threading


class Counter:

    _GUARDED_BY = {"count": "_lock", "total": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0

    def bump(self, n: int) -> None:
        with self._lock:
            self.count += 1
        self.total += n          # LOCK001: write outside the lock

    def peek(self) -> int:
        return self.count        # LOCK001: read outside the lock
