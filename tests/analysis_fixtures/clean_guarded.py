"""Fixture: lock discipline done right — trips NO rule.

Covers the idioms the lint must accept: guarded access under ``with``,
``*_locked`` helpers called with the lock held, blocking work done
between lock scopes, an inline waiver, and init-time writes."""
import threading

import numpy as np


class CleanCache:

    _GUARDED_BY = {"rows": "_lock", "hits": "_lock"}

    def __init__(self, fetch_fn):
        self._lock = threading.Lock()
        self.fetch_fn = fetch_fn
        self.rows = {}
        self.hits = 0          # __init__ writes are exempt

    def _lookup_locked(self, key):
        return self.rows.get(key)

    def get(self, key):
        with self._lock:
            hit = self._lookup_locked(key)
            if hit is not None:
                self.hits += 1
                return hit
        fresh = self.fetch_fn([key])          # blocking IO: lock released
        with self._lock:
            self.rows[key] = fresh[0]
            return fresh[0]

    def prefetch(self, key):
        with self._lock:
            # lock-ok: LOCK002 startup-only path, contention accepted
            self.rows[key] = self.fetch_fn([key])[0]

    def snapshot(self):
        with self._lock:
            return np.asarray(list(self.rows.values()))
