"""Fixture: ``*_locked`` helper called without the lock (LOCK004)."""
import threading


class Index:

    _GUARDED_BY = {"entries": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self.entries = {}

    def _find_locked(self, key):
        return self.entries.get(key)

    def get(self, key):
        with self._lock:
            return self._find_locked(key)

    def get_fast(self, key):
        return self._find_locked(key)   # LOCK004: lock not held
