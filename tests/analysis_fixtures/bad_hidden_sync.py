"""Fixture for the RUNTIME sanitizer: a pipeline that materializes a
device value mid-loop (leaky) next to one that stays on device (clean).
``tests/test_hotpath_sanitizer.py`` runs both under a
:class:`~repro.analysis.HotPathMonitor` and asserts only the leaky one
trips SYNC001."""
import jax.numpy as jnp
import numpy as np


def leaky_pipeline(steps: int = 3):
    x = jnp.arange(8.0)
    total = 0.0
    for _ in range(steps):
        x = x * 2.0
        total += float(np.asarray(x).sum())   # hidden d2h each step
    return total


def clean_pipeline(steps: int = 3):
    x = jnp.arange(8.0)
    for _ in range(steps):
        x = x * 2.0
    return np.asarray(x).sum()                # ONE d2h at the end
