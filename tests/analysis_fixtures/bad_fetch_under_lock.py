"""Fixture: blocking work under a lock (LOCK002 x3)."""
import time
import threading

import numpy as np


class SlowCache:

    _GUARDED_BY = {"rows": "_lock"}
    _LOCKS_OF = {"fetch_fn": ("Store._lock",)}

    def __init__(self, fetch_fn, store):
        self._lock = threading.Lock()
        self.fetch_fn = fetch_fn
        self.store = store
        self.rows = {}

    def refill(self, ids):
        with self._lock:
            fresh = self.fetch_fn(ids)          # LOCK002: fetch held
            time.sleep(0.01)                    # LOCK002: sleep held
            self.rows = dict(zip(ids, fresh))

    def snapshot_host(self):
        with self._lock:
            # LOCK002: host materialization of a device value while the
            # lock is held (np.asarray over a device-producing call)
            return np.asarray(self.store.snapshot())


class Store:

    def __init__(self):
        self._lock = threading.Lock()

    def snapshot(self):
        return None
