"""The load-generation layer: workload determinism (same seed => the
same bit-exact request stream), trace record/replay, hot-set drift, and
the bounded-memory serving metrics (mergeable log-bucketed latency
histogram + windowed delivered-rate series)."""
from collections import Counter

import numpy as np
import pytest

from repro.loadgen import (LatencyHistogram, ModelShape, WindowedRate,
                           Workload, WorkloadConfig, record_trace,
                           replay_trace)

SHAPE = ModelShape(vocab_sizes=(4000, 600), hotness=(4, 1), num_dense=3)


def _stream(cfg, shapes=None):
    return list(Workload(cfg, shapes or {"m": SHAPE}))


# ---------------------------------------------------------------------------
# workload determinism
# ---------------------------------------------------------------------------

def test_same_seed_identical_stream():
    cfg = WorkloadConfig(qps=200, duration_s=1.0, rows=4, seed=3)
    a, b = _stream(cfg), _stream(cfg)
    assert len(a) == len(b) > 50
    for ra, rb in zip(a, b):
        assert ra.t == rb.t
        assert ra.model == rb.model
        assert ra.dense.dtype == np.float32 and ra.cat.dtype == np.int32
        np.testing.assert_array_equal(ra.dense, rb.dense)
        np.testing.assert_array_equal(ra.cat, rb.cat)


def test_different_seed_different_stream():
    mk = lambda s: WorkloadConfig(qps=200, duration_s=1.0, seed=s)
    a, b = _stream(mk(0)), _stream(mk(1))
    assert [r.t for r in a] != [r.t for r in b]


def test_arrivals_monotone_and_bounded():
    for arrival in ("poisson", "constant"):
        cfg = WorkloadConfig(qps=100, duration_s=2.0, arrival=arrival)
        ts = [r.t for r in _stream(cfg)]
        assert ts == sorted(ts)
        assert all(0 < t <= cfg.duration_s for t in ts)
        # offered rate lands near the target (exactly, for constant)
        assert len(ts) == pytest.approx(200, rel=0.3)


def test_request_shapes_and_padding():
    cfg = WorkloadConfig(qps=50, duration_s=0.5, rows=6)
    for r in _stream(cfg):
        assert r.dense.shape == (6, SHAPE.num_dense)
        assert r.cat.shape == (6, SHAPE.num_tables, SHAPE.max_hot)
        # table 1 has hotness 1: the rest of its slots are -1 padded
        assert (r.cat[:, 1, 1:] == -1).all()
        assert (r.cat[:, 0, :] >= 0).all()
        assert (r.cat[:, 0, :] < SHAPE.vocab_sizes[0]).all()


def test_mix_routes_by_weight():
    shapes = {"a": SHAPE, "b": SHAPE}
    cfg = WorkloadConfig(qps=2000, duration_s=1.0, rows=1, seed=5,
                         mix={"a": 3.0, "b": 1.0})
    counts = Counter(r.model for r in _stream(cfg, shapes))
    assert counts["a"] / counts["b"] == pytest.approx(3.0, rel=0.25)


def test_config_validation():
    with pytest.raises(ValueError, match="arrival"):
        WorkloadConfig(qps=1, duration_s=1, arrival="burst")
    with pytest.raises(ValueError, match="zipf_a"):
        WorkloadConfig(qps=1, duration_s=1, zipf_a=1.0)
    with pytest.raises(ValueError, match="positive"):
        WorkloadConfig(qps=0, duration_s=1)
    with pytest.raises(ValueError, match="unknown models"):
        Workload(WorkloadConfig(qps=1, duration_s=1, mix={"nope": 1.0}),
                 {"m": SHAPE})


# ---------------------------------------------------------------------------
# hot-set drift
# ---------------------------------------------------------------------------

def _hot_ids(reqs, top=20):
    """The top-N most frequent ids of table 0 across a request window."""
    c = Counter()
    for r in reqs:
        c.update(int(x) for x in r.cat[:, 0, :].ravel())
    return {i for i, _ in c.most_common(top)}


@pytest.mark.parametrize("drift,max_overlap,min_overlap", [
    (0.0, 1.0, 0.5),      # stationary: early and late hot sets agree
    (0.4, 0.25, 0.0),     # drifting: the late hot set has moved on
])
def test_drift_moves_hot_set(drift, max_overlap, min_overlap):
    cfg = WorkloadConfig(qps=150, duration_s=2.0, rows=8, seed=11,
                         arrival="constant", zipf_a=1.5,
                         drift_per_s=drift)
    reqs = _stream(cfg)
    early = _hot_ids([r for r in reqs if r.t < 0.3])
    late = _hot_ids([r for r in reqs if r.t > cfg.duration_s - 0.3])
    overlap = len(early & late) / len(early | late)
    assert min_overlap <= overlap <= max_overlap, overlap


def test_drift_preserves_id_range():
    cfg = WorkloadConfig(qps=100, duration_s=1.0, drift_per_s=0.9)
    for r in _stream(cfg):
        assert (r.cat[:, 0, :] >= 0).all()
        assert (r.cat[:, 0, :] < SHAPE.vocab_sizes[0]).all()


# ---------------------------------------------------------------------------
# trace record / replay
# ---------------------------------------------------------------------------

def test_trace_roundtrip_bit_exact(tmp_path):
    cfg = WorkloadConfig(qps=100, duration_s=0.5, rows=3, seed=9,
                         mix=None)
    path = str(tmp_path / "trace.jsonl")
    orig = _stream(cfg)
    n = record_trace(path, orig)
    back = list(replay_trace(path))
    assert n == len(orig) == len(back)
    for a, b in zip(orig, back):
        assert a.t == b.t and a.model == b.model
        assert b.dense.dtype == np.float32 and b.cat.dtype == np.int32
        np.testing.assert_array_equal(a.dense, b.dense)
        np.testing.assert_array_equal(a.cat, b.cat)


def test_trace_rejects_foreign_file(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write('{"format": "something-else"}\n')
    with pytest.raises(ValueError, match="repro-loadtrace-v1"):
        list(replay_trace(path))


# ---------------------------------------------------------------------------
# latency histogram
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_bucket_error():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=2.0, sigma=0.8, size=20_000)
    h = LatencyHistogram()
    for s in samples:
        h.record(float(s))
    assert h.count == len(samples)
    assert h.mean == pytest.approx(float(samples.mean()), rel=1e-9)
    for q in (50, 95, 99, 99.9):
        want = float(np.percentile(samples, q))
        # bucket width is ~2% relative: allow a few buckets of slack
        assert h.percentile(q) == pytest.approx(want, rel=0.05)


def test_histogram_merge_equals_combined():
    rng = np.random.default_rng(1)
    a_ms, b_ms = rng.exponential(5.0, 500), rng.exponential(40.0, 500)
    ha, hb, hall = (LatencyHistogram() for _ in range(3))
    for v in a_ms:
        ha.record(float(v))
        hall.record(float(v))
    for v in b_ms:
        hb.record(float(v))
        hall.record(float(v))
    merged = ha.snapshot().merge(hb)
    np.testing.assert_array_equal(merged.counts, hall.counts)
    assert merged.sum_ms == pytest.approx(hall.sum_ms)
    assert merged.percentile(99) == hall.percentile(99)
    # snapshot().merge left the original untouched
    assert ha.count == 500


def test_histogram_merge_rejects_layout_mismatch():
    with pytest.raises(ValueError, match="bucket layouts"):
        LatencyHistogram().merge(LatencyHistogram(growth=1.1))


def test_histogram_dict_roundtrip_exact():
    h = LatencyHistogram()
    for v in (0.0005, 0.1, 3.0, 250.0, 1e7):   # under/over-flow included
        h.record(v)
    back = LatencyHistogram.from_dict(h.to_dict())
    np.testing.assert_array_equal(back.counts, h.counts)
    assert back.sum_ms == h.sum_ms
    assert back.summary() == h.summary()


def test_histogram_empty_and_reset():
    h = LatencyHistogram()
    assert h.percentile(99) == 0.0 and h.mean == 0.0
    h.record(5.0)
    assert h.count == 1
    h.reset()
    assert h.count == 0 and h.sum_ms == 0.0


# ---------------------------------------------------------------------------
# windowed delivered-rate
# ---------------------------------------------------------------------------

def test_windowed_rate_series_and_peak():
    r = WindowedRate(window_s=1.0)
    for t in (0.1, 0.2, 0.9, 1.5, 3.2, 3.3, 3.4):
        r.record(t)
    assert r.total == 7
    assert r.series() == [(0.0, 3.0), (1.0, 1.0), (3.0, 3.0)]
    assert r.peak() == 3.0


def test_windowed_rate_merge():
    a, b = WindowedRate(), WindowedRate()
    a.record(0.5, n=2)
    b.record(0.7)
    b.record(2.1)
    a.merge(b)
    assert dict(a.series()) == {0.0: 3.0, 2.0: 1.0}
    with pytest.raises(ValueError, match="window"):
        a.merge(WindowedRate(window_s=2.0))
