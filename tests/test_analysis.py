"""The static-analysis subsystem: rule detection on adversarial
fixtures, waivers, the shrink-only baseline, import-graph reachability,
the repo-wide clean gate, and regression tests for the data races the
lock lint surfaced (counter snapshots in the cache / HPS / server)."""
import os
import threading
import time

import numpy as np
import pytest

from repro.analysis import concurrency, deadcode
from repro.analysis.findings import apply_baseline, load_baseline
from repro.analysis.__main__ import main as analysis_main

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "analysis_fixtures")
SRC = os.path.join(ROOT, "src", "repro")


def _lint_fixture(name):
    return concurrency.lint_paths([os.path.join(FIXTURES, name)], ROOT)


# ---------------------------------------------------------------------------
# rule detection on the adversarial fixtures
# ---------------------------------------------------------------------------

def test_guarded_write_without_lock_trips_lock001_only():
    fs = _lint_fixture("bad_guarded_write.py")
    assert [f.rule for f in fs] == ["LOCK001", "LOCK001"]
    assert {f.symbol for f in fs} == {"Counter.bump", "Counter.peek"}
    assert not any(f.waived for f in fs)


def test_fetch_under_lock_trips_lock002_only():
    fs = _lint_fixture("bad_fetch_under_lock.py")
    assert [f.rule for f in fs] == ["LOCK002"] * 3
    msgs = " | ".join(f.message for f in fs)
    assert "fetch_fn" in msgs and "time.sleep" in msgs
    assert "device->host" in msgs          # the np.asarray(snapshot())


def test_lock_order_cycle_trips_lock003_only():
    fs = _lint_fixture("bad_lock_cycle.py")
    assert fs and all(f.rule == "LOCK003" for f in fs)
    assert any("cycle" in f.message for f in fs)


def test_locked_suffix_call_without_lock_trips_lock004_only():
    fs = _lint_fixture("bad_locked_call.py")
    assert [f.rule for f in fs] == ["LOCK004"]
    assert fs[0].symbol == "Index.get_fast"


def test_clean_fixture_trips_nothing():
    fs = _lint_fixture("clean_guarded.py")
    live = [f for f in fs if not f.waived]
    assert live == []
    # ... and its one intentional site is waived, not missed
    assert [f.rule for f in fs if f.waived] == ["LOCK002"]


# ---------------------------------------------------------------------------
# waivers + baseline
# ---------------------------------------------------------------------------

def _lint_source(tmp_path, src):
    p = tmp_path / "mod.py"
    p.write_text(src)
    return concurrency.lint_paths([str(p)], str(tmp_path))


BAD = """import threading
class C:
    _GUARDED_BY = {"x": "_lock"}
    def __init__(self):
        self._lock = threading.Lock()
        self.x = 0
    def peek(self):
        @ABOVE@
        return self.x@INLINE@
"""


def _bad(line_above="pass", inline=""):
    return BAD.replace("@ABOVE@", line_above).replace("@INLINE@", inline)


def test_waiver_on_offending_line(tmp_path):
    fs = _lint_source(tmp_path, _bad(
        inline="  # lock-ok: LOCK001 test read"))
    assert [f.rule for f in fs] == ["LOCK001"] and fs[0].waived
    assert fs[0].waive_reason == "test read"


def test_waiver_on_line_above(tmp_path):
    fs = _lint_source(tmp_path, _bad(
        line_above="# lock-ok: LOCK001 torn read accepted"))
    assert [f.rule for f in fs] == ["LOCK001"] and fs[0].waived


def test_waiver_wrong_rule_does_not_apply(tmp_path):
    fs = _lint_source(tmp_path, _bad(
        line_above="# lock-ok: LOCK002 wrong rule"))
    assert [f.rule for f in fs] == ["LOCK001"] and not fs[0].waived


def test_baseline_roundtrip_and_staleness(tmp_path):
    base = tmp_path / "baseline.toml"
    base.write_text(
        '# comment\n'
        '[[allow]]\n'
        'rule = "LOCK001"\n'
        'file = "mod.py"\n'
        'reason = "grandfathered"\n'
        '\n'
        '[[allow]]\n'
        'rule = "LOCK004"\n'
        'file = "other.py"\n'
        'line = 12\n')
    entries = load_baseline(str(base))
    assert len(entries) == 2 and entries[1]["line"] == 12

    fs = _lint_source(tmp_path, _bad())
    failing, stale = apply_baseline(fs, entries)
    assert failing == []                   # LOCK001 entry absorbed it
    assert len(stale) == 1                 # the LOCK004 entry is stale
    assert stale[0]["rule"] == "LOCK004"


def test_baseline_rejects_garbage(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text("[[allow]]\nrule = LOCK001\n")   # unquoted value
    with pytest.raises(ValueError):
        load_baseline(str(p))


# ---------------------------------------------------------------------------
# deadcode reachability
# ---------------------------------------------------------------------------

def test_deadcode_on_synthetic_tree(tmp_path):
    src = tmp_path / "src" / "pkg"
    for rel, body in {
        "api.py": "import pkg.used\n",
        "used.py": "x = 1\n",
        "testutil.py": "y = 2\n",
        "orphan.py": "z = 3\n",
        "plugins/alpha.py": "w = 4\n",
        "loader.py": 'NAME = "pkg.plugins." + "alpha"\n',
    }.items():
        p = src / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(body)
    tests_dir = tmp_path / "tests"
    tests_dir.mkdir()
    (tests_dir / "test_it.py").write_text("import pkg.testutil\n")

    rep = deadcode.reachability(str(tmp_path), str(src))
    assert "pkg.api" in rep.runtime and "pkg.used" in rep.runtime
    # loader is NOT a runtime seed (not api/launch/benchmarks) => its
    # prefix edge only matters once something reaches it
    assert rep.test_only == {"pkg.testutil"}
    assert "pkg.orphan" in rep.orphans

    fs = deadcode.lint(str(tmp_path), str(src))
    dead1 = [f for f in fs if f.rule == "DEAD001"]
    assert any("pkg.orphan" in f.message for f in dead1)


def test_deadcode_dynamic_prefix_marks_subpackage(tmp_path):
    src = tmp_path / "src" / "pkg"
    for rel, body in {
        "api.py": 'MOD = "pkg.plugins." + NAME\n',
        "plugins/alpha.py": "w = 4\n",
        "plugins/beta.py": "v = 5\n",
    }.items():
        p = src / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(body)
    rep = deadcode.reachability(str(tmp_path), str(src))
    # the "pkg.plugins." literal in a runtime root marks BOTH plugins
    # reachable, even though pkg/plugins has no __init__.py
    assert {"pkg.plugins.alpha", "pkg.plugins.beta"} <= rep.runtime
    assert rep.orphans == set()


# ---------------------------------------------------------------------------
# the repo itself is clean (the CI gate, exercised in-process)
# ---------------------------------------------------------------------------

def test_repo_lock_lint_is_clean():
    fs = concurrency.lint_tree(SRC, ROOT)
    live = [f.format() for f in fs if not f.waived]
    assert live == []


def test_repo_has_no_orphan_modules():
    fs = deadcode.lint(ROOT, SRC)
    dead1 = [f.format() for f in fs if f.rule == "DEAD001"]
    assert dead1 == []


def test_cli_check_gate_passes():
    assert analysis_main(["--check"]) == 0


def test_guard_contracts_declared_on_serving_classes():
    from repro.core.hps.embedding_cache import DeviceEmbeddingCache
    from repro.core.hps.hps import HPS
    from repro.core.hps.message_bus import MessageBus
    from repro.core.hps.persistent_db import PersistentDB
    from repro.core.hps.volatile_db import VolatileDB
    from repro.serve.server import InferenceServer
    for cls, attr in [(DeviceEmbeddingCache, "_id_of"),
                      (VolatileDB, "_store"),
                      (PersistentDB, "_maps"),
                      (MessageBus, "_topics"),
                      (HPS, "_l3_fetch_calls"),
                      (InferenceServer, "latency_hist"),
                      (InferenceServer, "requests_shed")]:
        assert attr in cls._GUARDED_BY, cls.__name__
    assert "fetch_fn" in DeviceEmbeddingCache._LOCKS_OF


# ---------------------------------------------------------------------------
# dynamic lock-order recorder
# ---------------------------------------------------------------------------

class _TwoLocks:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()


def test_lockorder_recorder_detects_abba_cycle():
    from repro.analysis import LockOrderRecorder
    obj = _TwoLocks()
    rec = LockOrderRecorder()
    rec.wrap(obj, "_a", "A")
    rec.wrap(obj, "_b", "B")
    with obj._a:
        with obj._b:                 # A -> B
            pass
    rec.assert_acyclic()             # one direction only: fine
    with obj._b:
        with obj._a:                 # B -> A: now both ways
            pass
    assert rec.edges() == {("A", "B"), ("B", "A")}
    with pytest.raises(AssertionError, match="lock-order cycle"):
        rec.assert_acyclic()


def test_lockorder_recorder_reentrant_and_idempotent():
    from repro.analysis import LockOrderRecorder
    from repro.analysis.lockorder import _RecordingLock
    obj = _TwoLocks()
    obj._a = threading.RLock()
    rec = LockOrderRecorder()
    w1 = rec.wrap(obj, "_a", "A")
    w2 = rec.wrap(obj, "_a", "A")    # second wrap returns the wrapper
    assert w1 is w2 and isinstance(obj._a, _RecordingLock)
    with obj._a:
        with obj._a:                 # reentrant re-acquire: no edge
            pass
    assert rec.edges() == set()
    rec.assert_acyclic()


# ---------------------------------------------------------------------------
# regression tests for the races the lint surfaced
# ---------------------------------------------------------------------------

def _cache(vocab=300, dim=8, capacity=32):
    from repro.core.hps.embedding_cache import DeviceEmbeddingCache
    store = np.random.default_rng(0).normal(
        size=(vocab, dim)).astype(np.float32)
    return DeviceEmbeddingCache(capacity, dim,
                                fetch_fn=lambda ids: store[ids])


def test_hit_rate_consistent_under_query_hammer():
    c = _cache()
    stop = threading.Event()
    bad = []

    def reader():
        while not stop.is_set():
            hr = c.hit_rate
            if not (0.0 <= hr <= 1.0):
                bad.append(hr)

    t = threading.Thread(target=reader)
    t.start()
    rng = np.random.default_rng(1)
    try:
        for _ in range(60):
            c.query(rng.integers(0, 300, size=16))
    finally:
        stop.set()
        t.join()
    assert bad == []
    snap = c.counters()
    assert snap["hits"] + snap["misses"] >= 60 * 1   # counted under lock


def test_hps_stats_snapshot_under_lookup_hammer(tmp_path):
    from repro.configs.base import EmbeddingTableConfig
    from repro.core.hps.hps import HPS
    from repro.core.hps.persistent_db import PersistentDB

    pdb = PersistentDB(str(tmp_path / "pdb"))
    tabs = []
    for i in range(2):
        rows = np.random.default_rng(i).normal(
            size=(100, 4)).astype(np.float32)
        pdb.create_table("m", f"t{i}", 100, 4, initial=rows)
        tabs.append(EmbeddingTableConfig(f"t{i}", 100, 4, hotness=2))
    hps = HPS("m", tabs, pdb, cache_capacity=16)

    stop = threading.Event()
    errs = []

    def hammer():
        rng = np.random.default_rng(7)
        while not stop.is_set():
            hps.lookup(rng.integers(0, 100, size=(4, 2, 2)))

    t = threading.Thread(target=hammer)
    t.start()
    last = -1
    try:
        for _ in range(40):
            st = hps.stats()
            calls = sum(st["l3_fetches"]["calls"].values())
            if calls < last:               # monotonic counter snapshot
                errs.append((last, calls))
            last = calls
            assert set(st) >= {"l1_hit_rate", "l2_hits", "l3_fetches",
                               "refresh", "stream"}
    finally:
        stop.set()
        t.join()
    hps.close()
    assert errs == []


def test_server_counters_thread_safe():
    from repro.serve.server import InferenceServer

    class _NoModel:
        def apply_dense(self, p, d, e, w):  # never called in this test
            raise AssertionError

    s = InferenceServer(_NoModel(), {}, None, engine="sync")
    n_threads, per = 8, 200

    def writer():
        for _ in range(per):
            s._record_latency(time.perf_counter())

    ts = [threading.Thread(target=writer) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert s.counters()["groups_served"] == n_threads * per
    pct = s.latency_percentiles()
    assert set(pct) == {"p50", "p95", "p99", "p999", "mean"}
    s.reset_latencies()
    assert s.counters()["groups_served"] == 0
    assert s.latency_percentiles() == {}
