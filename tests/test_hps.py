"""Hierarchical Parameter Server: 3-level fall-through, dynamic insertion,
LFU eviction, async refresh, and the Kafka-style online-update path."""
import numpy as np
import pytest

from repro.configs.base import EmbeddingTableConfig
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import Consumer, MessageBus, Producer
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB


def _pdb_with_table(tmp_path, model="m", table="t0", vocab=100, dim=4):
    pdb = PersistentDB(str(tmp_path / "pdb"))
    rows = np.arange(vocab * dim, dtype=np.float32).reshape(vocab, dim)
    pdb.create_table(model, table, vocab, dim, initial=rows)
    return pdb, rows


# ---------------------------------------------------------------------------
# L1 device cache
# ---------------------------------------------------------------------------

def test_l1_hit_miss_and_dynamic_insertion():
    store = np.arange(400, dtype=np.float32).reshape(100, 4)
    fetches = []

    def fetch(ids):
        fetches.append(list(ids))
        return store[ids]

    c = DeviceEmbeddingCache(8, 4, fetch_fn=fetch)
    out = np.asarray(c.query(np.asarray([3, 5, 3])))
    np.testing.assert_allclose(out, store[[3, 5, 3]])
    # miss accounting is per-incoming-id (both 3s miss: insertion happens
    # after the index probe); the duplicate is deduped before the fetch
    assert c.hits == 0 and c.misses == 3
    out2 = np.asarray(c.query(np.asarray([3, 5])))
    np.testing.assert_allclose(out2, store[[3, 5]])
    assert c.hits == 2 and c.misses == 3      # second query: all hits
    assert fetches == [[3, 5]]                # one batched, deduped fetch


def test_l1_lfu_eviction_keeps_hot():
    store = np.arange(400, dtype=np.float32).reshape(100, 4)
    c = DeviceEmbeddingCache(4, 4, fetch_fn=lambda ids: store[ids])
    for _ in range(5):
        c.query(np.asarray([0]))              # id 0 becomes hot
    c.query(np.asarray([1, 2, 3]))            # fill
    c.query(np.asarray([10, 11, 12]))         # force 3 evictions
    assert 0 in c.resident_ids()              # the hot id survived


def test_l1_refresh_propagates_updates():
    store = np.zeros((10, 4), np.float32)
    c = DeviceEmbeddingCache(8, 4, fetch_fn=lambda ids: store[ids])
    c.query(np.asarray([1, 2]))
    store[1] = 9.0                            # lower level updated
    n = c.refresh_once()
    assert n == 2
    np.testing.assert_allclose(np.asarray(c.query(np.asarray([1])))[0], 9.0)
    # refresh itself must not count as queries: 2 misses from the first
    # query, 1 hit from the probe above
    assert c.hits == 1 and c.misses == 2


# ---------------------------------------------------------------------------
# 3-level fall-through
# ---------------------------------------------------------------------------

def test_hps_fallthrough_and_promotion(tmp_path):
    pdb, rows = _pdb_with_table(tmp_path)
    vdb = VolatileDB()
    tabs = [EmbeddingTableConfig("t0", 100, 4)]
    hps = HPS("m", tabs, pdb, vdb=vdb, cache_capacity=16)
    cat = np.asarray([[[3, -1]], [[7, 3]]], np.int32)
    out = np.asarray(hps.lookup(cat))
    np.testing.assert_allclose(out[0, 0], rows[3])
    np.testing.assert_allclose(out[1, 0], rows[7] + rows[3])
    # missed ids were promoted into the VDB, under the model-scoped key
    # (one shared L2 can back several deployed models)
    assert vdb.size("m/t0") == 2
    # second lookup hits L1 entirely
    h0 = hps.caches["t0"].hits
    hps.lookup(cat)
    assert hps.caches["t0"].hits > h0
    assert hps.stats()["l1_hit_rate"]["t0"] > 0


def test_hps_vdb_hit_avoids_pdb(tmp_path):
    pdb, rows = _pdb_with_table(tmp_path)
    vdb = VolatileDB()
    vdb.insert("m/t0", np.asarray([5]), np.ones((1, 4), np.float32) * 123)
    tabs = [EmbeddingTableConfig("t0", 100, 4)]
    hps = HPS("m", tabs, pdb, vdb=vdb, cache_capacity=4)
    out = np.asarray(hps.lookup(np.asarray([[[5]]], np.int32)))
    # VDB value (123) wins over the PDB ground truth — L2 is authoritative
    np.testing.assert_allclose(out[0, 0], 123.0)


# ---------------------------------------------------------------------------
# online updates (Kafka-style)
# ---------------------------------------------------------------------------

def test_online_update_path(tmp_path):
    pdb, rows = _pdb_with_table(tmp_path)
    bus = MessageBus()
    tabs = [EmbeddingTableConfig("t0", 100, 4)]
    hps = HPS("m", tabs, pdb, cache_capacity=16, bus=bus)
    cat = np.asarray([[[7]]], np.int32)
    old = np.asarray(hps.lookup(cat))[0, 0]
    np.testing.assert_allclose(old, rows[7])

    # trainer publishes an update
    prod = Producer(bus, "m")
    prod.send("t0", np.asarray([7]), np.full((1, 4), 55.0, np.float32))
    prod.flush()

    n = hps.apply_updates()
    assert n == 1
    # PDB (ground truth) updated
    np.testing.assert_allclose(pdb.fetch("m", "t0", np.asarray([7]))[0], 55.0)
    # L1 still stale until refresh (poll-based, per the paper)
    np.testing.assert_allclose(np.asarray(hps.lookup(cat))[0, 0], rows[7])
    hps.refresh_caches()
    np.testing.assert_allclose(np.asarray(hps.lookup(cat))[0, 0], 55.0)


def test_producer_batching_and_consumer_offsets():
    bus = MessageBus()
    prod = Producer(bus, "m", max_batch_rows=4)
    for i in range(6):
        prod.send("t0", np.asarray([i]), np.ones((1, 2), np.float32) * i)
    prod.flush()
    cons = Consumer(bus, "m")
    seen = []
    cons.poll(lambda t, ids, rows: seen.extend(ids.tolist()))
    assert sorted(seen) == list(range(6))
    # second poll: nothing new
    again = []
    cons.poll(lambda t, ids, rows: again.extend(ids.tolist()))
    assert again == []


def test_vdb_lru_capacity():
    vdb = VolatileDB(shards=2, capacity_per_shard=2)
    for i in range(8):
        vdb.insert("t", np.asarray([i]), np.ones((1, 2), np.float32))
    assert vdb.size("t") == 4          # 2 shards × 2 capacity
    mask, _ = vdb.query("t", np.asarray([0, 1]))
    assert not mask.any()              # oldest evicted


def test_message_roundtrip_serialization():
    from repro.core.hps.message_bus import _deserialize, _serialize
    ids = np.asarray([1, 99, 12345], np.int64)
    rows = np.random.default_rng(0).normal(size=(3, 16)).astype(np.float32)
    i2, r2 = _deserialize(_serialize(ids, rows))
    np.testing.assert_array_equal(ids, i2)
    np.testing.assert_array_equal(rows, r2)
