"""Admission-controlled serving: the deadline batch-cut decision never
busts the SLO budget, bounded queues shed with a typed rejection
IMMEDIATELY (exact counters), ``close()`` never strands a handle even
under in-flight load, and the two serving locks stay cycle-free under
concurrent submit/stats traffic."""
import queue
import threading
import time

import jax
import numpy as np
import pytest

from repro.analysis import LockOrderRecorder
from repro.configs.base import TrainConfig  # noqa: F401  (registry dep)
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import (InferenceServer, ServerOverloaded,
                                deadline_batch_target,
                                deploy_from_training)


class _NoModel:
    """Stands in where the dense net is never reached: admission-path
    tests never let a request group through to the device."""

    def apply_dense(self, p, d, e, w):
        raise AssertionError("admission test served a request group")


def _req(rows=1):
    return (np.zeros((rows, 2), np.float32),
            np.zeros((rows, 1, 1), np.int32))


@pytest.fixture(scope="module")
def tiny(tmp_path_factory):
    """A real (untrained) dlrm deployment for the tests that must serve
    actual predictions; each test builds its own server from it."""
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=16)
        params = model.init(jax.random.PRNGKey(0))
        pdb = PersistentDB(str(tmp_path_factory.mktemp("pdb")))
        deploy_from_training(model, params, pdb, "m")
        hps = HPS("m", cfg.tables, pdb, cache_capacity=64)
        dense = {k: v for k, v in params.items() if k != "embedding"}
    return cfg, model, dense, hps


# ---------------------------------------------------------------------------
# the deadline batch-cut decision (pure, property-tested)
# ---------------------------------------------------------------------------

def test_deadline_target_never_busts_the_budget():
    """For any (age, slo, max_batch, estimate): the target is in
    [1, max_batch], and either it is the floor 1 (ship the oldest
    request now) or the predicted completion fits the SLO."""
    rng = np.random.default_rng(0)
    for _ in range(1000):
        slo = float(rng.uniform(1.0, 200.0))
        age = float(rng.uniform(0.0, 2.0 * slo))
        max_batch = int(rng.integers(1, 257))
        per_row = None if rng.random() < 0.2 \
            else float(rng.uniform(0.01, 10.0))
        t = deadline_batch_target(age, slo, max_batch, per_row)
        assert 1 <= t <= max_batch
        if t > 1 and per_row is not None:
            assert age + t * per_row <= slo, (age, slo, per_row, t)


def test_deadline_target_edges():
    # expired head: ship the smallest possible group immediately
    assert deadline_batch_target(100.0, 50.0, 64, 1.0) == 1
    # no estimate yet (cold server): coalesce freely until the deadline
    assert deadline_batch_target(10.0, 50.0, 64, None) == 64
    # ample slack: grow to max_batch
    assert deadline_batch_target(0.0, 1000.0, 64, 1.0) == 64
    # tight slack: (50 - 40) / 5 = 2 rows fit
    assert deadline_batch_target(40.0, 50.0, 64, 5.0) == 2


# ---------------------------------------------------------------------------
# bounded-queue shedding: typed, immediate, exactly counted
# ---------------------------------------------------------------------------

def test_full_queue_sheds_exactly_the_overflow():
    depth, extra = 5, 3
    s = InferenceServer(_NoModel(), {}, None, engine="sync",
                        queue_depth=depth)
    admitted = [s.submit(*_req()) for _ in range(depth)]
    rejected = [s.submit(*_req()) for _ in range(extra)]
    # the overflow handles resolve IMMEDIATELY with the typed rejection
    for h in rejected:
        out = h.get_nowait()
        assert isinstance(out, ServerOverloaded)
        assert "queue full" in str(out)
    # the admitted handles are still pending (server never started)
    for h in admitted:
        with pytest.raises(queue.Empty):
            h.get_nowait()
    assert s.counters()["requests_shed"] == extra


def test_submit_after_close_is_typed_rejection():
    s = InferenceServer(_NoModel(), {}, None, engine="sync",
                        queue_depth=4)
    pending = s.submit(*_req())
    s.close()
    # close() drained the queued handle with the rejection...
    assert isinstance(pending.get_nowait(), ServerOverloaded)
    # ...and later submits are refused at the gate, immediately
    out = s.submit(*_req()).get_nowait()
    assert isinstance(out, ServerOverloaded)
    assert "closed" in str(out)
    assert s.counters()["requests_shed"] == 2
    with pytest.raises(RuntimeError, match="closed"):
        s.start()


def test_set_admission_requires_stopped_server():
    s = InferenceServer(_NoModel(), {}, None, engine="sync")
    s.start()
    try:
        with pytest.raises(RuntimeError, match="stopped"):
            s.set_admission(queue_depth=2)
    finally:
        s.stop()
    s.set_admission(queue_depth=2, slo_ms=50.0)
    assert s.queue_depth == 2 and s.slo_ms == 50.0


def test_set_admission_shrink_sheds_overflow():
    s = InferenceServer(_NoModel(), {}, None, engine="sync")
    handles = [s.submit(*_req()) for _ in range(5)]
    s.set_admission(queue_depth=2)
    resolved = [h for h in handles
                if not h.empty()
                and isinstance(h.get_nowait(), ServerOverloaded)]
    assert len(resolved) == 3
    assert s.counters()["requests_shed"] == 3
    assert s._q.qsize() == 2    # the carried-over admissions


# ---------------------------------------------------------------------------
# close() under live load: every handle resolves, none hangs
# ---------------------------------------------------------------------------

def test_close_never_strands_a_handle_under_load(tiny):
    cfg, model, dense, hps = tiny
    s = InferenceServer(model, dense, hps, max_batch=8,
                        queue_depth=None, slo_ms=None)
    ds = SyntheticCTR(cfg, 4)
    s.start()
    handles = []
    try:
        for i in range(30):
            b = ds.batch(i)
            handles.append(s.submit(b["dense"], b["cat"]))
    finally:
        s.close()   # mid-flight: some groups served, the rest queued
    served = shed = 0
    for h in handles:
        out = h.get(timeout=60)     # a hung handle fails the test here
        if isinstance(out, ServerOverloaded):
            shed += 1
        else:
            assert not isinstance(out, BaseException)
            assert out.shape == (4,) and np.isfinite(out).all()
            served += 1
    assert served + shed == len(handles)
    c = s.counters()
    assert c["requests_delivered"] == served
    assert c["requests_shed"] == shed


def test_closed_multi_model_resolves_every_member(tiny):
    from repro.serve.server import MultiModelServer
    cfg, model, dense, hps = tiny
    members = {n: InferenceServer(model, dense, hps, max_batch=8,
                                  queue_depth=8)
               for n in ("a", "b")}
    mm = MultiModelServer(members)
    handles = [mm.submit(n, *_tiny_batch(cfg, i))
               for i, n in enumerate(("a", "b", "a"))]
    mm.close()      # never started: everything queued must resolve
    for h in handles:
        assert isinstance(h.get(timeout=10), ServerOverloaded)
    st = mm.stats()
    assert st["a"]["requests_shed"] == 2
    assert st["b"]["requests_shed"] == 1


def _tiny_batch(cfg, i):
    b = SyntheticCTR(cfg, 2, seed=i).batch(0)
    return b["dense"], b["cat"]


# ---------------------------------------------------------------------------
# lock discipline: the two serving locks stay cycle-free under load
# ---------------------------------------------------------------------------

def test_admission_and_stats_locks_acyclic(tiny):
    """Dynamic lock-order check over the REAL serving path: submit
    threads (admission gate), the serve loop (stats + delivery) and
    stats readers all run concurrently; the recorder must observe no
    lock-order cycle between ``_admit_lock`` and ``_stats_lock``."""
    cfg, model, dense, hps = tiny
    s = InferenceServer(model, dense, hps, max_batch=8, queue_depth=16,
                        slo_ms=10_000.0)
    rec = LockOrderRecorder()
    rec.wrap(s, "_admit_lock", "InferenceServer._admit_lock")
    rec.wrap(s, "_stats_lock", "InferenceServer._stats_lock")
    ds = SyntheticCTR(cfg, 2)
    s.start()
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            s.counters()
            s.latency_percentiles()
            time.sleep(1e-3)

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    try:
        handles = [s.submit(*_tiny_batch(cfg, i)) for i in range(40)]
        for h in handles:
            out = h.get(timeout=60)
            assert not isinstance(out, BaseException) \
                or isinstance(out, ServerOverloaded)
    finally:
        stop.set()
        t.join()
        s.stop()
    assert s.counters()["requests_delivered"] > 0
    # the two serving locks are by design never NESTED — the recorder
    # must see no acquisition edges at all (an even stronger statement
    # than acyclicity, which must of course also hold)
    assert rec.edges() == set()
    rec.assert_acyclic()
