"""Online-update path: versioned publisher, consumer version tracking,
and the end-to-end train-while-serving freshness loop (ETC passes
published on the bus, observable in LIVE predictions, no redeploy)."""
import numpy as np
import pytest

from repro.core.hps.message_bus import (Consumer, MessageBus, Producer,
                                        _serialize,
                                        _deserialize_versioned)
from repro.online import UpdatePublisher


def test_wire_format_roundtrips_version():
    ids = np.asarray([3, 9, 12], np.int64)
    rows = np.random.default_rng(0).normal(size=(3, 4)) \
        .astype(np.float32)
    i2, r2, v = _deserialize_versioned(_serialize(ids, rows, 41))
    np.testing.assert_array_equal(i2, ids)
    np.testing.assert_array_equal(r2, rows)
    assert v == 41


def test_publisher_versions_are_monotonic_and_chunked():
    bus = MessageBus()
    pub = UpdatePublisher(bus, "m", max_batch_rows=8)
    rows = np.ones((20, 4), np.float32)
    v1 = pub.publish({"t0": (np.arange(20), rows)})
    v2 = pub.publish({"t0": (np.arange(20), rows * 2),
                      "t1": (np.arange(5), rows[:5])})
    assert (v1, v2) == (1, 2)
    assert pub.last_version() == 2
    assert pub.publish_time(2) is not None
    # 20 rows at max_batch_rows=8 -> 3 chunks, all stamped v1
    msgs, _ = bus.fetch("hps.m.t0", 0, max_messages=100)
    versions = [_deserialize_versioned(m)[2] for m in msgs]
    assert versions == [1, 1, 1, 2, 2, 2]
    hist = pub.history()
    assert [h["version"] for h in hist] == [1, 2]
    assert hist[1]["tables"] == ["t0", "t1"]
    assert hist[1]["rows"] == 25


def test_consumer_tracks_last_versions():
    bus = MessageBus()
    pub = UpdatePublisher(bus, "m")
    pub.publish({"t0": (np.arange(3), np.ones((3, 2), np.float32))})
    pub.publish({"t1": (np.arange(2), np.ones((2, 2), np.float32))})
    con = Consumer(bus, "m")
    applied = {}
    con.poll(lambda t, i, r: applied.setdefault(t, 0))
    assert con.last_versions == {"t0": 1, "t1": 2}
    # legacy unversioned producer messages read back as version 0 and
    # never regress a table's recorded version
    prod = Producer(bus, "m")
    prod.send("t0", np.arange(2), np.ones((2, 2), np.float32))
    prod.flush()
    con.poll(lambda t, i, r: None)
    assert con.last_versions["t0"] == 1


def test_empty_tables_are_skipped():
    bus = MessageBus()
    pub = UpdatePublisher(bus, "m")
    v = pub.publish({"t0": (np.empty(0, np.int64),
                            np.empty((0, 4), np.float32)),
                     "t1": (np.arange(2),
                            np.ones((2, 4), np.float32))})
    assert bus.topics() == ["hps.m.t1"]
    assert pub.history()[0] == pytest.approx(
        pub.history()[0] | {"version": v, "tables": ["t1"], "rows": 2})


def test_train_while_serving_freshness_loop(tmp_path):
    """The tentpole end to end: deploy LIVE, run incremental ETC passes,
    publish at each boundary, and require the updates to become visible
    in live predictions (converging onto the freshly-trained oracle)
    with no redeploy and all three storage levels consistent."""
    from repro.launch.online_train import run_online
    m = run_online(base_steps=10, online_steps=10, passes=2,
                   cache_rows=256, requests=2, batch=128,
                   deploy_dir=str(tmp_path / "bundle"), verbose=False)
    assert m["versions_published"] == 2
    assert m["updates_applied"] >= 2          # both passes consumed
    assert m["rows_refreshed"] > 0            # L1 actually refreshed
    assert m["final_dist"] < 5e-3             # converged onto oracle
    assert m["final_dist"] < m["baseline_dist"]
    assert m["freshness_lag_s"] < 120
