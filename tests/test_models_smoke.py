"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates at REDUCED size and runs one forward/train step on CPU
with shape + finiteness asserts. Decode parity vs full forward is checked
for one arch per block family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

from repro.configs.base import TrainConfig
from repro.configs.registry import (
    LM_ARCHS, RECSYS_ARCHS, reduce_for_smoke, reduce_recsys_for_smoke,
)
from repro.launch.mesh import make_test_mesh
from repro.models.lm.backbone import LMModel


def _batch_for(cfg, b=2, s=24, key=0):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(key), (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patches"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(key + 1), (b, cfg.frontend_seq, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(key + 2), (b, 16, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(LM_ARCHS))
def test_lm_arch_train_step(arch):
    cfg = reduce_for_smoke(LM_ARCHS[arch])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = LMModel(cfg, mesh, embed_mode="hybrid", hot_fraction=0.1,
                        q_chunk=16, k_chunk=16, loss_chunk=16)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch_for(cfg)

        loss, grads = jax.jit(jax.value_and_grad(model.train_loss))(
            params, batch)
        assert np.isfinite(float(loss)), arch
        # loss should start near ln(V) for random init
        assert abs(float(loss) - np.log(cfg.vocab_size)) < 2.0, arch
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(l)).all() for l in leaves), arch
        # at least one embedding grad is nonzero
        gnorm = sum(float(jnp.abs(l).sum()) for l in leaves)
        assert gnorm > 0, arch


@pytest.mark.parametrize("arch", sorted(LM_ARCHS))
def test_lm_arch_decode_step(arch):
    cfg = reduce_for_smoke(LM_ARCHS[arch])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = LMModel(cfg, mesh, embed_mode="replicated",
                        q_chunk=16, k_chunk=16)
        params = model.init(jax.random.PRNGKey(0))
        b, smax = 2, 16
        cache = model.init_cache(b, smax)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, 1), 0,
                                    cfg.vocab_size)
        pos = jnp.zeros((b,), jnp.int32)
        logits, new_cache = jax.jit(model.decode_step)(params, tokens,
                                                       cache, pos)
        assert logits.shape == (b, model.logits_size), arch
        assert np.isfinite(np.asarray(logits)).all(), arch
        # caches got updated (structure preserved)
        assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["olmo-1b", "recurrentgemma-9b",
                                  "xlstm-125m"])
def test_decode_matches_prefill(arch):
    """Feeding tokens one-by-one through decode == full forward logits."""
    cfg = reduce_for_smoke(LM_ARCHS[arch])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = LMModel(cfg, mesh, embed_mode="replicated",
                        q_chunk=8, k_chunk=8)
        params = model.init(jax.random.PRNGKey(0))
        b, s = 1, 8
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                    cfg.vocab_size)
        # full forward last-position logits
        full = np.asarray(model.prefill(params, {"tokens": tokens}))
        # token-by-token decode
        cache = model.init_cache(b, s)
        step = jax.jit(model.decode_step)
        for i in range(s):
            logits, cache = step(params, tokens[:, i:i + 1],
                                 cache, jnp.full((b,), i, jnp.int32))
        got = np.asarray(logits)
        v = cfg.vocab_size
        np.testing.assert_allclose(got[:, :v], full[:, :v],
                                   rtol=0.1, atol=0.15)
        # random-init logits are nearly flat, so exact argmax equality is
        # noise; require the two paths to be highly correlated instead
        a, b_ = got[:, :v].ravel(), full[:, :v].ravel()
        corr = np.corrcoef(a, b_)[0, 1]
        assert corr > 0.99, f"decode/prefill correlation {corr}"


@pytest.mark.parametrize("arch", sorted(RECSYS_ARCHS))
def test_recsys_arch_train_step(arch):
    from repro.data.synthetic import SyntheticCTR
    from repro.models.recsys.model import RecsysModel
    from repro.train.train_step import build_train_step, init_opt_state

    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS[arch])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=16)
        params = model.init(jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v)
                 for k, v in SyntheticCTR(cfg, 16).batch(0).items()}
        tcfg = TrainConfig()
        step = jax.jit(build_train_step(model, tcfg))
        p2, o2, aux = step(params, init_opt_state(params, tcfg), batch)
        assert np.isfinite(float(aux["loss"]))
        assert float(aux["loss"]) < 2.0           # ~ln(2) ballpark for BCE
        # params actually moved
        moved = jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).sum()),
            params, p2)
        assert sum(jax.tree.leaves(moved)) > 0


def test_recsys_kernel_path_matches_jnp_path():
    """use_kernels=True (Pallas) and the jnp pool produce the same logits."""
    from repro.data.synthetic import SyntheticCTR
    from repro.models.recsys.model import RecsysModel

    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    with mesh:
        m1 = RecsysModel(cfg, mesh, global_batch=8, use_kernels=False)
        m2 = RecsysModel(cfg, mesh, global_batch=8, use_kernels=True)
        params = m1.init(jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v)
                 for k, v in SyntheticCTR(cfg, 8).batch(0).items()}
        l1 = np.asarray(m1.apply(params, batch))
        l2 = np.asarray(m2.apply(params, batch))
        np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-2)


def test_all_arch_configs_match_assignment():
    """Spot-check the exact architecture numbers from the assignment."""
    a = LM_ARCHS
    assert a["granite-moe-1b-a400m"].num_layers == 24
    assert a["granite-moe-1b-a400m"].moe.num_experts == 32
    assert a["granite-moe-1b-a400m"].moe.top_k == 8
    assert a["granite-moe-3b-a800m"].d_model == 1536
    assert a["phi3-mini-3.8b"].d_ff == 8192
    assert a["phi3-mini-3.8b"].vocab_size == 32064
    assert a["minitron-4b"].vocab_size == 256000
    assert a["command-r-plus-104b"].d_model == 12288
    assert a["command-r-plus-104b"].num_heads == 96
    assert a["olmo-1b"].norm == "nonparam_ln"
    assert a["seamless-m4t-large-v2"].encoder_layers == 24
    assert a["pixtral-12b"].vocab_size == 131072
    assert a["xlstm-125m"].d_ff == 0
    assert a["recurrentgemma-9b"].block_pattern == (
        "rglru", "rglru", "local_attn")
    assert a["recurrentgemma-9b"].num_kv_heads == 1
    # long_500k applicability
    from repro.configs.base import LM_SHAPE_BY_NAME, shape_applicable
    long = LM_SHAPE_BY_NAME["long_500k"]
    assert shape_applicable(a["xlstm-125m"], long)
    assert shape_applicable(a["recurrentgemma-9b"], long)
    assert not shape_applicable(a["phi3-mini-3.8b"], long)


def test_moe_dispatch_matches_dense_reference():
    """Bucketed MoE dispatch == explicit per-token expert mixture when the
    capacity factor is generous enough that nothing drops."""
    import functools
    from jax.sharding import PartitionSpec as P
    import dataclasses
    from repro.configs.base import MoEConfig
    from repro.models.lm import moe as moe_lib

    cfg = reduce_for_smoke(LM_ARCHS["granite-moe-1b-a400m"])
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                           capacity_factor=8.0))
    mesh = make_test_mesh((1, 1))
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_init(key, cfg, model_axis_size=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    fn = compat.shard_map(
        functools.partial(moe_lib.moe_apply_local, cfg=cfg,
                          model_axis="model", model_axis_size=1),
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), p), P()),
        out_specs=P(), check_vma=False)
    out = fn(p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()

    # dense reference: route every token through its top-k experts exactly
    from repro.models.lm.transformer import norm_apply
    h = norm_apply(p["norm"], x, cfg)
    logits = (h @ p["router"]).astype(jnp.float32)
    gate_vals, sel = jax.lax.top_k(logits, cfg.moe.top_k)
    gate = jax.nn.softmax(gate_vals, axis=-1)

    def expert(e, v):
        u = jax.nn.silu(v @ p["w1"][e]) * (v @ p["w3"][e])
        return u @ p["w2"][e]

    want = np.asarray(x, np.float64).copy()
    hn = np.asarray(h)
    for b in range(x.shape[0]):
        for s in range(x.shape[1]):
            acc = np.zeros(cfg.d_model)
            for k in range(cfg.moe.top_k):
                e = int(sel[b, s, k])
                y = expert(e, hn[b, s])
                acc += float(gate[b, s, k]) * np.asarray(y)
            want[b, s] += acc
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-3, atol=1e-3)
