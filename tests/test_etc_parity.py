"""ETC-staged training parity: ``Solver(etc=...)`` vs the in-memory
``fit()`` oracle, plus eviction/flush/resume determinism.

The contract: a cache that covers every vocab row trains EXACTLY like
the in-memory path (same init seed, same optimizers, same clip); an
evicting cache stays a working approximation (loss improves, predictions
bounded); and pass boundaries (flush + restage) change nothing — the
PS round-trips params AND optimizer state exactly.
"""
import warnings

import numpy as np
import pytest

from repro.api import (CreateSolver, DataReaderParams, DenseLayer, Input,
                       Model, SparseEmbedding)
from repro.configs.base import ETCParams
from repro.models.recsys.dense_graph import GraphError


def _build(etc=None, seed=0, vocab=(100, 80), hotness=1):
    solver = CreateSolver(batch_size=64, lr=1e-2, seed=seed, etc=etc)
    reader = DataReaderParams(source="synthetic", num_dense_features=4)
    m = Model(solver, reader, name="etc-parity")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=list(vocab), dim=8,
                          top_name="emb", hotness=hotness))
    m.add(DenseLayer("mlp", ["dense", "emb"], ["logit"], units=(16, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m


def _fit(m, steps=20):
    with warnings.catch_warnings():     # full-coverage caches warn
        warnings.simplefilter("ignore", RuntimeWarning)
        return m.fit(steps=steps)


def test_full_coverage_matches_in_memory_oracle():
    """cache_rows >= vocab: every row stays resident, the ETC step is
    the in-memory step — one-hot lookups match the oracle bit-for-bit."""
    oracle = _build()
    h1 = _fit(oracle)
    etc = _build(etc=ETCParams(cache_rows=100, passes=2))
    h2 = _fit(etc)
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 1e-6
    batch = oracle._reader_data_fn()(999)
    np.testing.assert_allclose(etc.predict(batch),
                               oracle.predict(batch), atol=1e-6)


def test_full_coverage_multi_hot_within_tolerance():
    """hotness > 1 pools in a different summation order than the
    collection lookup, so full coverage is tight-tolerance, not
    bit-exact."""
    oracle = _build(hotness=2)
    h1 = _fit(oracle)
    etc = _build(etc=ETCParams(cache_rows=100, passes=2), hotness=2)
    h2 = _fit(etc)
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 5e-3
    batch = oracle._reader_data_fn()(999)
    np.testing.assert_allclose(etc.predict(batch),
                               oracle.predict(batch), atol=2e-2)


def test_evicting_cache_still_learns_and_stays_bounded():
    oracle = _build(vocab=(200, 160), hotness=2)
    h1 = _fit(oracle, steps=30)
    m = _build(etc=ETCParams(cache_rows=96, passes=3),
               vocab=(200, 160), hotness=2)
    h2 = _fit(m, steps=30)
    assert m._online.etc.evictions > 0        # capacity actually binds
    assert h2[-1]["loss"] < h2[0]["loss"]     # learning through churn
    batch = oracle._reader_data_fn()(999)
    diff = np.abs(m.predict(batch) - oracle.predict(batch)).max()
    assert diff < 0.15                        # approximation, not drift


def test_pass_boundaries_change_nothing():
    """1 pass vs 4 passes over the same steps: flush + keyset restage at
    each boundary must round-trip params and adagrad state exactly."""
    a = _build(etc=ETCParams(cache_rows=64, passes=1))
    _fit(a, steps=24)
    b = _build(etc=ETCParams(cache_rows=64, passes=4))
    _fit(b, steps=24)
    batch = a._reader_data_fn()(500)
    np.testing.assert_array_equal(a.predict(batch), b.predict(batch))


def test_etc_run_is_deterministic():
    a = _build(etc=ETCParams(cache_rows=72, passes=2))
    b = _build(etc=ETCParams(cache_rows=72, passes=2))
    ha, hb = _fit(a, steps=16), _fit(b, steps=16)
    assert [h["loss"] for h in ha] == [h["loss"] for h in hb]
    batch = a._reader_data_fn()(123)
    np.testing.assert_array_equal(a.predict(batch), b.predict(batch))


def test_cached_ps_resume_continues_training(tmp_path):
    """ps='cached': a second fit() on a fresh model over the same
    ps_root starts from the flushed tables (the PS is the durable tier,
    not a checkpoint dir)."""
    etc = ETCParams(cache_rows=64, ps="cached",
                    ps_root=str(tmp_path / "ps"), passes=1)
    a = _build(etc=etc)
    _fit(a, steps=10)
    probe = a._reader_data_fn()(42)
    pa = a.predict(probe)
    # fresh process-equivalent: new model, same ps_root; its trainer
    # seeds the PS from the model init — overwriting — so pull the
    # tables BEFORE via a bare OnlineTrainer export instead
    from repro.core.etc.parameter_server import CachedPS
    ps = CachedPS(a.cfg.tables, etc.ps_root)
    rows = ps.pull("f0", np.arange(100))
    got = a._online.ps.pull("f0", np.arange(100))
    np.testing.assert_array_equal(rows, got)     # disk == live PS
    assert pa.shape == probe["label"].shape


def test_solver_etc_validation_and_json_roundtrip(tmp_path):
    with pytest.raises(GraphError, match="Solver.etc"):
        CreateSolver(etc={"cache_rows": -1})
    with pytest.raises(ValueError, match="ps_root"):
        ETCParams(ps="cached")
    with pytest.raises(ValueError, match="ps"):
        ETCParams(ps="bogus")
    m = _build(etc=ETCParams(cache_rows=77, passes=3))
    path = str(tmp_path / "graph.json")
    m.graph_to_json(path)
    m2 = Model.from_json(path)
    assert isinstance(m2.solver.etc, ETCParams)
    assert (m2.solver.etc.cache_rows, m2.solver.etc.passes) == (77, 3)


def test_etc_rejects_wide_models():
    solver = CreateSolver(batch_size=32,
                          etc=ETCParams(cache_rows=32))
    reader = DataReaderParams(source="synthetic", num_dense_features=4)
    m = Model(solver, reader, name="etc-wdl")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[50, 40], dim=8, top_name="emb",
                          hotness=2))
    m.add(SparseEmbedding(vocab_sizes=[50, 40], dim=1, top_name="wide",
                          hotness=2))
    m.add(DenseLayer("mlp", ["dense", "emb"], ["deep_logit"],
                     units=(8, 1)))
    m.add(DenseLayer("reduce_sum", ["wide"], ["wide_logit"]))
    m.add(DenseLayer("sigmoid", ["deep_logit", "wide_logit"], ["prob"]))
    with pytest.raises(GraphError, match="single-collection"):
        m.fit(steps=2)
