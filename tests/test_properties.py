"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.embedding.strategies import _bucket_by_owner
from repro.kernels import ops, ref
from repro.optim.optimizers import clip_by_global_norm

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Pallas lookup kernel: linearity + permutation/padding invariances
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 40), st.integers(1, 6), st.integers(8, 200),
       st.integers(0, 2 ** 31 - 1))
def test_lookup_matches_oracle_random_shapes(b, h, v, seed):
    d = 16
    key = jax.random.PRNGKey(seed)
    table = jax.random.normal(key, (v, d), jnp.float32)
    rows = jax.random.randint(jax.random.fold_in(key, 1), (b, h), -1, v)
    got = ops.fused_embedding_lookup(table, rows)
    want = ref.embedding_lookup_ref(table, rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_lookup_is_linear_in_table(seed):
    v, d, b, h = 64, 8, 9, 3
    key = jax.random.PRNGKey(seed)
    t1 = jax.random.normal(key, (v, d))
    t2 = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    rows = jax.random.randint(jax.random.fold_in(key, 2), (b, h), -1, v)
    lhs = ops.fused_embedding_lookup(t1 + 2.0 * t2, rows)
    rhs = (ops.fused_embedding_lookup(t1, rows)
           + 2.0 * ops.fused_embedding_lookup(t2, rows))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_lookup_hotness_permutation_invariant(seed):
    """Sum pooling must not care about the order of ids within a sample."""
    v, d, b, h = 50, 8, 6, 5
    key = jax.random.PRNGKey(seed)
    table = jax.random.normal(key, (v, d))
    rows = jax.random.randint(jax.random.fold_in(key, 1), (b, h), -1, v)
    perm = jax.random.permutation(jax.random.fold_in(key, 2), h)
    np.testing.assert_allclose(
        np.asarray(ops.fused_embedding_lookup(table, rows)),
        np.asarray(ops.fused_embedding_lookup(table, rows[:, perm])),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Bucketing (all-to-all id routing): conservation + capacity laws
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 16),
       st.integers(0, 2 ** 31 - 1))
def test_bucket_by_owner_invariants(m, n_shards, capacity, seed):
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.integers(-1, n_shards * 13, m), jnp.int32)
    send, slot_of, valid = jax.jit(
        _bucket_by_owner, static_argnums=(1, 2))(flat, n_shards, capacity)
    send = np.asarray(send)
    slot_of = np.asarray(slot_of)
    valid = np.asarray(valid)
    flat = np.asarray(flat)

    # 1. every valid id landed in its owner's bucket at the slot recorded
    for i in range(m):
        if valid[i]:
            owner, pos = divmod(int(slot_of[i]), capacity)
            assert owner == flat[i] % n_shards
            assert send[owner, pos] == flat[i] // n_shards
    # 2. capacity respected: per owner, at most `capacity` valid entries
    for s in range(n_shards):
        assert (send[s] >= 0).sum() <= capacity
    # 3. padding ids are never valid
    assert not valid[flat < 0].any() if (flat < 0).any() else True
    # 4. an id is dropped ONLY if its owner bucket is full
    for i in range(m):
        if flat[i] >= 0 and not valid[i]:
            assert (send[flat[i] % n_shards] >= 0).sum() == capacity


# ---------------------------------------------------------------------------
# Optimizer invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.floats(0.1, 10.0), st.integers(0, 2 ** 31 - 1))
def test_clip_by_global_norm_bound(max_norm, seed):
    key = jax.random.PRNGKey(seed)
    g = {"a": jax.random.normal(key, (7, 3)) * 100,
         "b": jax.random.normal(jax.random.fold_in(key, 1), (5,)) * 100}
    clipped, norm = clip_by_global_norm(g, max_norm)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                               for x in jax.tree.leaves(clipped))))
    assert total <= max_norm * 1.01


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_rowwise_adagrad_touches_only_accessed_rows(seed):
    """Rows with zero gradient must not move (sparse-update semantics)."""
    from repro.configs.base import TrainConfig
    from repro.optim.sparse import rowwise_adagrad

    opt = rowwise_adagrad(TrainConfig(learning_rate=0.1))
    key = jax.random.PRNGKey(seed)
    p = {"t": jax.random.normal(key, (20, 4))}
    state = opt.init(p)
    g = jnp.zeros((20, 4)).at[3].set(1.0).at[7].set(-2.0)
    new_p, new_state = opt.update({"t": g}, state, p)
    moved = np.abs(np.asarray(new_p["t"]) - np.asarray(p["t"])).sum(axis=1)
    assert moved[3] > 0 and moved[7] > 0
    untouched = [i for i in range(20) if i not in (3, 7)]
    np.testing.assert_allclose(moved[untouched], 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# Checkpoint roundtrip property
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_checkpoint_roundtrip_random_trees(seed):
    import tempfile
    from repro.train import checkpoint as ck
    rng = np.random.default_rng(seed)
    tree = {
        "w": rng.normal(size=(rng.integers(1, 8), rng.integers(1, 8)))
        .astype(np.float32),
        "nested": {"k": rng.integers(0, 100, size=(3,)).astype(np.int64)},
    }
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 0, tree)
        flat, _ = ck.load(d, 0)
        out = ck.unflatten_like(tree, flat)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     tree, out)


# ---------------------------------------------------------------------------
# Synthetic data: determinism + Zipf shape
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_synthetic_batches_are_deterministic(step):
    from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
    from repro.data.synthetic import SyntheticCTR
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    a = SyntheticCTR(cfg, 8).batch(step)
    b = SyntheticCTR(cfg, 8).batch(step)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_synthetic_ids_are_zipf_distributed():
    from repro.configs.registry import RECSYS_ARCHS
    from repro.data.synthetic import SyntheticCTR
    cfg = RECSYS_ARCHS["dlrm-criteo"]
    ds = SyntheticCTR(cfg, 4096)
    cat = ds.batch(0)["cat"]
    big = cat[:, 2, 0]      # a 10M-vocab table
    # rank 0 must dominate: top-1% of ids should cover >> 1% of accesses
    frac_small = (big < cfg.tables[2].vocab_size // 100).mean()
    assert frac_small > 0.5
