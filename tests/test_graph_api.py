"""Graph API (paper §2): lowering, JSON round-trip, and the
config-driven deployment path.

The acceptance bar: all four recipes build through ``model.add(...)``,
``graph_to_json`` + checkpoint alone reconstruct a serving
InferenceServer via ``launch.serve`` whose predictions match the
in-process ``deploy()`` bit-exactly.
"""
import importlib
import json
import os

import numpy as np
import pytest

from repro.api import (
    DataReaderParams, DenseLayer, GraphError, Input, Model,
    SparseEmbedding, Solver,
)
from repro.configs.base import recsys_config_hash
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.data.synthetic import SyntheticCTR

ARCHS = ["dlrm-criteo", "dcn-criteo", "deepfm-criteo", "wdl-criteo"]


def _recipe(arch):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_"))


def _small_dlrm(name="g-dlrm", batch=16):
    m = Model(Solver(batch_size=batch, lr=1e-2),
              DataReaderParams(num_dense_features=4), name=name)
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[300, 100], dim=8, hotness=2,
                          top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(16, 8),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(16, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_recipes_lower_to_registry_configs(arch):
    """The graph IS the config: full + smoke recipes lower bit-exactly
    onto the registry entries the rest of the stack executes."""
    mod = _recipe(arch)
    assert mod.build_model().to_recsys_config() == RECSYS_ARCHS[arch]
    assert mod.build_model(smoke=True).to_recsys_config() == \
        reduce_recsys_for_smoke(RECSYS_ARCHS[arch])
    assert mod.GRAPH_CONFIG == mod.CONFIG


@pytest.mark.parametrize("arch", ARCHS)
def test_recipes_train_one_step(arch):
    m = _recipe(arch).build_model(
        smoke=True, solver=Solver(batch_size=16, lr=1e-2))
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    hist = m.fit(data.batch, steps=1)
    assert len(hist) == 1 and np.isfinite(hist[0]["loss"])
    preds = m.predict(data.batch(99))
    assert preds.shape == (16,)
    assert ((preds > 0) & (preds < 1)).all()


def test_wdl_graph_declares_two_embedding_branches():
    m = _recipe("wdl-criteo").build_model(smoke=True)
    dims = sorted(e.dim for e in m._embeddings)
    assert dims == [1, 16]           # wide + deep
    m.compile()
    assert m.model.wide is not None  # lowered model grew the wide branch


def test_summary_mentions_every_layer():
    m = _small_dlrm()
    s = m.summary()
    for token in ("dot_interaction", "SparseEmbedding", "emb", "logit",
                  "dlrm"):
        assert token in s


# ---------------------------------------------------------------------------
# Lowering errors
# ---------------------------------------------------------------------------

def test_unknown_tensor_is_rejected():
    m = Model(name="bad")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[10], dim=4))
    m.add(DenseLayer("mlp", ["nope"], ["x"], units=(4,)))
    with pytest.raises(GraphError, match="unknown tensor 'nope'"):
        m.to_recsys_config()


def _ngroup_model(name="ngroup", batch=16):
    """Three SparseEmbedding groups with three distinct dims."""
    m = Model(Solver(batch_size=batch, lr=1e-2),
              DataReaderParams(num_dense_features=4), name=name)
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[300, 100], dim=8, top_name="a"))
    m.add(SparseEmbedding(vocab_sizes=[60], dim=4, top_name="b"))
    m.add(SparseEmbedding(vocab_sizes=[40, 20, 10], dim=2, top_name="c"))
    m.add(DenseLayer("concat", ["dense", "a", "b", "c"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["logit"], units=(16, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m


def test_n_group_embeddings_lower_and_train():
    """Multiple independently-dimensioned deep groups are a first-class
    lowering now (formerly a GraphError): the first group is the primary
    collection, each further group gets its own param key and cat column
    span, and fit/predict run through the generic program."""
    m = _ngroup_model()
    cfg = m.to_recsys_config()
    assert cfg.model == "graph"
    assert [(g.name, g.dim, len(g.tables)) for g in cfg.extra_groups] \
        == [("b", 4, 1), ("c", 2, 3)]
    # cat layout: primary tables first, then each group's, in order
    assert [t.name for t in cfg.all_tables] \
        == ["f0", "f1", "b_f0", "c_f0", "c_f1", "c_f2"]
    m.compile()
    assert set(m.model.collections()) == \
        {"embedding", "embedding@b", "embedding@c"}
    assert m.model.group_columns() == \
        {"embedding": (0, 2), "embedding@b": (2, 3), "embedding@c": (3, 6)}
    data = SyntheticCTR(m.cfg, 16)
    hist = m.fit(data.batch, steps=2)
    assert all(np.isfinite(h["loss"]) for h in hist)
    preds = m.predict(data.batch(7))
    assert preds.shape == (16,) and ((preds > 0) & (preds < 1)).all()


def test_n_group_json_round_trip(tmp_path):
    m = _ngroup_model()
    p = str(tmp_path / "g.json")
    m.graph_to_json(p)
    m2 = Model.from_json(p)
    assert m2.to_recsys_config() == m.to_recsys_config()


def test_n_group_duplicate_table_names_rejected():
    m = Model(name="dup")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[30], dim=8, top_name="a",
                          table_names=["t"]))
    m.add(SparseEmbedding(vocab_sizes=[30], dim=4, top_name="b",
                          table_names=["t"]))
    m.add(DenseLayer("concat", ["dense", "a", "b"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["logit"], units=(1,)))
    with pytest.raises(GraphError, match="globally.*unique|'t'"):
        m.to_recsys_config()


def test_extra_group_name_may_not_shadow_param_keys():
    m = Model(name="shadow")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[30], dim=8, top_name="a"))
    m.add(SparseEmbedding(vocab_sizes=[30], dim=4,
                          top_name="wide_embedding"))
    with pytest.raises(GraphError, match="reserved"):
        m.to_recsys_config()


def test_dlrm_bottom_dim_mismatch_rejected():
    m = Model(name="bad")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[10], dim=8, top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(16, 4)))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("mlp", ["bot", "inter"], ["logit"], units=(1,)))
    with pytest.raises(GraphError, match="embedding dim"):
        m.to_recsys_config()


def test_sigmoid_must_stay_terminal():
    m = _small_dlrm()
    m.add(DenseLayer("relu", ["prob"], ["extra"]))
    with pytest.raises(GraphError, match="'prob'.*terminal"):
        m.to_recsys_config()


# ---------------------------------------------------------------------------
# Adversarial graph validation (the generic compiler's error surface:
# every rejection names the offending tensor/layer)
# ---------------------------------------------------------------------------

def _graph_base(name="adv"):
    m = Model(Solver(batch_size=8), DataReaderParams(num_dense_features=4),
              name=name)
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[50, 30], dim=8, top_name="emb"))
    return m


def test_cycle_is_rejected_naming_the_layers():
    m = _graph_base()
    # a <- concat(flat, b), b <- relu(a): mutually dependent
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("concat", ["flat", "b"], ["a"]))
    m.add(DenseLayer("relu", ["a"], ["b"]))
    m.add(DenseLayer("mlp", ["b"], ["logit"], units=(1,)))
    with pytest.raises(GraphError, match="cycle.*'a'.*'b'"):
        m.to_recsys_config()


def test_dangling_bottom_name_is_rejected():
    m = _graph_base()
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat", "ghost"], ["logit"], units=(1,)))
    with pytest.raises(GraphError,
                       match=r"DenseLayer\(mlp\) -> 'logit' reads "
                             "unknown tensor 'ghost'"):
        m.to_recsys_config()


def test_shape_mismatch_is_rejected_naming_both_tensors():
    m = _graph_base()
    m.add(DenseLayer("mlp", ["dense"], ["a"], units=(8,)))
    m.add(DenseLayer("mlp", ["dense"], ["b"], units=(4,)))
    m.add(DenseLayer("add", ["a", "b"], ["bad"]))
    m.add(DenseLayer("mlp", ["bad"], ["logit"], units=(1,)))
    with pytest.raises(GraphError, match="'b'.*'a'"):
        m.to_recsys_config()


def test_dual_terminals_rejected():
    m = _graph_base()
    m.add(DenseLayer("mlp", ["dense"], ["logit_a"], units=(1,)))
    m.add(DenseLayer("mlp", ["emb"], ["logit_b"], units=(1,)))
    with pytest.raises(GraphError,
                       match="exactly one terminal.*logit_a.*logit_b"):
        m.to_recsys_config()


def test_unused_layer_rejected():
    m = _graph_base()
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["logit"], units=(1,)))
    m.add(DenseLayer("relu", ["flat"], ["orphan"]))   # feeds nothing
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    with pytest.raises(GraphError, match="orphan"):
        m.to_recsys_config()


def test_unread_embedding_rejected():
    m = _graph_base()
    m.add(DenseLayer("mlp", ["dense"], ["logit"], units=(1,)))
    with pytest.raises(GraphError, match="'emb' is never read"):
        m.to_recsys_config()


def test_wide_terminal_rejected():
    m = _graph_base()
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["wide_out"], units=(16,)))
    with pytest.raises(GraphError, match="'wide_out'.*not logit-shaped"):
        m.to_recsys_config()


def test_slice_bounds_rejected():
    m = _graph_base()
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("slice", ["dense"], ["cut"], start=2, stop=9))
    m.add(DenseLayer("mlp", ["flat", "cut"], ["logit"], units=(1,)))
    with pytest.raises(GraphError, match=r"'cut'.*\[2:9\].*out of range"):
        m.to_recsys_config()


def test_reserved_tensor_name_rejected():
    m = _graph_base()
    m.add(DenseLayer("mlp", ["dense"], ["embedding"], units=(1,)))
    with pytest.raises(GraphError, match="'embedding' is reserved"):
        m.to_recsys_config()


def test_duplicated_sigmoid_bottom_does_not_classify_canonical():
    """sigmoid(['logit', 'logit']) means 2x the logit under the generic
    executor — it must lower generically, NOT silently classify as the
    canonical dlrm (whose program would sum 'logit' once)."""
    m = Model(Solver(batch_size=8), DataReaderParams(num_dense_features=4),
              name="dup-sig")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[50, 30], dim=8, top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(16, 8),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(16, 1)))
    m.add(DenseLayer("sigmoid", ["logit", "logit"], ["prob"]))
    cfg = m.to_recsys_config()
    assert cfg.model == "graph"      # declared semantics win
    # ...and the single-bottom twin still classifies canonical
    single = _small_dlrm()
    assert single.to_recsys_config().model == "dlrm"


def test_layers_may_be_declared_out_of_order():
    """The compiler topologically sorts: declaration order is free."""
    m = _graph_base()
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    m.add(DenseLayer("mlp", ["flat"], ["logit"], units=(1,)))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    cfg = m.to_recsys_config()
    assert cfg.model == "graph"
    m.compile()
    data = SyntheticCTR(m.cfg, 8)
    m.fit(data.batch, steps=1)
    assert m.predict(data.batch(1)).shape == (8,)


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

def test_graph_json_round_trip_stable(tmp_path):
    m = _recipe("wdl-criteo").build_model(smoke=True)
    p1 = str(tmp_path / "g1.json")
    p2 = str(tmp_path / "g2.json")
    m.graph_to_json(p1)
    m2 = Model.from_json(p1)
    m2.graph_to_json(p2)
    with open(p1) as f1, open(p2) as f2:
        assert json.load(f1) == json.load(f2)
    assert m2.to_recsys_config() == m.to_recsys_config()


def test_graph_json_hash_tamper_detected(tmp_path):
    m = _small_dlrm()
    p = str(tmp_path / "g.json")
    m.graph_to_json(p)
    with open(p) as f:
        d = json.load(f)
    # tamper with the model but keep the stale hash
    for layer in d["layers"]:
        if layer["kind"] == "sparse_embedding":
            layer["dim"] = 4
        if layer["kind"] == "dense" and layer["type"] == "mlp" \
                and layer["bottom_names"] == ["dense"]:
            layer["units"] = [16, 4]
    with open(p, "w") as f:
        json.dump(d, f)
    with pytest.raises(GraphError, match="hash"):
        Model.from_json(p)


def test_save_load_predict_bit_identical(tmp_path):
    m = _small_dlrm()
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    m.fit(data.batch, steps=3)
    batch = data.batch(77)
    want = m.predict(batch)
    m.save(str(tmp_path / "sv"))
    m2 = Model.load(str(tmp_path / "sv"))
    np.testing.assert_array_equal(m2.predict(batch), want)


def test_load_then_fit_resumes(tmp_path):
    m = _small_dlrm()
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    m.fit(data.batch, steps=3)
    saved = m.predict(data.batch(5))
    m.save(str(tmp_path / "sv"))

    m2 = Model.load(str(tmp_path / "sv"))
    # bare-loaded model trains onward from the saved weights
    before = m2.predict(data.batch(5))
    np.testing.assert_array_equal(before, saved)
    hist = m2.fit(data.batch, steps=2)
    assert len(hist) == 2
    after = m2.predict(data.batch(5))
    assert not np.array_equal(before, after)


# ---------------------------------------------------------------------------
# Deployment: object-driven == config-driven
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dlrm-criteo", "wdl-criteo"])
def test_config_deploy_matches_object_deploy(arch, tmp_path):
    """A trained graph deploys from its JSON alone: the ps.json bundle
    reconstructs a server whose predictions are bit-exact with the
    in-process deploy() (wdl covers the two-HPS wide branch)."""
    from repro.launch.serve import build_server_from_config
    m = _recipe(arch).build_model(
        smoke=True, solver=Solver(batch_size=16, lr=1e-2))
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    m.fit(data.batch, steps=2)
    batch = data.batch(42)

    dep = str(tmp_path / "dep")
    server = m.deploy(dep, cache_capacity=128)
    want = server.predict(batch["dense"], batch["cat"])

    server2, loaded = build_server_from_config(
        os.path.join(dep, "ps.json"))
    got = server2.predict(batch["dense"], batch["cat"])
    np.testing.assert_array_equal(got, want)
    # and both track the training-graph forward pass
    np.testing.assert_allclose(got, m.predict(batch),
                               rtol=2e-2, atol=2e-2)
    assert loaded.cfg == m.cfg


def test_ps_json_contents(tmp_path):
    m = _small_dlrm()
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    m.fit(data.batch, steps=1)
    dep = str(tmp_path / "dep")
    m.deploy(dep, cache_capacity=99, refresh_budget=7, cache_shards=1)
    with open(os.path.join(dep, "ps.json")) as f:
        d = json.load(f)
    assert d["format"] == "repro-ps-v1"
    assert d["cache_capacity"] == 99
    assert d["refresh_budget"] == 7
    assert d["config_hash"] == recsys_config_hash(m.cfg)
    assert [t["name"] for t in d["tables"]] == \
        [t.name for t in m.cfg.tables]
    for rel in (d["graph_path"], d["dense_weights_path"]):
        assert os.path.exists(os.path.join(dep, rel))


# ---------------------------------------------------------------------------
# Generic executor: canonical recipes bit-exact with the fixed pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_program_matches_reference_pipeline_bit_exact(arch):
    """The compiled DenseGraphProgram and the pre-compiler fixed
    pipeline produce IDENTICAL logits for the same params — the
    bit-exactness contract of the lowering redesign."""
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh
    from repro.models.recsys.model import RecsysModel

    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS[arch])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=16)
        params = model.init(jax.random.PRNGKey(1))
        batch = SyntheticCTR(cfg, 16).batch(0)
        cat = jnp.asarray(batch["cat"])
        emb = model.embedding.lookup(params["embedding"], cat)
        wide = model.wide.lookup(params["wide_embedding"], cat) \
            if model.wide is not None else None
        dense = jnp.asarray(batch["dense"])
        got = np.asarray(model.apply_dense(params, dense, emb, wide))
        want = np.asarray(
            model.apply_dense_reference(params, dense, emb, wide))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Novel architectures: train / round-trip / deploy with zero per-arch code
# ---------------------------------------------------------------------------

NOVEL_ARCHS = ["twotower-criteo", "crossdeep-criteo"]


@pytest.mark.parametrize("arch", NOVEL_ARCHS)
def test_novel_arch_lowers_generic_and_trains(arch):
    m = _recipe(arch).build_model(
        smoke=True, solver=Solver(batch_size=16, lr=1e-2))
    cfg = m.to_recsys_config()
    assert cfg.model == "graph"
    assert cfg.dense_graph and cfg.dense_graph[0][0] == "inputs"
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    hist = m.fit(data.batch, steps=2)
    assert len(hist) == 2 and all(np.isfinite(h["loss"]) for h in hist)
    preds = m.predict(data.batch(99))
    assert preds.shape == (16,)
    assert ((preds > 0) & (preds < 1)).all()


@pytest.mark.parametrize("arch", NOVEL_ARCHS)
def test_novel_arch_json_round_trip(arch, tmp_path):
    m = _recipe(arch).build_model(smoke=True)
    p = str(tmp_path / "g.json")
    m.graph_to_json(p)
    m2 = Model.from_json(p)
    assert m2.to_recsys_config() == m.to_recsys_config()
    # the embedded config hash covers the dense graph: editing a layer
    # (widening a hidden mlp keeps the graph VALID, so only the hash
    # can catch it) with a stale hash must be detected
    with open(p) as f:
        d = json.load(f)
    for layer in d["layers"]:
        if layer["kind"] == "dense" and layer["type"] == "mlp" \
                and len(layer["units"]) > 1:
            layer["units"][0] += 1
    with open(p, "w") as f:
        json.dump(d, f)
    with pytest.raises(GraphError, match="hash"):
        Model.from_json(p)


def test_novel_arch_save_load_and_deploy_bit_identical(tmp_path):
    """Two-tower: save()/load() then deploy() — the rebuilt
    config-driven server matches the in-process one bit-exactly (the
    acceptance bar extended to novel graphs)."""
    from repro.launch.serve import build_server_from_config
    m = _recipe("twotower-criteo").build_model(
        smoke=True, solver=Solver(batch_size=16, lr=1e-2))
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    m.fit(data.batch, steps=2)
    batch = data.batch(42)
    want = m.predict(batch)

    m.save(str(tmp_path / "sv"))
    m2 = Model.load(str(tmp_path / "sv"))
    np.testing.assert_array_equal(m2.predict(batch), want)

    dep = str(tmp_path / "dep")
    server = m.deploy(dep, cache_capacity=128)
    got = server.predict(batch["dense"], batch["cat"])
    server2, loaded = build_server_from_config(
        os.path.join(dep, "ps.json"))
    np.testing.assert_array_equal(
        server2.predict(batch["dense"], batch["cat"]), got)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    assert loaded.cfg == m.cfg


# ---------------------------------------------------------------------------
# Criteo reader: seekable batch(step) + deterministic failure-replay
# ---------------------------------------------------------------------------

def _criteo_dlrm(path, batch=8):
    """A 26-table dlrm graph over a tiny Criteo TSV (the format carries
    26 categorical columns, so the reader needs all 26 tables)."""
    m = Model(Solver(batch_size=batch, lr=1e-2, ckpt_interval=2),
              DataReaderParams(source="criteo", path=path),
              name="criteo-dlrm")
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(vocab_sizes=[50] * 26, dim=8, top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(16, 8),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(16, 1)))
    return m


def test_criteo_batch_step_is_seekable_and_deterministic(tmp_path):
    """``batch(step)`` is a pure function of (file, B, step): call order
    does not matter, steps address lines ``[sB, sB+B) mod N`` (epoch
    boundaries wrap seamlessly), and two readers agree bit-exactly."""
    from repro.data import criteo
    cfg = _criteo_dlrm("unused").to_recsys_config()
    path = str(tmp_path / "criteo.tsv")
    criteo.write_synthetic_file(path, 37, cfg, seed=3)
    with open(path) as f:
        lines = f.readlines()
    r = criteo.CriteoReader(path, cfg, 8)
    assert r.num_lines == 37
    r.batch(11)                                 # out-of-order access...
    got = r.batch(5)                            # abs lines 40..47 -> wrap
    want = criteo.parse_lines(
        [lines[i % 37] for i in range(40, 48)], cfg)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    fresh = criteo.CriteoReader(path, cfg, 8).batch(5)
    for k in want:                              # ...changes nothing
        np.testing.assert_array_equal(fresh[k], got[k])
    # the legacy generator still yields the same stream, tail included
    epoch = list(criteo.reader(path, cfg, 8, loop=False))
    assert len(epoch) == 5 and epoch[-1]["dense"].shape[0] == 5
    for i, b in enumerate(epoch[:-1]):
        w = criteo.parse_lines(lines[i * 8:(i + 1) * 8], cfg)
        for k in w:
            np.testing.assert_array_equal(b[k], w[k])


def test_criteo_crlf_lines_hash_like_lf(tmp_path):
    """CRLF TSVs must parse identically to LF ones: a trailing \\r on
    the last categorical column would silently remap every C26 id
    (the seekable reader hands binary-mode lines through untranslated)."""
    from repro.data import criteo
    cfg = _criteo_dlrm("unused").to_recsys_config()
    lf, crlf = str(tmp_path / "lf.tsv"), str(tmp_path / "crlf.tsv")
    criteo.write_synthetic_file(lf, 16, cfg, seed=5)
    with open(lf, "rb") as f:
        data = f.read()
    with open(crlf, "wb") as f:
        f.write(data.replace(b"\n", b"\r\n"))
    a = criteo.CriteoReader(lf, cfg, 16).batch(0)
    b = criteo.CriteoReader(crlf, cfg, 16).batch(0)
    c = next(criteo.reader(crlf, cfg, 16))
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
        np.testing.assert_array_equal(a[k], c[k])


def test_criteo_resume_mid_epoch_is_deterministic(tmp_path):
    """The ROADMAP open item: a criteo run killed mid-epoch and resumed
    from its checkpoint must replay the exact batches — final weights
    bit-identical to the uninterrupted run."""
    from repro.data import criteo
    from repro.models.recsys.model import export_logical_params
    import jax

    path = str(tmp_path / "criteo.tsv")
    criteo.write_synthetic_file(path, 40, _criteo_dlrm(path)
                                .to_recsys_config(), seed=1)

    full = _criteo_dlrm(path)
    full.fit(steps=4)                           # the uninterrupted run

    ck = str(tmp_path / "ck")
    part = _criteo_dlrm(path)
    part.fit(steps=2, ckpt_dir=ck)              # "crash" after step 1...
    resumed = _criteo_dlrm(path)
    resumed.fit(steps=4, ckpt_dir=ck)           # ...restore + replay 2,3

    with full.mesh:
        want = export_logical_params(full.model, full.params)
        got = export_logical_params(resumed.model, resumed.params)
    flat_w = jax.tree_util.tree_leaves_with_path(want)
    flat_g = dict(jax.tree_util.tree_leaves_with_path(got))
    assert flat_w and len(flat_w) == len(flat_g)
    for key, w in flat_w:
        np.testing.assert_array_equal(np.asarray(w),
                                      np.asarray(flat_g[key]),
                                      err_msg=str(key))
