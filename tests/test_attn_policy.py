"""The measured attention-partition policy (EXPERIMENTS.md §Perf iter 11)
and the seqpar/chunked equivalence property."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import LM_ARCHS, reduce_for_smoke
from repro.launch.mesh import make_test_mesh
from repro.models.lm import transformer as tf
from repro.models.lm.backbone import LMModel


def _partition_for(arch, model_size, remat="none"):
    """Evaluate the auto rule without building a big mesh."""
    import math
    cfg = LM_ARCHS[arch]
    a = math.gcd(cfg.num_kv_heads, model_size)
    b = model_size // a
    group = cfg.num_heads // cfg.num_kv_heads
    dirty = group % b != 0
    # fsdp proxy as in LMModel
    fsdp = cfg.dense_param_count * 12 / max(model_size, 1) > 10e9
    training = remat != "none"
    return "seq" if (dirty or (fsdp and training)) else "heads"


@pytest.mark.parametrize("arch,expect", [
    ("minitron-4b", "seq"),            # g=3 dirty
    ("granite-moe-3b-a800m", "seq"),   # g=3 dirty
    ("granite-moe-1b-a400m", "heads"),  # g=2 clean
    ("pixtral-12b", "heads"),          # g=4 clean
    ("phi3-mini-3.8b", "heads"),       # kv=32 divides
    ("olmo-1b", "heads"),              # kv=16 divides
])
def test_partition_rule_matches_measurements(arch, expect):
    assert _partition_for(arch, 16) == expect


def test_fsdp_arch_seq_only_when_training():
    assert _partition_for("command-r-plus-104b", 16, remat="full") == "seq"
    assert _partition_for("command-r-plus-104b", 16, remat="none") == "heads"


def test_backbone_rule_single_device_is_heads():
    """model_size == 1 -> never seqpar, regardless of arch."""
    mesh = make_test_mesh((1, 1))
    for arch in ("olmo-1b", "minitron-4b", "command-r-plus-104b"):
        cfg = reduce_for_smoke(LM_ARCHS[arch])
        model = LMModel(cfg, mesh, embed_mode="replicated", remat="full")
        assert model.attn_partition == "heads", arch


SETTINGS = dict(max_examples=8, deadline=None)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 16, 32]),
       st.sampled_from([(4, 2), (4, 4), (6, 2)]))
def test_chunked_attention_chunk_invariance(seed, chunk, heads):
    """Output must not depend on chunk sizes (property)."""
    hq, hkv = heads
    b, s, d = 2, 64, 16
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    o1 = tf.chunked_attention(q, k, v, causal=True, q_chunk=chunk,
                              k_chunk=chunk)
    o2 = tf.chunked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-3)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_folded_and_legacy_blocks_agree(seed):
    """The two measured softmax block styles are numerically equivalent."""
    b, s, hq, hkv, d = 2, 32, 4, 2, 16
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    legacy = tf.chunked_attention(q, k, v, causal=True, q_chunk=8,
                                  k_chunk=8, folded=False)
    folded = tf.chunked_attention(q, k, v, causal=True, q_chunk=8,
                                  k_chunk=8, folded=True)
    np.testing.assert_allclose(np.asarray(legacy), np.asarray(folded),
                               rtol=2e-3, atol=2e-3)


def test_folded_windowed_grads_finite():
    """Regression: inf in the exp VJP on fully-masked windowed blocks."""
    b, s, hq, hkv, d = 1, 64, 2, 1, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))

    def loss(q, k, v):
        o = tf.chunked_attention(q, k, v, causal=True, window=24,
                                 q_chunk=16, k_chunk=16, folded=True)
        return (o ** 2).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
