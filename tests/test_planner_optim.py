"""Placement planner + optimizers + frequency stats."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    DATA_PARALLEL, DISTRIBUTED, HYBRID, EmbeddingTableConfig, MeshConfig,
    TrainConfig,
)
from repro.core.embedding.frequency import FrequencyStats, apply_remap
from repro.core.embedding.planner import plan, resolve_strategies
from repro.optim.optimizers import make
from repro.optim.sparse import rowwise_adagrad

MESH = MeshConfig((16, 16), ("data", "model"))


def test_planner_tiny_table_replicates():
    t = EmbeddingTableConfig("tiny", 100, 16, strategy="auto")
    d = plan([t], MESH, 65536)
    assert d["tiny"].strategy == DATA_PARALLEL


def test_planner_huge_table_not_replicated():
    t = EmbeddingTableConfig("huge", 10_000_000, 128, strategy="auto")
    d = plan([t], MESH, 65536)
    assert d["huge"].strategy in (DISTRIBUTED, HYBRID)
    # memory estimate reflects sharding
    assert d["huge"].mem_bytes < 10_000_000 * 128 * 4


def test_planner_respects_pinned_strategy():
    t = EmbeddingTableConfig("pin", 1000, 8, strategy=DISTRIBUTED)
    d = plan([t], MESH, 1024)
    assert d["pin"].strategy == DISTRIBUTED
    assert "pinned" in d["pin"].note


def test_resolve_strategies_roundtrip():
    tabs = [EmbeddingTableConfig("a", 100, 8, strategy="auto"),
            EmbeddingTableConfig("b", 5_000_000, 64, strategy="auto")]
    out = resolve_strategies(tabs, MESH, 65536)
    assert all(t.strategy != "auto" for t in out)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "adam", "adamw"])
def test_dense_optimizer_descends_quadratic(name):
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.01)
    opt = make(name, cfg)
    p = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(p)
    for _ in range(100):
        g = {"w": 2 * p["w"]}
        p, state = opt.update(g, state, p)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_rowwise_adagrad_state_is_one_scalar_per_row():
    opt = rowwise_adagrad(TrainConfig())
    p = {"t": jnp.zeros((100, 64))}
    state = opt.init(p)
    assert state["acc"]["t"].shape == (100,)   # D× smaller than Adam


def test_rowwise_adagrad_adapts_per_row():
    opt = rowwise_adagrad(TrainConfig(learning_rate=1.0))
    p = {"t": jnp.zeros((2, 4))}
    state = opt.init(p)
    g = jnp.stack([jnp.full((4,), 10.0), jnp.full((4,), 0.1)])
    p2, _ = opt.update({"t": g}, state, p)
    d = np.abs(np.asarray(p2["t"]))
    # adagrad normalizes: both rows move ~lr despite 100x gradient gap
    np.testing.assert_allclose(d[0], d[1], rtol=1e-3)


# ---------------------------------------------------------------------------
# Frequency stats (hot/cold machinery)
# ---------------------------------------------------------------------------

def test_frequency_remap_sorts_by_count():
    fs = FrequencyStats([10])
    ids = np.asarray([[[7, 7, 7]], [[7, 2, -1]], [[2, 5, -1]]], np.int32)
    fs.update(ids)
    remap = fs.remap(0)
    assert remap[7] == 0          # most frequent -> rank 0
    assert remap[2] == 1
    assert remap[5] == 2
    out = apply_remap(ids, [remap])
    assert (out[ids == 7] == 0).all()
    assert (out[ids == -1] == -1).all()


def test_frequency_coverage_estimate():
    fs = FrequencyStats([100])
    rng = np.random.default_rng(0)
    ids = rng.zipf(1.5, (1000, 1, 1)).clip(1, 100).astype(np.int32) - 1
    fs.update(ids)
    cov_10 = fs.coverage(0, 0.10)
    cov_50 = fs.coverage(0, 0.50)
    assert 0 < cov_10 < cov_50 <= 1.0
    assert cov_10 > 0.10          # Zipf: top 10% covers way more than 10%
