"""Model-parallel training through the graph API (the PR 9 tentpole).

``Solver(mesh_shape=...)`` must carry all the way into ``fit()``: the
loss trajectory on a forced-host (2,2) mesh has to match the
single-device run (gspmd mode is bit-exact up to one f32 ulp per
reduction; we allow 1e-5), checkpoints must move between mesh sizes,
and the N-group models must deploy and serve from a mesh-trained state.

Multi-device runs live in subprocesses (XLA_FLAGS set before the jax
import); the pytest process keeps its single real device. Validation
errors are cheap and run in-process.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n_devices: int = 4, timeout: int = 600):
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        + body
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


# ---------------------------------------------------------------------------
# fit() parity: (2,2) mesh vs single device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dlrm_criteo", "wdl_criteo",
                                  "twotower_criteo"])
def test_mp_fit_matches_single_device(arch):
    out = run_with_devices(rf"""
import importlib
from repro.api import Solver

mod = importlib.import_module("repro.configs.{arch}")
losses = {{}}
for shape in ((1, 1), (2, 2)):
    m = mod.build_model(smoke=True, solver=Solver(
        batch_size=32, lr=1e-2, mesh_shape=shape))
    m.compile()
    losses[shape] = [h["loss"] for h in m.fit(steps=4)]
dev = max(abs(a - b)
          for a, b in zip(losses[(1, 1)], losses[(2, 2)]))
assert dev <= 1e-5, (dev, losses)
print("PARITY_OK", dev)
""")
    assert "PARITY_OK" in out


def test_mp_manual_mode_tracks_gspmd():
    """manual mode (explicit psum, one shard_map) on the (2,2) mesh
    stays within fp tolerance of the single-device gspmd run."""
    out = run_with_devices(r"""
import importlib
from repro.api import Solver

mod = importlib.import_module("repro.configs.dlrm_criteo")
ref = mod.build_model(smoke=True, solver=Solver(batch_size=32, lr=1e-2))
ref.compile()
href = [h["loss"] for h in ref.fit(steps=4)]
m = mod.build_model(smoke=True, solver=Solver(
    batch_size=32, lr=1e-2, mesh_shape=(2, 2), mode="manual"))
m.compile()
hm = [h["loss"] for h in m.fit(steps=4)]
dev = max(abs(a - b) for a, b in zip(href, hm))
assert dev <= 5e-3, (dev, href, hm)
print("MANUAL_OK", dev)
""")
    assert "MANUAL_OK" in out


def test_mp_comm_choices_agree():
    """Both embedding exchange recipes produce the same training run —
    comm changes the collective schedule, never the math."""
    out = run_with_devices(r"""
import importlib
from repro.api import Solver

mod = importlib.import_module("repro.configs.dlrm_criteo")
runs = {}
for comm in ("allgather_rs", "all_to_all"):
    m = mod.build_model(smoke=True, solver=Solver(
        batch_size=32, lr=1e-2, mesh_shape=(2, 2), comm=comm))
    m.compile()
    runs[comm] = [h["loss"] for h in m.fit(steps=4)]
dev = max(abs(a - b) for a, b in
          zip(runs["allgather_rs"], runs["all_to_all"]))
assert dev <= 1e-5, (dev, runs)
print("COMM_OK", dev)
""")
    assert "COMM_OK" in out


# ---------------------------------------------------------------------------
# Elastic checkpoints + N-group deploy from a mesh-trained state
# ---------------------------------------------------------------------------

def test_mp_save_load_resumes_across_mesh_sizes(tmp_path):
    out = run_with_devices(rf"""
import importlib
import numpy as np
from repro.api import Model, Solver
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh

mod = importlib.import_module("repro.configs.neumf_criteo")
m = mod.build_model(smoke=True, solver=Solver(batch_size=32, lr=1e-2,
                                              mesh_shape=(2, 2)))
m.compile()
m.fit(steps=3)
b = SyntheticCTR(m.cfg, 8).batch(0)
p_mp = m.predict(b)
ck = {str(tmp_path)!r}
m.save(ck)

# load the (2,2)-trained weights onto a single device...
m1 = Model.load(ck, mesh=make_test_mesh((1, 1)))
np.testing.assert_array_equal(m1.predict(b), p_mp)
# ...and keep training there
h1 = m1.fit(steps=2)
assert all(np.isfinite(x["loss"]) for x in h1)

# and back onto a (4,1) mesh
m4 = Model.load(ck, mesh=make_test_mesh((4, 1)))
np.testing.assert_array_equal(m4.predict(b), p_mp)
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_mp_ngroup_fit_deploy_serve(tmp_path):
    """Three embedding groups of three dims, trained on a (2,2) mesh,
    deployed, and served from the rebuilt bundle — the acceptance bar
    for N-group lowering riding the MP trainer."""
    out = run_with_devices(rf"""
import importlib, os
import numpy as np
from repro.api import Solver
from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import build_server_from_config

mod = importlib.import_module("repro.configs.neumf_criteo")
m = mod.build_model(smoke=True, solver=Solver(batch_size=32, lr=1e-2,
                                              mesh_shape=(2, 2)))
m.compile()
assert len(m.cfg.extra_groups) == 2
assert len({{m.cfg.embedding_dim}} |
           {{g.dim for g in m.cfg.extra_groups}}) == 3
m.fit(steps=3)
b = SyntheticCTR(m.cfg, 8).batch(0)
want = m.predict(b)

dep = os.path.join({str(tmp_path)!r}, "dep")
server = m.deploy(dep, cache_capacity=256)
with m.mesh:
    live = server.predict(b["dense"], b["cat"])
np.testing.assert_array_equal(live, want)

srv, m2 = build_server_from_config(os.path.join(dep, "ps.json"))
with m2.mesh:
    got = srv.predict(b["dense"], b["cat"])
np.testing.assert_array_equal(got, want)
print("NGROUP_MP_OK")
""")
    assert "NGROUP_MP_OK" in out


# ---------------------------------------------------------------------------
# Up-front validation (in-process: errors must fire before any device
# work, so the single real device is all they need)
# ---------------------------------------------------------------------------

def test_solver_rejects_bad_mesh_shapes():
    from repro.api import GraphError, Solver
    with pytest.raises(GraphError, match="positive ints"):
        Solver(batch_size=8, mesh_shape=(0, 2))
    with pytest.raises(GraphError, match="positive ints"):
        Solver(batch_size=8, mesh_shape=())
    with pytest.raises(GraphError, match="positive ints"):
        Solver(batch_size=8, mesh_shape=(True, 1))
    with pytest.raises(GraphError, match="devices .* visible|only"):
        Solver(batch_size=8, mesh_shape=(64, 64))
    with pytest.raises(GraphError, match="mode"):
        Solver(batch_size=8, mode="magic")
    with pytest.raises(GraphError, match="comm"):
        Solver(batch_size=8, comm="carrier-pigeon")


def test_oversubscribed_mesh_error_names_the_fix():
    from repro.api import GraphError, Solver
    with pytest.raises(GraphError,
                       match="xla_force_host_platform_device_count"):
        Solver(batch_size=8, mesh_shape=(64, 64))


def test_compile_rejects_indivisible_batch():
    out = run_with_devices(r"""
import importlib
from repro.api import GraphError, Solver

mod = importlib.import_module("repro.configs.dlrm_criteo")
m = mod.build_model(smoke=True,
                    solver=Solver(batch_size=30, mesh_shape=(4, 1)))
try:
    m.compile()
    raise SystemExit("compile() accepted an indivisible batch")
except GraphError as e:
    msg = str(e)
assert "batch_size=30" in msg and "4" in msg and "data" in msg, msg
print("BATCH_DIV_OK")
""")
    assert "BATCH_DIV_OK" in out


def test_compile_rejects_unsplittable_localized_group():
    out = run_with_devices(r"""
from repro.api import (DataReaderParams, DenseLayer, GraphError, Input,
                       Model, SparseEmbedding, Solver)

m = Model(Solver(batch_size=32, mesh_shape=(2, 2)),
          DataReaderParams(num_dense_features=4), name="loc-bad")
m.add(Input(dense_dim=4))
# 3 localized tables cannot split over 4 devices
m.add(SparseEmbedding(vocab_sizes=[64, 64, 64], dim=8,
                      strategy="localized", top_name="emb"))
m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
m.add(DenseLayer("mlp", ["flat"], ["logit"], units=(1,)))
m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
try:
    m.compile()
    raise SystemExit("compile() accepted an unsplittable localized group")
except GraphError as e:
    msg = str(e)
assert "localized" in msg and "3" in msg and "4" in msg, msg
print("LOC_DIV_OK")
""")
    assert "LOC_DIV_OK" in out
