"""The stream-fed serve engine: ``submit()`` batching feeds the dense
net directly from ``HPS.lookup_stream`` (no caller-thread
materialization), and its predictions must be BIT-EXACT with the
unpipelined server across dlrm and wdl (the two-HPS wide branch) —
including under concurrent submits from multiple threads."""
import queue
import threading

import numpy as np
import pytest

from repro.api import Solver
from repro.data.synthetic import SyntheticCTR
from repro.serve.server import InferenceServer


def _build(arch):
    import importlib
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_"))
    m = mod.build_model(smoke=True,
                        solver=Solver(batch_size=16, lr=1e-2))
    m.compile()
    m.fit(steps=2)
    return m


@pytest.fixture(scope="module", params=["dlrm-criteo", "wdl-criteo"])
def served(request, tmp_path_factory):
    """One trained model + its deployed HPS, behind TWO servers over the
    SAME storage: the stream-fed engine under test and the unpipelined
    reference. Embedding values are identical at every storage level, so
    any prediction difference is the pipeline's fault."""
    m = _build(request.param)
    dep = str(tmp_path_factory.mktemp("dep_" + request.param))
    stream = m.deploy(dep, cache_capacity=256, max_batch=8)
    assert stream.engine == "stream"            # the default engine
    sync = InferenceServer(m.model, m.dense_params(), stream.hps,
                           wide_hps=stream.wide_hps, max_batch=8,
                           engine="sync")
    return m, stream, sync


def _requests(cfg, n, rows):
    data = [SyntheticCTR(cfg, rows, seed=100 + i) for i in range(n)]
    return [(d.batch(i)["dense"], d.batch(i)["cat"])
            for i, d in enumerate(data)]


def test_stream_submit_bitexact_with_sequential(served):
    """Pre-queued requests coalesce into deterministic groups of
    max_batch rows; every group's predictions must be bit-identical to
    the sequential server run on the same coalesced group."""
    m, stream, sync = served
    reqs = _requests(m.cfg, 6, 4)               # coalesce 2-by-2 into 8
    handles = [stream.submit(d, c) for d, c in reqs]
    stream.start()
    try:
        got = [h.get(timeout=120) for h in handles]
    finally:
        stream.stop()
    for i in range(0, 6, 2):                    # the drained groups
        dense = np.concatenate([reqs[i][0], reqs[i + 1][0]])
        cat = np.concatenate([reqs[i][1], reqs[i + 1][1]])
        want = sync.predict(dense, cat)
        np.testing.assert_array_equal(got[i], want[:4])
        np.testing.assert_array_equal(got[i + 1], want[4:])


def test_stream_submit_bitexact_under_concurrent_submits(served):
    """Multiple threads submitting at once: every response bit-exact
    with the sequential server's prediction for that request (max_batch
    == request rows, so each request is one device batch)."""
    m, stream, sync = served
    stream.max_batch = 8
    n_threads, per_thread, rows = 4, 5, 8
    results = {}
    errors = []

    def client(tid):
        try:
            data = SyntheticCTR(m.cfg, rows, seed=500 + tid)
            out = []
            for i in range(per_thread):
                b = data.batch(i)
                h = stream.submit(b["dense"], b["cat"])
                out.append((b, h.get(timeout=120)))
            results[tid] = out
        except Exception as e:                  # surfaced after join
            errors.append(e)

    stream.start()
    try:
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert not any(t.is_alive() for t in threads)
    finally:
        stream.stop()
    assert not errors, errors
    assert len(results) == n_threads
    for tid, out in results.items():
        for b, got in out:
            assert isinstance(got, np.ndarray), got
            want = sync.predict(b["dense"], b["cat"])
            np.testing.assert_array_equal(got, want)


def test_stream_predict_path_unchanged(served):
    """The synchronous predict() entry point stays bit-identical across
    engines (it never enters the pipeline)."""
    m, stream, sync = served
    b = SyntheticCTR(m.cfg, 8, seed=9).batch(0)
    np.testing.assert_array_equal(stream.predict(b["dense"], b["cat"]),
                                  sync.predict(b["dense"], b["cat"]))


def test_stage_sync_engine_bitexact(served):
    """The no-overlap benchmark reference engine serves the same bits."""
    m, stream, sync = served
    ss = InferenceServer(m.model, m.dense_params(), stream.hps,
                         wide_hps=stream.wide_hps, max_batch=8,
                         engine="stage_sync")
    b = SyntheticCTR(m.cfg, 8, seed=11).batch(3)
    want = sync.predict(b["dense"], b["cat"])
    h = ss.submit(b["dense"], b["cat"])
    ss.start()
    try:
        np.testing.assert_array_equal(h.get(timeout=120), want)
    finally:
        ss.stop()


def test_stream_burst_error_reaches_every_handle(served):
    """A poisoned request group must surface its exception to the
    waiting handles instead of hanging the callers or the loop."""
    m, stream, sync = served
    bad_cat = np.zeros((4, 2), np.int32)        # 2-D without hotness
    h = stream.submit(np.zeros((4, 1), np.float32), bad_cat)
    stream.start()
    try:
        out = h.get(timeout=120)
        assert isinstance(out, Exception)
        # and the loop survived: a good request still serves
        b = SyntheticCTR(m.cfg, 8, seed=21).batch(0)
        h2 = stream.submit(b["dense"], b["cat"])
        got = h2.get(timeout=120)
    finally:
        stream.stop()
    np.testing.assert_array_equal(got, sync.predict(b["dense"], b["cat"]))


def test_stream_dense_stage_error_reaches_own_handle(served):
    """A group that fails AFTER its lookup — in the dense net (dense
    rows != cat rows) — must still deliver the exception to its own
    handles: the group sits between fifo and in_flight when it dies."""
    m, stream, sync = served
    good = SyntheticCTR(m.cfg, 8, seed=31).batch(0)
    bad_dense = good["dense"][:3]               # 3 dense rows, 8 cat rows
    h = stream.submit(bad_dense, good["cat"])
    stream.start()
    try:
        out = h.get(timeout=120)
        assert isinstance(out, Exception), out
        h2 = stream.submit(good["dense"], good["cat"])  # loop survived
        got = h2.get(timeout=120)
    finally:
        stream.stop()
    np.testing.assert_array_equal(
        got, sync.predict(good["dense"], good["cat"]))


@pytest.mark.parametrize("engine", ["stream", "sync"])
def test_uncoalesceable_requests_error_all_handles(served, engine):
    """Requests whose widths cannot concatenate into one group must
    error BOTH handles and leave the serve loop alive — on every
    engine (the coalescer itself owns that delivery)."""
    m, stream, sync = served
    srv = InferenceServer(m.model, m.dense_params(), stream.hps,
                          wide_hps=stream.wide_hps, max_batch=64,
                          engine=engine)
    T = len(m.cfg.tables)
    h1 = srv.submit(np.zeros((4, 13), np.float32),
                    np.zeros((4, T, 1), np.int32))
    h2 = srv.submit(np.zeros((4, 13), np.float32),
                    np.zeros((4, T, 2), np.int32))    # width mismatch
    srv.start()
    try:
        assert isinstance(h1.get(timeout=120), Exception)
        assert isinstance(h2.get(timeout=120), Exception)
        b = SyntheticCTR(m.cfg, 8, seed=41).batch(0)  # loop survived
        got = srv.submit(b["dense"], b["cat"]).get(timeout=120)
    finally:
        srv.stop()
    np.testing.assert_array_equal(got, sync.predict(b["dense"], b["cat"]))


def test_engine_validated():
    with pytest.raises(ValueError, match="engine"):
        InferenceServer(object(), {}, None, engine="warp")
