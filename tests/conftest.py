"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) CPU device. Multi-device behaviour is exercised by the
subprocess tests in test_distributed.py."""
import jax
import numpy as np
import pytest

from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="session")
def mesh1():
    return make_test_mesh((1, 1))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
