"""EmbeddingCollection strategy correctness on a single-device mesh.

Every strategy path (dp / distributed ag_rs / distributed a2a / localized /
hybrid) must agree with the strategy-free reference oracle, including
gradients. Multi-device behaviour is covered by test_distributed.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    DATA_PARALLEL, DISTRIBUTED, HYBRID, LOCALIZED, EmbeddingTableConfig,
)
from repro.core.embedding import EmbeddingCollection
from repro.launch.mesh import make_test_mesh


def _tables(strategy, n=3, vocab=50, dim=8, hotness=3):
    return [EmbeddingTableConfig(f"t{i}", vocab + 7 * i, dim,
                                 hotness=hotness, strategy=strategy,
                                 hot_fraction=0.2)
            for i in range(n)]


def _ids(key, tables, b=16):
    h = max(t.hotness for t in tables)
    cols = []
    for t in tables:
        ids = jax.random.randint(key, (b, 1, h), -1, t.vocab_size)
        cols.append(ids)
        key = jax.random.fold_in(key, 1)
    return jnp.concatenate(cols, axis=1)


@pytest.mark.parametrize("strategy,comm", [
    (DATA_PARALLEL, "allgather_rs"),
    (DISTRIBUTED, "allgather_rs"),
    (DISTRIBUTED, "all_to_all"),
    (LOCALIZED, "allgather_rs"),
    (HYBRID, "allgather_rs"),
    (HYBRID, "all_to_all"),
])
def test_strategy_matches_reference(strategy, comm):
    mesh = make_test_mesh((1, 1))
    tables = _tables(strategy)
    with mesh:
        coll = EmbeddingCollection(tables, mesh, comm=comm)
        params = coll.init(jax.random.PRNGKey(0))
        ids = _ids(jax.random.PRNGKey(1), tables)
        got = coll.lookup(params, ids)
        want = coll.lookup_reference(params, ids)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("strategy,comm", [
    (DISTRIBUTED, "allgather_rs"),
    (DISTRIBUTED, "all_to_all"),
    (HYBRID, "allgather_rs"),
])
def test_strategy_grads_match_reference(strategy, comm):
    mesh = make_test_mesh((1, 1))
    tables = _tables(strategy, n=2)
    with mesh:
        coll = EmbeddingCollection(tables, mesh, comm=comm)
        params = coll.init(jax.random.PRNGKey(0))
        ids = _ids(jax.random.PRNGKey(1), tables, b=8)

        def loss(fn):
            def inner(p):
                out = fn(p, ids)
                return (out.astype(jnp.float32) ** 2).sum()
            return inner

        g1 = jax.grad(loss(coll.lookup))(params)
        g2 = jax.grad(loss(coll.lookup_reference))(params)
        for k in g1:
            np.testing.assert_allclose(
                np.asarray(g1[k]), np.asarray(g2[k]), rtol=1e-5, atol=1e-5,
                err_msg=f"grad mismatch for group {k}")


def test_mean_combiner():
    mesh = make_test_mesh((1, 1))
    tables = [EmbeddingTableConfig("m", 40, 8, hotness=4, combiner="mean",
                                   strategy=DATA_PARALLEL)]
    with mesh:
        coll = EmbeddingCollection(tables, mesh)
        params = coll.init(jax.random.PRNGKey(0))
        ids = jnp.asarray([[[3, 7, -1, -1]], [[5, -1, -1, -1]]], jnp.int32)
        out = np.asarray(coll.lookup(params, ids))
        tab = np.asarray(params["dp"])
        np.testing.assert_allclose(out[0, 0], (tab[3] + tab[7]) / 2,
                                   rtol=1e-5)
        np.testing.assert_allclose(out[1, 0], tab[5], rtol=1e-5)


def test_mixed_strategies_one_collection():
    mesh = make_test_mesh((1, 1))
    tables = (_tables(DATA_PARALLEL, 1) + _tables(DISTRIBUTED, 2)
              + _tables(HYBRID, 1))
    # rename to be unique
    import dataclasses
    tables = [dataclasses.replace(t, name=f"t{i}")
              for i, t in enumerate(tables)]
    with mesh:
        coll = EmbeddingCollection(tables, mesh)
        params = coll.init(jax.random.PRNGKey(0))
        ids = _ids(jax.random.PRNGKey(1), tables)
        got = coll.lookup(params, ids)
        want = coll.lookup_reference(params, ids)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # output column order matches the original table order
        assert got.shape == (16, len(tables), 8)


def test_striped_layout_roundtrip():
    mesh = make_test_mesh((1, 1))
    tables = _tables(DISTRIBUTED, 2)
    with mesh:
        coll = EmbeddingCollection(tables, mesh, comm="all_to_all")
        params = coll.init(jax.random.PRNGKey(0))
        rt = coll.from_logical(coll.to_logical(params))
        np.testing.assert_array_equal(np.asarray(rt["dist"]),
                                      np.asarray(params["dist"]))


def test_export_import_logical_roundtrip():
    mesh = make_test_mesh((1, 1))
    tables = _tables(HYBRID, 2)
    with mesh:
        coll = EmbeddingCollection(tables, mesh, comm="all_to_all")
        params = coll.init(jax.random.PRNGKey(0))
        logical = coll.export_logical(params)
        back = coll.import_logical(logical)
        for k in params:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(params[k]))


def test_unresolved_auto_strategy_raises():
    mesh = make_test_mesh((1, 1))
    tables = [EmbeddingTableConfig("a", 10, 4, strategy="auto")]
    with pytest.raises(ValueError, match="planner"):
        EmbeddingCollection(tables, mesh)
