"""Per-kernel allclose vs the pure-jnp oracles (interpret mode on CPU),
with shape/dtype sweeps per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels import embedding_lookup as el
from repro.kernels import dot_interaction as di


# ---------------------------------------------------------------------------
# fused_embedding_lookup
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,h", [
    (64, 8, 16, 1),        # one-hot
    (1000, 64, 37, 3),     # multi-hot, non-aligned batch
    (513, 16, 8, 7),       # vocab not multiple of block
    (2048, 128, 128, 2),   # aligned, MXU-shaped
])
def test_lookup_matches_oracle(v, d, b, h):
    table = jax.random.normal(jax.random.PRNGKey(0), (v, d), jnp.float32)
    rows = jax.random.randint(jax.random.PRNGKey(1), (b, h), -1, v)
    out = ops.fused_embedding_lookup(table, rows)
    expected = ref.embedding_lookup_ref(table, rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lookup_dtypes(dtype):
    v, d, b, h = 256, 32, 24, 2
    table = jax.random.normal(jax.random.PRNGKey(0), (v, d)).astype(dtype)
    rows = jax.random.randint(jax.random.PRNGKey(1), (b, h), -1, v)
    out = ops.fused_embedding_lookup(table, rows)
    expected = ref.embedding_lookup_ref(table.astype(jnp.float32), rows)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=tol, atol=tol)


def test_lookup_grad_matches_oracle():
    v, d, b, h = 300, 24, 19, 4
    table = jax.random.normal(jax.random.PRNGKey(0), (v, d), jnp.float32)
    rows = jax.random.randint(jax.random.PRNGKey(1), (b, h), -1, v)

    def loss_k(t):
        return (ops.fused_embedding_lookup(t, rows) ** 2).sum()

    def loss_r(t):
        return (ref.embedding_lookup_ref(t, rows) ** 2).sum()

    g1 = jax.grad(loss_k)(table)
    g2 = jax.grad(loss_r)(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-5, atol=1e-5)


def test_lookup_all_padding_rows():
    table = jnp.ones((64, 8), jnp.float32)
    rows = jnp.full((4, 3), -1, jnp.int32)
    out = ops.fused_embedding_lookup(table, rows)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_lookup_duplicate_ids_count_semantics():
    table = jax.random.normal(jax.random.PRNGKey(0), (32, 4))
    rows = jnp.asarray([[5, 5, 5]], jnp.int32)
    out = ops.fused_embedding_lookup(table, rows)
    np.testing.assert_allclose(np.asarray(out)[0], 3 * np.asarray(table)[5],
                               rtol=1e-6)


def test_lookup_bwd_kernel_direct():
    """The raw bwd kernel equals the scatter-add oracle."""
    v, d, b, h = 512, 16, 128, 2
    rows = jax.random.randint(jax.random.PRNGKey(1), (b, h), -1, v)
    dpool = jax.random.normal(jax.random.PRNGKey(2), (b, d), jnp.float32)
    got = el.lookup_bwd((v, d), rows, dpool, block_b=64, block_v=128,
                        interpret=True)
    want = ref.embedding_grad_ref((v, d), rows, dpool)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_b,block_v", [(8, 64), (64, 512), (128, 128)])
def test_lookup_block_shape_sweep(block_b, block_v):
    v, d, b, h = 640, 32, 96, 2
    table = jax.random.normal(jax.random.PRNGKey(0), (v, d), jnp.float32)
    rows = jax.random.randint(jax.random.PRNGKey(1), (b, h), -1, v)
    out = ops.fused_embedding_lookup(table, rows, block_b, block_v)
    expected = ref.embedding_lookup_ref(table, rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dot_interaction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,d", [(8, 4, 16), (37, 27, 128), (64, 14, 16)])
def test_interaction_matches_oracle(b, f, d):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, f, d), jnp.float32)
    out = ops.dot_interaction(x)
    expected = ref.dot_interaction_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


def test_interaction_self_interaction():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 5, 16), jnp.float32)
    out = ops.dot_interaction(x, True)
    expected = ref.dot_interaction_ref(x, self_interaction=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


def test_interaction_grad_matches_oracle():
    x = jax.random.normal(jax.random.PRNGKey(0), (12, 9, 8), jnp.float32)

    def lk(x):
        return (ops.dot_interaction(x) ** 2).sum()

    def lr(x):
        return (ref.dot_interaction_ref(x) ** 2).sum()

    g1, g2 = jax.grad(lk)(x), jax.grad(lr)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interaction_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8, 32)).astype(dtype)
    out = ops.dot_interaction(x)
    expected = ref.dot_interaction_ref(x.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
def test_flash_attention_fwd(causal, window):
    b, s, hq, hkv, d = 2, 64, 4, 2, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    o = ops.flash_attention(q, k, v, causal, window, 16, 16)
    want = ref.flash_attention_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hq,hkv,s,d,bq,bk", [
    (4, 4, 32, 16, 8, 8),      # MHA
    (6, 2, 64, 32, 16, 32),    # GQA, uneven blocks
    (8, 1, 32, 64, 32, 16),    # MQA
])
def test_flash_attention_shape_sweep(hq, hkv, s, d, bq, bk):
    b = 2
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    o = ops.flash_attention(q, k, v, True, None, bq, bk)
    want = ref.flash_attention_ref(q, k, v, True, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    b, s, hq, hkv, d = 1, 32, 2, 2, 16
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, s, hq, d)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, hkv, d)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (b, s, hkv, d)).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, True, None, 16, 16)
    want = ref.flash_attention_ref(q, k, v, True, None)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_grads():
    b, s, hq, hkv, d = 2, 32, 4, 2, 16
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))

    def lk(q, k, v):
        return (ops.flash_attention(q, k, v, True, None, 16, 16) ** 2).sum()

    def lr(q, k, v):
        return (ref.flash_attention_ref(q, k, v, True, None) ** 2).sum()

    gk = jax.grad(lk, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b_, n in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{n} mismatch")


def test_flash_matches_chunked_attention():
    """The Pallas kernel and the jnp chunked path are interchangeable."""
    from repro.models.lm.transformer import chunked_attention
    b, s, hq, hkv, d = 2, 48, 4, 2, 16
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    o1 = ops.flash_attention(q, k, v, True, None, 16, 16)
    o2 = chunked_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=5e-3, atol=5e-3)


def test_kernel_pool_matches_collection_pool():
    """kernel_pool is a drop-in for pooled_local_lookup."""
    from repro.core.embedding.common import pooled_local_lookup
    mega = jax.random.normal(jax.random.PRNGKey(0), (400, 16))
    rows = jax.random.randint(jax.random.PRNGKey(1), (6, 5, 3), -1, 400)
    got = ops.kernel_pool(mega, rows)
    want = pooled_local_lookup(mega, rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
