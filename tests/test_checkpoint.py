"""Checkpointing: atomicity, integrity, async overlap, retention."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones((5,), np.int32),
                       "c": np.asarray(2.5, np.float32)}}


def test_save_load_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ck.save(d, 7, tree, meta={"note": "x"})
    flat, manifest = ck.load(d, 7)
    assert manifest["step"] == 7 and manifest["meta"]["note"] == "x"
    out = ck.unflatten_like(tree, flat)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, out)


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    ck.save(d, 0, _tree())
    # flip bits in the npz payload
    path = os.path.join(d, "step_0000000000", "arrays.npz")
    data = np.load(path)
    arrays = {k: data[k].copy() for k in data.files}
    arrays["a"][0, 0] += 1
    np.savez(path, **arrays)
    with pytest.raises(IOError, match="corruption"):
        ck.load(d, 0)
    # but skipping verification still loads
    flat, _ = ck.load(d, 0, verify=False)
    assert flat["a"][0, 0] == 1.0


def test_tmpdir_crash_leaves_no_partial_checkpoint(tmp_path):
    d = str(tmp_path)
    # a stale tmp dir from a crashed save must not count as a checkpoint
    os.makedirs(os.path.join(d, ".tmp_step_0000000005"))
    assert ck.list_checkpoints(d) == []
    ck.save(d, 5, _tree())     # overwrites the stale tmp, then renames
    assert ck.list_checkpoints(d) == [5]


def test_retention_keeps_last_k(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ck.save(d, s, _tree(), keep_last=3)
    assert ck.list_checkpoints(d) == [3, 4, 5]
    assert ck.latest_step(d) == 5


def test_async_saver_overlap_and_error_surfacing(tmp_path):
    d = str(tmp_path)
    saver = ck.AsyncSaver(d)
    saver.save(1, _tree())
    saver.wait()
    assert ck.latest_step(d) == 1
    # errors surface on the *next* wait
    saver.directory = "/proc/definitely/not/writable"
    saver.save(2, _tree())
    with pytest.raises(BaseException):
        saver.wait()


def test_async_saver_snapshots_before_mutation(tmp_path):
    """The saver must snapshot values at save() time (donation safety)."""
    d = str(tmp_path)
    saver = ck.AsyncSaver(d)
    arr = np.zeros((4,), np.float32)
    saver.save(3, {"x": arr})
    arr += 99.0              # mutate after save() returns
    saver.wait()
    flat, _ = ck.load(d, 3)
    np.testing.assert_array_equal(flat["x"], 0.0)
