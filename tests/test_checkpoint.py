"""Checkpointing: atomicity, integrity, async overlap, retention."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones((5,), np.int32),
                       "c": np.asarray(2.5, np.float32)}}


def test_save_load_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ck.save(d, 7, tree, meta={"note": "x"})
    flat, manifest = ck.load(d, 7)
    assert manifest["step"] == 7 and manifest["meta"]["note"] == "x"
    out = ck.unflatten_like(tree, flat)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, out)


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    ck.save(d, 0, _tree())
    # flip bits in the npz payload
    path = os.path.join(d, "step_0000000000", "arrays.npz")
    data = np.load(path)
    arrays = {k: data[k].copy() for k in data.files}
    arrays["a"][0, 0] += 1
    np.savez(path, **arrays)
    with pytest.raises(IOError, match="corruption"):
        ck.load(d, 0)
    # but skipping verification still loads
    flat, _ = ck.load(d, 0, verify=False)
    assert flat["a"][0, 0] == 1.0


def test_tmpdir_crash_leaves_no_partial_checkpoint(tmp_path):
    d = str(tmp_path)
    # a stale tmp dir from a crashed save must not count as a checkpoint
    os.makedirs(os.path.join(d, ".tmp_step_0000000005"))
    assert ck.list_checkpoints(d) == []
    ck.save(d, 5, _tree())     # overwrites the stale tmp, then renames
    assert ck.list_checkpoints(d) == [5]


def test_retention_keeps_last_k(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ck.save(d, s, _tree(), keep_last=3)
    assert ck.list_checkpoints(d) == [3, 4, 5]
    assert ck.latest_step(d) == 5


def test_async_saver_overlap_and_error_surfacing(tmp_path):
    d = str(tmp_path)
    saver = ck.AsyncSaver(d)
    saver.save(1, _tree())
    saver.wait()
    assert ck.latest_step(d) == 1
    # errors surface on the *next* wait
    saver.directory = "/proc/definitely/not/writable"
    saver.save(2, _tree())
    with pytest.raises(BaseException):
        saver.wait()


def test_async_saver_snapshots_before_mutation(tmp_path):
    """The saver must snapshot values at save() time (donation safety)."""
    d = str(tmp_path)
    saver = ck.AsyncSaver(d)
    arr = np.zeros((4,), np.float32)
    saver.save(3, {"x": arr})
    arr += 99.0              # mutate after save() returns
    saver.wait()
    flat, _ = ck.load(d, 3)
    np.testing.assert_array_equal(flat["x"], 0.0)


# ---------------------------------------------------------------------------
# Logical (mesh-independent) embedding checkpoints: pad-row hygiene
# ---------------------------------------------------------------------------

def test_import_logical_truncates_and_rejects_short_ckpt():
    """``import_logical`` must size the physical arrays from the
    COLLECTION, not the checkpoint: over-long checkpoints (e.g. written
    by a buggy exporter that kept a foreign mesh's pad rows) are
    truncated to the logical row count, and short ones raise naming the
    row counts instead of mis-striping silently."""
    from repro.configs.base import EmbeddingTableConfig
    from repro.core.embedding.collection import EmbeddingCollection
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((1, 1))
    tables = [EmbeddingTableConfig("t0", 101, 8, hotness=1,
                                   strategy="distributed")]
    with mesh:
        coll = EmbeddingCollection(tables, mesh, shard_axes="all")
        clean = {"dist": np.random.default_rng(0)
                 .normal(size=(101, 8)).astype(np.float32)}
        p_clean = coll.import_logical(clean)
        overlong = {"dist": np.concatenate(
            [clean["dist"], np.full((3, 8), 777.0, np.float32)])}
        p_over = coll.import_logical(overlong)
        for k in p_clean:
            np.testing.assert_array_equal(np.asarray(p_clean[k]),
                                          np.asarray(p_over[k]))
        with pytest.raises(ValueError, match="100 rows, need 101"):
            coll.import_logical({"dist": clean["dist"][:100]})


def test_import_logical_mesh_round_trip_zeroes_pads():
    """Regression for the elastic-resume bug: a checkpoint written on
    mesh (1,1) and imported on (2,2) (whose sharded layout rounds rows
    UP per shard) must land with every physical pad row exactly zero —
    logical AND physical round trips are bit-exact in both directions."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    body = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax
import numpy as np
from repro.configs.base import EmbeddingTableConfig
from repro.core.embedding.collection import EmbeddingCollection
from repro.launch.mesh import make_test_mesh

tables = [EmbeddingTableConfig(f"t{i}", 1001, 8, hotness=1,
                               strategy="distributed") for i in range(2)]
colls = {}
for name, shape in (("22", (2, 2)), ("11", (1, 1))):
    mesh = make_test_mesh(shape)
    with mesh:
        colls[name] = (mesh, EmbeddingCollection(tables, mesh,
                                                 shard_axes="all"))
(m22, c22), (m11, c11) = colls["22"], colls["11"]
with m22:
    p22 = c22.init(jax.random.PRNGKey(0))
log = {k: np.asarray(v) for k, v in c22.export_logical(p22).items()}
assert log["dist"].shape[0] == 2002, log["dist"].shape

# (2,2) -> (1,1): logical payloads survive the mesh change bit-exactly
with m11:
    p11 = c11.import_logical(log)
log11 = {k: np.asarray(v) for k, v in c11.export_logical(p11).items()}
for k in log:
    np.testing.assert_array_equal(log[k], log11[k])

# (1,1) -> (2,2): physical arrays (pad rows INCLUDED) match a fresh
# import of the same logical state — pads are provably zeroed, never
# stale garbage from whatever the checkpoint carried
with m22:
    p22a = c22.import_logical(log)
    p22b = c22.import_logical(log11)
for k in p22a:
    a, b = np.asarray(p22a[k]), np.asarray(p22b[k])
    assert a.shape == b.shape and a.shape[0] == 2004, (k, a.shape)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, np.asarray(p22[k]))
print("PAD_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}"
        f"\nSTDERR:\n{proc.stderr}")
    assert "PAD_OK" in proc.stdout
