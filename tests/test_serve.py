"""Inference path: deploy-from-training -> HPS -> batched server, plus
training/serving parity (the server must produce the same predictions as
the training-graph forward pass)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus, Producer
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training


@pytest.fixture(scope="module")
def deployed(tmp_path_factory):
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=16)
        params = model.init(jax.random.PRNGKey(0))
        pdb = PersistentDB(str(tmp_path_factory.mktemp("pdb")))
        deploy_from_training(model, params, pdb, "dlrm")
        hps = HPS("dlrm", cfg.tables, pdb, cache_capacity=64)
        dense_params = {k: v for k, v in params.items() if k != "embedding"}
        server = InferenceServer(model, dense_params, hps)
    return cfg, mesh, model, params, pdb, hps, server


def test_deploy_preserves_tables(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    logical = model.embedding.export_logical(params["embedding"])
    # reconstruct one table from the PDB and compare to training params
    t = cfg.tables[0]
    rows = pdb.fetch("dlrm", t.name, np.arange(t.vocab_size))
    want = model.embedding.lookup_reference(
        params["embedding"],
        jnp.asarray(np.stack(
            [np.arange(t.vocab_size)[:, None]]
            + [np.full((t.vocab_size, 1), -1)] * (cfg.num_tables - 1),
            axis=1), jnp.int32))
    np.testing.assert_allclose(rows, np.asarray(want)[:, 0, :],
                               rtol=1e-5, atol=1e-6)


def test_server_matches_training_forward(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    batch = SyntheticCTR(cfg, 32).batch(0)
    with mesh:
        want = jax.nn.sigmoid(model.apply(
            params, {k: jnp.asarray(v) for k, v in batch.items()}))
        got = server.predict(batch["dense"], batch["cat"])
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-2, atol=2e-2)
    assert server.latency_percentiles()["p50"] > 0


def test_server_batching_queue(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    server.start()
    try:
        batches = [SyntheticCTR(cfg, 4, seed=i).batch(0) for i in range(5)]
        handles = [server.submit(b["dense"], b["cat"]) for b in batches]
        outs = [h.get(timeout=60) for h in handles]
        for b, o in zip(batches, outs):
            assert o.shape == (4,)
            assert np.isfinite(o).all()
    finally:
        server.stop()


def test_cache_hit_rate_improves_with_zipf(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    ds = SyntheticCTR(cfg, 64)
    for step in range(5):
        server.predict(**{k: v for k, v in ds.batch(step).items()
                          if k in ("dense", "cat")})
    stats = hps.stats()
    # Zipf access: after warmup the L1 should be hitting
    assert np.mean(list(stats["l1_hit_rate"].values())) > 0.3


def test_online_update_reaches_server(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    bus = MessageBus()
    hps2 = HPS("dlrm", cfg.tables, pdb, cache_capacity=64, bus=bus)
    t = cfg.tables[0]
    cat = np.full((1, cfg.num_tables, 2), -1, np.int32)
    cat[0, 0, 0] = 5
    before = np.asarray(hps2.lookup(cat))[0, 0]

    prod = Producer(bus, "dlrm")
    prod.send(t.name, np.asarray([5]),
              np.full((1, t.dim), 1234.5, np.float32))
    prod.flush()
    assert hps2.apply_updates() == 1
    hps2.refresh_caches()
    after = np.asarray(hps2.lookup(cat))[0, 0]
    np.testing.assert_allclose(after, 1234.5)
    assert not np.allclose(before, after)
