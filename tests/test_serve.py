"""Inference path: deploy-from-training -> HPS -> batched server, plus
training/serving parity (the server must produce the same predictions as
the training-graph forward pass)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus, Producer
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training


@pytest.fixture(scope="module")
def deployed(tmp_path_factory):
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=16)
        params = model.init(jax.random.PRNGKey(0))
        pdb = PersistentDB(str(tmp_path_factory.mktemp("pdb")))
        deploy_from_training(model, params, pdb, "dlrm")
        hps = HPS("dlrm", cfg.tables, pdb, cache_capacity=64)
        dense_params = {k: v for k, v in params.items() if k != "embedding"}
        server = InferenceServer(model, dense_params, hps)
    return cfg, mesh, model, params, pdb, hps, server


def test_deploy_preserves_tables(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    logical = model.embedding.export_logical(params["embedding"])
    # reconstruct one table from the PDB and compare to training params
    t = cfg.tables[0]
    rows = pdb.fetch("dlrm", t.name, np.arange(t.vocab_size))
    want = model.embedding.lookup_reference(
        params["embedding"],
        jnp.asarray(np.stack(
            [np.arange(t.vocab_size)[:, None]]
            + [np.full((t.vocab_size, 1), -1)] * (cfg.num_tables - 1),
            axis=1), jnp.int32))
    np.testing.assert_allclose(rows, np.asarray(want)[:, 0, :],
                               rtol=1e-5, atol=1e-6)


def test_server_matches_training_forward(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    batch = SyntheticCTR(cfg, 32).batch(0)
    with mesh:
        want = jax.nn.sigmoid(model.apply(
            params, {k: jnp.asarray(v) for k, v in batch.items()}))
        got = server.predict(batch["dense"], batch["cat"])
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-2, atol=2e-2)
    assert server.latency_percentiles()["p50"] > 0


def test_server_batching_queue(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    server.start()
    try:
        batches = [SyntheticCTR(cfg, 4, seed=i).batch(0) for i in range(5)]
        handles = [server.submit(b["dense"], b["cat"]) for b in batches]
        outs = [h.get(timeout=60) for h in handles]
        for b, o in zip(batches, outs):
            assert o.shape == (4,)
            assert np.isfinite(o).all()
    finally:
        server.stop()


def test_cache_hit_rate_improves_with_zipf(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    ds = SyntheticCTR(cfg, 64)
    for step in range(5):
        server.predict(**{k: v for k, v in ds.batch(step).items()
                          if k in ("dense", "cat")})
    stats = hps.stats()
    # Zipf access: after warmup the L1 should be hitting
    assert np.mean(list(stats["l1_hit_rate"].values())) > 0.3


def test_online_update_reaches_server(deployed):
    cfg, mesh, model, params, pdb, hps, server = deployed
    bus = MessageBus()
    hps2 = HPS("dlrm", cfg.tables, pdb, cache_capacity=64, bus=bus)
    t = cfg.tables[0]
    cat = np.full((1, cfg.num_tables, 2), -1, np.int32)
    cat[0, 0, 0] = 5
    before = np.asarray(hps2.lookup(cat))[0, 0]

    prod = Producer(bus, "dlrm")
    prod.send(t.name, np.asarray([5]),
              np.full((1, t.dim), 1234.5, np.float32))
    prod.flush()
    assert hps2.apply_updates() == 1
    hps2.refresh_caches()
    after = np.asarray(hps2.lookup(cat))[0, 0]
    np.testing.assert_allclose(after, 1234.5)
    assert not np.allclose(before, after)


# ---------------------------------------------------------------------------
# Ensemble bundles: several models served from ONE storage backend
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ensemble(tmp_path_factory):
    """dlrm + wdl trained briefly, deployed as ONE ensemble bundle with
    a shared VDB/PDB/bus. Both smoke recipes name their tables C1..C6,
    so any missing model-scoping at any storage level shows up as
    cross-model corruption immediately."""
    from repro.api import Solver, deploy_ensemble
    from repro.configs import dlrm_criteo, wdl_criteo
    models = []
    for mod in (dlrm_criteo, wdl_criteo):
        m = mod.build_model(smoke=True,
                            solver=Solver(batch_size=16, lr=1e-2))
        m.compile()
        m.fit(steps=2)
        models.append(m)
    d = str(tmp_path_factory.mktemp("ens"))
    bus = MessageBus()
    server = deploy_ensemble(models, d, cache_capacity=128, bus=bus)
    return models, d, bus, server


def _probe_batches(models):
    return {m.name: SyntheticCTR(m.cfg, 8, seed=3).batch(7)
            for m in models}


def test_ensemble_bundle_roundtrip(ensemble):
    """deploy_ensemble -> ps.json -> build_server_from_config: the
    rebuilt multi-model server matches the in-process one bit-exactly
    for every member model."""
    import os
    from repro.launch.serve import build_server_from_config
    models, d, bus, server = ensemble
    batches = _probe_batches(models)
    rebuilt, loaded = build_server_from_config(os.path.join(d, "ps.json"))
    assert sorted(rebuilt.models) == sorted(m.name for m in models)
    for m in models:
        b = batches[m.name]
        want = server.predict(m.name, b["dense"], b["cat"])
        got = rebuilt.predict(m.name, b["dense"], b["cat"])
        np.testing.assert_array_equal(got, want)
        assert loaded[m.name].cfg == m.cfg


def test_ensemble_matches_independent_servers(ensemble, tmp_path):
    """Sharing one VDB/PDB process across models shares bytes, not
    values: the ensemble server is bit-exact with two fully independent
    per-model in-process deployments."""
    models, d, bus, server = ensemble
    batches = _probe_batches(models)
    for m in models:
        solo = m.deploy(str(tmp_path / ("solo_" + m.name)),
                        cache_capacity=128)
        b = batches[m.name]
        np.testing.assert_array_equal(
            server.predict(m.name, b["dense"], b["cat"]),
            solo.predict(b["dense"], b["cat"]))


def test_ensemble_ps_json_contents(ensemble):
    import json
    import os
    models, d, bus, server = ensemble
    with open(os.path.join(d, "ps.json")) as f:
        doc = json.load(f)
    assert doc["format"] == "repro-ps-ensemble-v1"
    assert [e["model"] for e in doc["models"]] == \
        [m.name for m in models]
    # one shared storage root; per-model graph/dense artifacts
    assert {e["pdb_root"] for e in doc["models"]} == {"pdb"}
    for m, e in zip(models, doc["models"]):
        assert os.path.exists(os.path.join(d, e["graph_path"]))
        assert os.path.exists(os.path.join(d, e["dense_weights_path"]))


def test_ensemble_shared_vdb_is_model_scoped(ensemble):
    """Both models promote misses into the ONE VolatileDB — under
    model-scoped keys, so identical table names never collide."""
    models, d, bus, server = ensemble
    batches = _probe_batches(models)
    for m in models:
        b = batches[m.name]
        server.predict(m.name, b["dense"], b["cat"])
    vdb = server.vdb
    for m in models:
        assert vdb.size(f"{m.name}/C1") > 0
    assert vdb.size("C1") == 0                  # no unscoped leakage


def test_ensemble_online_update_isolation(ensemble):
    """An online update on ONE model's bus topics must reach that
    model's serving path and must leave every other model's tables —
    L1, L2 and L3 — bit-identical."""
    models, d, bus, server = ensemble
    a, b_model = models
    batches = _probe_batches(models)
    ba, bb = batches[a.name], batches[b_model.name]
    # ids actually probed by each batch's first table, so the update is
    # visible in the prediction once it propagates
    ids = np.unique(ba["cat"][:, 0, 0])
    ids = ids[ids >= 0][:4]
    before_a = server.predict(a.name, ba["dense"], ba["cat"])
    before_b = server.predict(b_model.name, bb["dense"], bb["cat"])
    l3_b_before = server.pdb.fetch(b_model.name, "C1", ids)

    prod = Producer(bus, a.name)
    dim = a.cfg.tables[0].dim
    prod.send("C1", ids, np.full((len(ids), dim), 77.5, np.float32))
    prod.flush()
    sa, sb = server[a.name], server[b_model.name]
    assert sa.hps.apply_updates() == 1
    assert sb.hps.apply_updates() == 0          # not its topic
    while sa.hps.refresh_backlog():
        sa.hps.refresh_step(budget=64)

    after_a = server.predict(a.name, ba["dense"], ba["cat"])
    after_b = server.predict(b_model.name, bb["dense"], bb["cat"])
    assert not np.array_equal(before_a, after_a)    # update landed on A
    np.testing.assert_array_equal(before_b, after_b)  # B untouched (L1/L2)
    np.testing.assert_array_equal(                    # B untouched (L3)
        server.pdb.fetch(b_model.name, "C1", ids), l3_b_before)


def test_ensemble_rejects_duplicate_names(ensemble, tmp_path):
    from repro.api import GraphError, deploy_ensemble
    models, d, bus, server = ensemble
    with pytest.raises(GraphError, match="unique"):
        deploy_ensemble([models[0], models[0]], str(tmp_path / "dup"))


def _tiny_graph_model(name, hotness):
    """A minimal trainable graph model whose table hotness we control."""
    from repro.api import (DataReaderParams, DenseLayer, Input, Model,
                           Solver, SparseEmbedding)
    m = Model(Solver(batch_size=8, lr=1e-2),
              DataReaderParams(num_dense_features=4), name=name)
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[400, 400], dim=8,
                          hotness=hotness, top_name="emb"))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["deep"], units=(8,)))
    m.add(DenseLayer("concat", ["flat", "deep"], ["both"]))
    m.add(DenseLayer("mlp", ["both"], ["logit"], units=(1,)))
    m.compile()
    m.fit(steps=1)
    return m


def test_ensemble_l1_sized_from_table_hotness(tmp_path):
    """No more one-global-knob L1: by default each member's
    cache_capacity is its hotness-proportional share of the total row
    budget, persisted in ps.json; explicit overrides still win."""
    import json
    import os

    from repro.api import deploy_ensemble, hotness_cache_capacities
    hot = _tiny_graph_model("hot-model", hotness=8)
    cold = _tiny_graph_model("cold-model", hotness=1)

    want = hotness_cache_capacities([hot, cold], budget=2048)
    assert want["hot-model"] > want["cold-model"]    # 8x the ids/sample
    server = deploy_ensemble([hot, cold], str(tmp_path / "auto"),
                             cache_budget=2048)
    server.stop()
    with open(os.path.join(str(tmp_path / "auto"), "ps.json")) as f:
        caps = {m["model"]: m["cache_capacity"]
                for m in json.load(f)["models"]}
    assert caps == want
    # the budget is conserved (up to rounding / per-model floors)
    assert abs(sum(caps.values()) - 2048) <= len(caps) * 64

    # explicit overrides: uniform int, and per-model dict pinning
    server = deploy_ensemble([hot, cold], str(tmp_path / "uniform"),
                             cache_capacity=96)
    server.stop()
    with open(os.path.join(str(tmp_path / "uniform"), "ps.json")) as f:
        caps = {m["model"]: m["cache_capacity"]
                for m in json.load(f)["models"]}
    assert caps == {"hot-model": 96, "cold-model": 96}

    server = deploy_ensemble([hot, cold], str(tmp_path / "pin"),
                             cache_budget=2048,
                             cache_capacity={"cold-model": 77})
    server.stop()
    with open(os.path.join(str(tmp_path / "pin"), "ps.json")) as f:
        caps = {m["model"]: m["cache_capacity"]
                for m in json.load(f)["models"]}
    assert caps["cold-model"] == 77                  # pinned
    assert caps["hot-model"] == want["hot-model"]    # hotness share


def test_ensemble_rebalance_tracks_observed_misses(tmp_path):
    """Observed-hit-rate budget re-split: after one member takes all
    the L1 misses, the shared row budget shifts toward it (the idle
    member drops to the floor), and the resized members keep serving
    bit-identical predictions — survivors and refills both come from
    the full-precision lower levels."""
    from repro.api import deploy_ensemble
    hot = _tiny_graph_model("hot-m", hotness=4)
    cold = _tiny_graph_model("cold-m", hotness=4)
    server = deploy_ensemble([hot, cold], str(tmp_path / "reb"),
                             cache_budget=1024,
                             rebalance_interval_s=3600.0)
    try:
        batches = {m.name: SyntheticCTR(m.cfg, 8, seed=3).batch(7)
                   for m in (hot, cold)}
        for m in (hot, cold):
            b = batches[m.name]
            server.predict(m.name, b["dense"], b["cat"])
        # absorb warmup misses into the baseline counters
        server.rebalance_now()
        bc = batches["cold-m"]
        before_cold = server.predict("cold-m", bc["dense"], bc["cat"])

        # drive many distinct ids through hot-m only
        ds = SyntheticCTR(hot.cfg, 16)
        for step in range(12):
            b = ds.batch(step)
            server.predict("hot-m", b["dense"], b["cat"])
        caps = server.rebalance_now()
        assert caps["hot-m"] > caps["cold-m"]
        assert caps["cold-m"] >= 64                    # floored, not starved
        assert sum(caps.values()) <= 1024 + 2 * 64     # budget conserved
        st = server.rebalance_stats()
        assert st["rebalances"] >= 1
        assert st["capacities"] == caps

        after_cold = server.predict("cold-m", bc["dense"], bc["cat"])
        np.testing.assert_array_equal(after_cold, before_cold)
    finally:
        server.stop()


def test_rebuild_with_cache_capacity_override(tmp_path):
    """launch.serve honors an operator-side per-model L1 override when
    standing a bundle back up."""
    import os

    from repro.api import deploy_ensemble
    from repro.launch.serve import build_server_from_config
    a = _tiny_graph_model("model-a", hotness=2)
    b = _tiny_graph_model("model-b", hotness=2)
    server = deploy_ensemble([a, b], str(tmp_path / "ens"),
                             cache_capacity=128)
    server.stop()
    rebuilt, _ = build_server_from_config(
        os.path.join(str(tmp_path / "ens"), "ps.json"),
        cache_capacity={"model-a": 32})
    cap_a = next(iter(rebuilt["model-a"].hps.caches.values())).capacity
    cap_b = next(iter(rebuilt["model-b"].hps.caches.values())).capacity
    assert cap_a == 32       # overridden
    assert cap_b == 128      # bundle value kept
