"""Graph API front door (paper §2) + portable export (ONNX analogue)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.data.synthetic import SyntheticCTR


def test_graph_api_dlrm_end_to_end(tmp_path):
    m = Model(Solver(batch_size=64, lr=1e-2),
              DataReaderParams(num_dense_features=4), name="api-dlrm")
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[500, 300, 100], dim=16,
                          hotness=2, top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(32, 16),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(32, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    m.compile()
    data = SyntheticCTR(m.cfg, 64)
    hist = m.fit(data.batch, steps=15)
    assert len(hist) == 15
    losses = [h["loss"] for h in hist]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])

    batch = data.batch(100)
    preds = m.predict(batch)
    assert preds.shape == (64,)
    assert ((preds > 0) & (preds < 1)).all()

    # deploy -> HPS server serves the same predictions
    server = m.deploy(str(tmp_path / "dep"))
    got = server.predict(batch["dense"], batch["cat"])
    np.testing.assert_allclose(got, preds, rtol=2e-2, atol=2e-2)


def test_graph_api_plain_tower(tmp_path):
    """A cross-less tower lowers to DCN with zero cross layers."""
    m = Model(Solver(batch_size=32, lr=1e-2),
              DataReaderParams(num_dense_features=4))
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[200, 100], dim=8, top_name="emb"))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["deep"], units=(32, 16)))
    m.add(DenseLayer("concat", ["flat", "deep"], ["both"]))
    m.add(DenseLayer("mlp", ["both"], ["logit"], units=(1,)))
    cfg = m.to_recsys_config()
    assert cfg.model == "dcn" and cfg.num_cross_layers == 0
    m.compile()
    data = SyntheticCTR(m.cfg, 32)
    m.fit(data.batch, steps=5)
    preds = m.predict(data.batch(50))
    assert preds.shape == (32,)
    assert np.isfinite(preds).all()


def test_api_checkpointing(tmp_path):
    m = Model(Solver(batch_size=16),
              DataReaderParams(num_dense_features=4))
    m.add(Input(dense_dim=4))
    m.add(SparseEmbedding(vocab_sizes=[100], dim=8, top_name="emb"))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["deep"], units=(16,)))
    m.add(DenseLayer("concat", ["flat", "deep"], ["both"]))
    m.add(DenseLayer("mlp", ["both"], ["logit"], units=(1,)))
    m.compile()
    data = SyntheticCTR(m.cfg, 16)
    m.fit(data.batch, steps=4, ckpt_dir=str(tmp_path / "ck"))
    from repro.train import checkpoint as ck
    assert ck.latest_step(str(tmp_path / "ck")) is not None


# ---------------------------------------------------------------------------
# Portable export
# ---------------------------------------------------------------------------

def _smoke_cfg(arch):
    """Registry archs reduce for smoke; novel graph archs lower their
    recipe graph to a generic model='graph' config — exercising
    RecsysModel construction from the config ALONE (the dense DAG
    travels inside it)."""
    if arch in RECSYS_ARCHS:
        return reduce_recsys_for_smoke(RECSYS_ARCHS[arch])
    import importlib

    from repro.configs.registry import RECSYS_RECIPES
    mod = importlib.import_module(RECSYS_RECIPES[arch])
    return mod.build_model(smoke=True).to_recsys_config()


@pytest.mark.parametrize("arch", ["dlrm-criteo", "dcn-criteo",
                                  "deepfm-criteo", "wdl-criteo",
                                  "twotower-criteo", "crossdeep-criteo",
                                  "neumf-criteo"])
def test_export_numpy_parity(arch, tmp_path):
    """The exported graph run by PURE NUMPY matches the JAX forward —
    the wide models' two-table-set graphs, novel generic graphs, AND
    N-group models whose extra gathers carry a cat column offset
    (the export is a walk of the compiled program, no per-arch code)."""
    from repro.export import export_recsys, load_exported, run_exported
    from repro.launch.mesh import make_test_mesh
    from repro.models.recsys.model import RecsysModel

    cfg = _smoke_cfg(arch)
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=16)
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticCTR(cfg, 16).batch(0)
        want = np.asarray(jax.nn.sigmoid(model.apply(
            params, {k: jnp.asarray(v) for k, v in batch.items()})))

        d = export_recsys(model, params, str(tmp_path / "exp"), arch)
    graph, weights = load_exported(d)
    assert graph["format"] == "repro-portable-v1"
    got = run_exported(graph, weights, batch)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["dlrm-criteo", "wdl-criteo",
                                  "twotower-criteo", "neumf-criteo"])
def test_export_artifact_is_self_describing(arch, tmp_path):
    from repro.export import export_recsys, load_exported
    from repro.launch.mesh import make_test_mesh
    from repro.models.recsys.model import RecsysModel

    cfg = _smoke_cfg(arch)
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=8)
        params = model.init(jax.random.PRNGKey(0))
        d = export_recsys(model, params, str(tmp_path / "exp"))
    graph, weights = load_exported(d)
    # every table advertised in metadata has its weights, full vocab
    # (wide models advertise the *_wide twins too)
    for t in graph["tables"]:
        w = weights[f"table/{t['name']}"]
        assert w.shape == (t["vocab"], t["dim"])
    if cfg.model == "wdl":
        assert any(t["name"].endswith("_wide") for t in graph["tables"])
    # every node's op is in the documented opset
    from repro.export import OPSET
    assert all(n["op"] in OPSET for n in graph["nodes"])