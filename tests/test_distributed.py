"""Multi-device correctness via subprocesses (8 virtual CPU devices).

Each subprocess sets XLA_FLAGS before importing jax — the main pytest
process keeps the single real device (required for the smoke tests)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n_devices: int = 8, timeout: int = 600):
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        + body
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import (DATA_PARALLEL, DISTRIBUTED, HYBRID,
                                LOCALIZED, EmbeddingTableConfig)
from repro.core.embedding import EmbeddingCollection
from repro.launch.mesh import make_test_mesh

def tables(strategy, n=4, vocab=64, dim=8, hotness=3):
    return [EmbeddingTableConfig(f"t{i}", vocab + 8 * i, dim,
                                 hotness=hotness, strategy=strategy,
                                 hot_fraction=0.25) for i in range(n)]

def make_ids(key, tabs, b=16):
    h = max(t.hotness for t in tabs)
    cols = []
    for t in tabs:
        cols.append(jax.random.randint(key, (b, 1, h), -1, t.vocab_size))
        key = jax.random.fold_in(key, 1)
    return jnp.concatenate(cols, axis=1)
"""


@pytest.mark.parametrize("strategy,comm,mesh_shape", [
    ("DISTRIBUTED", "allgather_rs", "(4, 2)"),
    ("DISTRIBUTED", "all_to_all", "(4, 2)"),
    ("LOCALIZED", "allgather_rs", "(8, 1)"),
    ("HYBRID", "allgather_rs", "(4, 2)"),
    ("HYBRID", "all_to_all", "(2, 4)"),
    ("DATA_PARALLEL", "allgather_rs", "(4, 2)"),
])
def test_strategy_multidevice(strategy, comm, mesh_shape):
    body = COMMON + f"""
mesh = make_test_mesh({mesh_shape})
tabs = tables({strategy}, n=8)
with mesh:
    coll = EmbeddingCollection(tabs, mesh, comm="{comm}",
                               capacity_factor=4.0)
    params = coll.init(jax.random.PRNGKey(0))
    ids = make_ids(jax.random.PRNGKey(1), tabs, b=16)
    got = jax.jit(coll.lookup)(params, ids)
    want = coll.lookup_reference(params, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
print("OK")
"""
    assert "OK" in run_with_devices(body)


def test_distributed_grads_multidevice():
    body = COMMON + """
mesh = make_test_mesh((4, 2))
tabs = tables(DISTRIBUTED, n=2)
with mesh:
    coll = EmbeddingCollection(tabs, mesh, comm="allgather_rs")
    params = coll.init(jax.random.PRNGKey(0))
    ids = make_ids(jax.random.PRNGKey(1), tabs, b=16)
    loss = lambda fn: (lambda p: (fn(p, ids).astype(jnp.float32)**2).sum())
    g1 = jax.jit(jax.grad(loss(coll.lookup)))(params)
    g2 = jax.grad(loss(coll.lookup_reference))(params)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-4, atol=1e-4)
print("OK")
"""
    assert "OK" in run_with_devices(body)


def test_hybrid_a2a_grads_multidevice():
    body = COMMON + """
mesh = make_test_mesh((2, 4))
tabs = tables(HYBRID, n=2)
with mesh:
    coll = EmbeddingCollection(tabs, mesh, comm="all_to_all",
                               capacity_factor=4.0)
    params = coll.init(jax.random.PRNGKey(0))
    ids = make_ids(jax.random.PRNGKey(1), tabs, b=16)
    loss = lambda fn: (lambda p: (fn(p, ids).astype(jnp.float32)**2).sum())
    g1 = jax.jit(jax.grad(loss(coll.lookup)))(params)
    g2 = jax.grad(loss(coll.lookup_reference))(params)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-4, atol=1e-4)
print("OK")
"""
    assert "OK" in run_with_devices(body)


def test_recsys_train_step_multidevice_parity():
    """GSPMD and manual-collective train steps agree on 8 devices."""
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.models.recsys.model import RecsysModel
from repro.launch.mesh import make_test_mesh
from repro.configs.base import TrainConfig
from repro.train.train_step import (build_train_step,
                                    build_manual_train_step, init_opt_state)
from repro.data.synthetic import SyntheticCTR

cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
mesh = make_test_mesh((4, 2))
with mesh:
    model = RecsysModel(cfg, mesh, global_batch=32)
    params = model.init(jax.random.PRNGKey(0))
    ds = SyntheticCTR(cfg, 32)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    tcfg = TrainConfig()
    opt = init_opt_state(params, tcfg)
    g_step = jax.jit(build_train_step(model, tcfg))
    m_step = jax.jit(build_manual_train_step(model, tcfg, mesh))
    p1, o1, a1 = g_step(params, opt, batch)
    params2 = model.init(jax.random.PRNGKey(0))
    opt2 = init_opt_state(params2, tcfg)
    p2, o2, a2 = m_step(params2, opt2, batch)
    np.testing.assert_allclose(float(a1["loss"]), float(a2["loss"]),
                               rtol=1e-4)
    # bf16 all-reduce ordering differs between GSPMD and manual psum;
    # per-element agreement is to ~1e-3 absolute
    for k in p1:
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-3),
            p1[k], p2[k])
print("OK")
"""
    assert "OK" in run_with_devices(body)


def test_lm_train_step_multidevice():
    """A reduced LM arch lowers + executes on a (2,2,2) pod mesh."""
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import LM_ARCHS, reduce_for_smoke
from repro.models.lm.backbone import LMModel
from repro.launch.mesh import make_test_mesh

cfg = reduce_for_smoke(LM_ARCHS["granite-moe-1b-a400m"])
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
with mesh:
    model = LMModel(cfg, mesh, embed_mode="hybrid", q_chunk=16, k_chunk=16,
                    loss_chunk=16)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 4, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    loss = jax.jit(model.train_loss)({k: v for k, v in params.items()},
                                     {"tokens": tokens})
    assert np.isfinite(float(loss))
print("OK")
"""
    assert "OK" in run_with_devices(body, n_devices=8)
