"""Direct coverage for the HPS storage plumbing: the Kafka-analogue
message bus (serialization round-trips, multi-topic consumption, offset
bookkeeping, producer batching thresholds) and the level-3 persistent DB
(create/open/fetch/upsert/flush against the on-disk memmaps)."""
import os

import numpy as np
import pytest

from repro.core.hps.message_bus import (Consumer, MessageBus, Producer,
                                        _deserialize, _serialize)
from repro.core.hps.persistent_db import PersistentDB


# ---------------------------------------------------------------------------
# message bus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(0, 4), (1, 1), (3, 16), (257, 8)])
def test_serialize_roundtrip_shapes(n, d):
    rng = np.random.default_rng(n * 31 + d)
    ids = rng.integers(0, 2**62, size=n).astype(np.int64)
    rows = rng.normal(size=(n, d)).astype(np.float32)
    i2, r2 = _deserialize(_serialize(ids, rows))
    np.testing.assert_array_equal(ids, i2)
    np.testing.assert_array_equal(rows, r2)
    assert i2.dtype == np.int64 and r2.dtype == np.float32


def test_deserialized_arrays_are_writable_copies():
    ids = np.asarray([1, 2], np.int64)
    rows = np.ones((2, 3), np.float32)
    i2, r2 = _deserialize(_serialize(ids, rows))
    i2[0] = 99          # frombuffer views would raise here
    r2[0] = 99.0
    assert ids[0] == 1 and rows[0, 0] == 1.0


def test_consumer_polls_multiple_topics_with_offsets():
    bus = MessageBus()
    prod = Producer(bus, "m")
    for t, base in (("t0", 0), ("t1", 100)):
        prod.send(t, np.asarray([base, base + 1]),
                  np.full((2, 4), float(base), np.float32))
    prod.flush()
    # a different model's topic must be invisible to this consumer
    other = Producer(bus, "other_model")
    other.send("t0", np.asarray([7]), np.zeros((1, 4), np.float32))
    other.flush()

    cons = Consumer(bus, "m")
    assert sorted(cons.discover()) == ["hps.m.t0", "hps.m.t1"]
    seen = {}
    n = cons.poll(lambda t, ids, rows: seen.setdefault(t, []).extend(
        ids.tolist()))
    assert n == 2
    assert seen == {"t0": [0, 1], "t1": [100, 101]}
    # offsets advanced: a second poll sees nothing, a new message only
    prod.send("t1", np.asarray([102]), np.zeros((1, 4), np.float32))
    prod.flush("t1")
    again = {}
    assert cons.poll(lambda t, ids, rows: again.setdefault(t, [])
                     .extend(ids.tolist())) == 1
    assert again == {"t1": [102]}


def test_producer_batches_at_row_threshold():
    bus = MessageBus()
    prod = Producer(bus, "m", max_batch_rows=4)
    for i in range(3):
        prod.send("t0", np.asarray([i]), np.ones((1, 2), np.float32))
    assert bus.topics() == []                  # below threshold: buffered
    prod.send("t0", np.asarray([3]), np.ones((1, 2), np.float32))
    msgs, off = bus.fetch("hps.m.t0", 0)
    assert len(msgs) == 1 and off == 1         # one coalesced message
    ids, rows = _deserialize(msgs[0])
    assert ids.tolist() == [0, 1, 2, 3] and rows.shape == (4, 2)


def test_fetch_respects_offset_and_max():
    bus = MessageBus()
    for i in range(5):
        bus.publish("tp", bytes([i]))
    msgs, off = bus.fetch("tp", 1, max_messages=2)
    assert msgs == [bytes([1]), bytes([2])] and off == 3
    msgs, off = bus.fetch("tp", off, max_messages=64)
    assert msgs == [bytes([3]), bytes([4])] and off == 5


# ---------------------------------------------------------------------------
# persistent DB
# ---------------------------------------------------------------------------

def test_pdb_create_fetch_upsert_flush_reopen(tmp_path):
    root = str(tmp_path / "pdb")
    pdb = PersistentDB(root)
    rows = np.arange(40, dtype=np.float32).reshape(10, 4)
    pdb.create_table("m", "emb", 10, 4, initial=rows)
    assert pdb.table_shape("m", "emb") == (10, 4)
    np.testing.assert_array_equal(pdb.fetch("m", "emb", np.asarray([2, 7])),
                                  rows[[2, 7]])

    pdb.upsert("m", "emb", np.asarray([3]), np.full((1, 4), 9.5, np.float32))
    pdb.flush()

    # a brand-new process-equivalent handle must see the flushed bytes
    pdb2 = PersistentDB(root)
    pdb2.open_table("m", "emb")
    assert pdb2.table_shape("m", "emb") == (10, 4)
    np.testing.assert_allclose(pdb2.fetch("m", "emb", np.asarray([3]))[0],
                               9.5)
    np.testing.assert_array_equal(pdb2.fetch("m", "emb", np.asarray([0])),
                                  rows[[0]])
    # reopened maps are writable too (r+): upsert round-trips
    pdb2.upsert("m", "emb", np.asarray([0]), np.full((1, 4), -1.0,
                                                     np.float32))
    np.testing.assert_allclose(pdb2.fetch("m", "emb", np.asarray([0]))[0],
                               -1.0)


def test_pdb_create_without_initial_is_zeros(tmp_path):
    pdb = PersistentDB(str(tmp_path / "pdb"))
    pdb.create_table("m", "z", 6, 3)
    np.testing.assert_array_equal(pdb.fetch("m", "z", np.arange(6)),
                                  np.zeros((6, 3), np.float32))


def test_pdb_namespaces_are_isolated(tmp_path):
    pdb = PersistentDB(str(tmp_path / "pdb"))
    pdb.create_table("m1", "t", 4, 2,
                     initial=np.ones((4, 2), np.float32))
    pdb.create_table("m2", "t", 4, 2,
                     initial=np.full((4, 2), 2.0, np.float32))
    np.testing.assert_allclose(pdb.fetch("m1", "t", np.asarray([0]))[0], 1.0)
    np.testing.assert_allclose(pdb.fetch("m2", "t", np.asarray([0]))[0], 2.0)
    files = os.listdir(str(tmp_path / "pdb"))
    assert "m1__t.f32" in files and "m2__t.f32" in files


# ---------------------------------------------------------------------------
# VolatileDB incremental sorted index
# ---------------------------------------------------------------------------

def _assert_index_matches_rebuild(shard):
    """The incremental merge must leave exactly the index a full
    rebuild would produce."""
    occ = shard.id_of[:shard.n]
    order = np.argsort(occ, kind="stable").astype(np.int64)
    np.testing.assert_array_equal(shard.sorted_ids, occ[order])
    np.testing.assert_array_equal(shard.sorted_slots, order)
    assert len(np.unique(occ)) == shard.n  # ids stay unique per shard


def test_vdb_incremental_index_matches_rebuild_under_churn():
    from repro.core.hps.volatile_db import VolatileDB

    rng = np.random.default_rng(7)
    db = VolatileDB(shards=3, capacity_per_shard=48)
    reference = {}
    for step in range(200):
        n = int(rng.integers(1, 32))
        ids = rng.integers(0, 400, n)
        rows = rng.normal(size=(n, 4)).astype(np.float32)
        db.insert("t", ids, rows)
        for i, r in zip(ids, rows):       # last write wins
            reference[int(i)] = r.copy()
        if step % 9 == 0:
            db.evict("t", rng.integers(0, 400, 4))
        for shard in db._store["t"]:
            _assert_index_matches_rebuild(shard)
        # probe results agree with a ground-truth dict for every hit
        q = rng.integers(0, 400, 20)
        mask, out = db.query("t", q)
        for j, qid in enumerate(q):
            if mask[j]:
                np.testing.assert_array_equal(out[j],
                                              reference[int(qid)])


def test_vdb_insert_more_than_capacity_keeps_index_consistent():
    from repro.core.hps.volatile_db import VolatileDB

    rng = np.random.default_rng(1)
    db = VolatileDB(shards=1, capacity_per_shard=16)
    # one batch far larger than the shard: fills + evicts in one call
    ids = np.arange(64, dtype=np.int64)
    rows = rng.normal(size=(64, 4)).astype(np.float32)
    db.insert("t", ids, rows)
    shard = db._store["t"][0]
    assert shard.n == 16
    _assert_index_matches_rebuild(shard)
    # a second overflowing batch exercises the victim-removal path
    ids2 = np.arange(100, 140, dtype=np.int64)
    rows2 = rng.normal(size=(40, 4)).astype(np.float32)
    db.insert("t", ids2, rows2)
    assert shard.n == 16
    _assert_index_matches_rebuild(shard)
