"""Paper §3 claim — HPS inference speedup, batch-size dependent (5–62x).

Three inference embedding paths over a Zipf request stream:

  cpu_baseline — per-request python-dict lookups + numpy dense net
                 (the "CPU baseline implementation" of the paper),
  hps          — L1 device cache (hot hits) + VDB/PDB fall-through, jitted
                 dense net,
  device_full  — entire table resident on device (upper bound).

Reported per batch size, mirroring the paper's batch-dependent speedup
curve."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, time_fn
from repro.configs.registry import RECSYS_ARCHS
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training


class CpuBaseline:
    """Dict-of-rows lookup + numpy MLP — no device, no cache."""

    def __init__(self, model, params):
        self.model = model
        logical = model.embedding.export_logical(params["embedding"])
        self.tables = {}
        g = model.embedding.groups["dp"]
        mega = np.asarray(logical["dp"])
        for i, (t, off) in enumerate(zip(g.tables, g.offsets)):
            end = g.offsets[i + 1] if i + 1 < g.num_tables else g.total_rows
            self.tables[i] = {j: mega[off + j] for j in range(end - off)}
        self.dense_params = jax.tree.map(
            np.asarray, {k: v for k, v in params.items()
                         if k != "embedding"})

    def predict(self, dense, cat):
        b, t, h = cat.shape
        d = next(iter(self.tables[0].values())).shape[0]
        emb = np.zeros((b, t, d), np.float32)
        for bi in range(b):
            for ti in range(t):
                for hi in range(h):
                    v = cat[bi, ti, hi]
                    if v >= 0:
                        emb[bi, ti] += self.tables[ti][int(v)]
        # numpy dense net (bottom mlp + interaction + top mlp)
        p = self.dense_params
        x = dense
        i = 0
        while f"w{i}" in p["bottom"]:
            x = np.maximum(x @ p["bottom"][f"w{i}"] + p["bottom"][f"b{i}"],
                           0)
            i += 1
        feats = np.concatenate([x[:, None, :], emb], axis=1)
        gram = np.einsum("bfd,bgd->bfg", feats, feats)
        iu, ju = np.tril_indices(feats.shape[1], -1)
        top_in = np.concatenate([x, gram[:, iu, ju]], axis=1)
        i = 0
        h_ = top_in
        n = len(p["top"]) // 2
        while f"w{i}" in p["top"]:
            h_ = h_ @ p["top"][f"w{i}"] + p["top"][f"b{i}"]
            if i < n - 1:
                h_ = np.maximum(h_, 0)
            i += 1
        return 1 / (1 + np.exp(-h_[:, 0]))


def run(report: Report, tmp_root: str = "artifacts/bench_hps"):
    cfg0 = RECSYS_ARCHS["dlrm-criteo"]
    tables = tuple(dataclasses.replace(
        t, vocab_size=min(t.vocab_size, 30000), dim=32,
        strategy="data_parallel") for t in cfg0.tables[:8])
    cfg = dataclasses.replace(cfg0, tables=tables, embedding_dim=32,
                              bottom_mlp=(64, 32),
                              top_mlp=(128, 64, 1))
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=64)
        params = model.init(jax.random.PRNGKey(0))
        pdb = PersistentDB(tmp_root)
        deploy_from_training(model, params, pdb, "dlrm-bench")
        hps = HPS("dlrm-bench", cfg.tables, pdb, cache_capacity=4096)
        dense_params = {k: v for k, v in params.items()
                        if k != "embedding"}
        server = InferenceServer(model, dense_params, hps)
        baseline = CpuBaseline(model, params)

        for batch_size in (1, 16, 256, 2048):
            ds = SyntheticCTR(cfg, batch_size)
            b = ds.batch(0)
            # warm the cache with the zipf head
            for s in range(3):
                w = ds.batch(s + 100)
                server.predict(w["dense"], w["cat"])

            t_hps = time_fn(lambda: server.predict(b["dense"], b["cat"]),
                            iters=5)["min_s"]
            t_cpu = time_fn(lambda: baseline.predict(b["dense"], b["cat"]),
                            warmup=1, iters=3)["min_s"]
            report.add(f"hps_infer.b{batch_size}.hps", t_hps,
                       f"qps={batch_size / t_hps:.0f}")
            report.add(f"hps_infer.b{batch_size}.cpu_baseline", t_cpu,
                       f"qps={batch_size / t_cpu:.0f}")
            report.add(f"hps_infer.b{batch_size}.speedup", t_cpu / t_hps,
                       f"x={t_cpu / t_hps:.1f}")
        hit = np.mean(list(hps.stats()["l1_hit_rate"].values()))
        report.add("hps_infer.l1_hit_rate", hit, f"rate={hit:.3f}")
