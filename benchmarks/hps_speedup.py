"""Paper §3 claim — HPS inference speedup, batch-size dependent (5–62x).

Three inference embedding paths over a Zipf request stream:

  cpu_baseline — per-request python-dict lookups + numpy dense net
                 (the "CPU baseline implementation" of the paper),
  hps          — L1 device cache (hot hits) + VDB/PDB fall-through, jitted
                 dense net,
  device_full  — entire table resident on device (upper bound).

Reported per batch size, mirroring the paper's batch-dependent speedup
curve.

Additionally, ``lookup_throughput`` isolates the L1 cache itself: the
vectorized batched query (sorted-index probe, one coalesced fetch, one
scatter, one Pallas gather) against the seed's per-id implementation
(python dict probes + one ``payload.at[s].set`` dispatch per inserted
row), over the same Zipf id stream — plus the striped-payload variant
(``shards=4`` host shards), which must track the single-payload cache.

``budget_capacity_sweep`` holds the L1 HBM byte budget FIXED and sweeps
the payload dtype (f32/f16/int8): the compressed modes buy 2x / ~3.55x
resident rows for the same bytes, reported as measured L1 hit-rate lift
and serve throughput against a remote L2 under the same Zipf stream.

``pipeline_throughput`` measures the two-stage serving engine in the
paper's remote-L2 regime (each coalesced miss fetch pays a Redis-style
network round trip, modeled identically in every arm): the
double-buffered ``HPS.lookup_stream`` pipeline against (a) a
stage-synchronous engine that completes each table's device scatter
before the next host probe — the no-overlap reference the paper's
pipelining argument is about — and (b) the default ``HPS.lookup`` loop,
whose device work XLA's async dispatch already overlaps with host work
but whose probes and remote fetches still serialize. Timings are minima
over many short interleaved passes (the arms alternate, so machine-load
epochs hit both equally and the min samples each arm's quiet-window
floor).

``serve_throughput`` measures the same regime END-TO-END through
``InferenceServer.submit``: the stream-fed serve engine (embeddings feed
the dense net straight off ``lookup_stream``; predictions materialize
one group behind) against the stage-synchronous submit path and the old
blocking drain loop — the pipelining claim at the prediction, not the
embedding.

``slo_latency_sweep`` drives the ADMISSION-CONTROLLED endpoint with a
seeded open-loop workload (the ``repro.loadgen`` harness — submission on
schedule, no coordinated omission) at an under-capacity and a ~3x
overload point: an unbounded legacy arm against a bounded-queue fixed
``max_batch`` arm against the full deadline-aware controller. The
headline row requires the deadline arm's delivered p99 to undercut
fixed-batch coalescing at overload.

``run`` also dumps the serving rows to ``artifacts/hps_lookup.json`` so
the roofline report re-surfaces them — a serving-path regression shows
up in ``artifacts/bench_results.csv`` even when only the roofline bench
runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, time_fn
from repro.configs.base import EmbeddingTableConfig
from repro.configs.registry import RECSYS_ARCHS
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training

HPS_LOOKUP_ARTIFACT = "artifacts/hps_lookup.json"


class SeedPerIdCache:
    """The seed L1 implementation, kept verbatim as the baseline under
    measurement: per-id python dict probes and one device dispatch per
    inserted row."""

    def __init__(self, capacity, dim, *, fetch_fn, decay=0.99):
        self.capacity = capacity
        self.fetch_fn = fetch_fn
        self.decay = decay
        self.payload = jnp.zeros((capacity, dim), jnp.float32)
        self._slot_of: Dict[int, int] = {}
        self._id_of = np.full(capacity, -1, np.int64)
        self._freq = np.zeros(capacity, np.float64)
        self._next_free = 0
        self._lock = threading.RLock()

    def query(self, ids):
        with self._lock:
            slots = np.empty(len(ids), np.int64)
            missing_idx = []
            for i, id_ in enumerate(map(int, ids)):
                s = self._slot_of.get(id_, -1)
                slots[i] = s
                if s < 0:
                    missing_idx.append(i)
                else:
                    self._freq[s] += 1.0
            if missing_idx:
                miss_ids = ids[missing_idx]
                rows = self.fetch_fn(miss_ids)
                ins = np.empty(len(miss_ids), np.int64)
                for k, (id_, row) in enumerate(
                        zip(map(int, miss_ids), rows)):
                    if id_ in self._slot_of:
                        ins[k] = self._slot_of[id_]
                        continue
                    if self._next_free < self.capacity:
                        s = self._next_free
                        self._next_free += 1
                    else:
                        self._freq *= self.decay
                        s = int(self._freq.argmin())
                        old = self._id_of[s]
                        if old >= 0:
                            del self._slot_of[old]
                    self._slot_of[id_] = s
                    self._id_of[s] = id_
                    self._freq[s] = 1.0
                    ins[k] = s
                    self.payload = self.payload.at[s].set(jnp.asarray(row))
                slots[missing_idx] = ins
            return jnp.take(self.payload, jnp.asarray(slots), axis=0)


def lookup_throughput(report: Report):
    """L1 query throughput, vectorized vs seed per-id, same Zipf stream.

    The cache (2k rows) sits in front of a 30k-row table, so at steady
    state every batch carries Zipf-tail misses — the realistic serving
    regime, where the seed pays one device dispatch per missed row while
    the batched cache pays one scatter per query.
    """
    vocab, dim, capacity = 30000, 32, 2048
    store = np.random.default_rng(0).normal(
        size=(vocab, dim)).astype(np.float32)
    fetch = lambda ids: store[ids]
    rng = np.random.default_rng(1)

    per_pass, passes = 4, 5
    for batch in (256, 2048):
        # pre-draw identical stream slices; each timed pass consumes a
        # fresh slice so eviction churn (not a warmed hit loop) is measured
        slices = [[(rng.zipf(1.2, batch) - 1) % vocab
                   for _ in range(per_pass)]
                  for _ in range(passes + 2)]      # +2 warmup passes
        impls = {"vectorized": DeviceEmbeddingCache(capacity, dim,
                                                    fetch_fn=fetch),
                 "sharded4": DeviceEmbeddingCache(capacity, dim, shards=4,
                                                  fetch_fn=fetch),
                 "per_id": SeedPerIdCache(capacity, dim, fetch_fn=fetch)}
        times = {}
        for name, cache in impls.items():
            cursor = {"i": 0}

            def run_pass(cache=cache, cursor=cursor):
                batches = slices[cursor["i"] % len(slices)]
                cursor["i"] += 1
                for s in batches:
                    out = cache.query(s)
                jax.block_until_ready(out)

            times[name] = time_fn(run_pass, warmup=2,
                                  iters=passes)["min_s"]
            qps = per_pass * batch / times[name]
            report.add(f"hps_lookup.b{batch}.{name}", times[name],
                       f"ids/s={qps:.0f}")
        speedup = times["per_id"] / times["vectorized"]
        report.add(f"hps_lookup.b{batch}.speedup", speedup,
                   f"x={speedup:.1f}")
        stripe_cost = times["sharded4"] / times["vectorized"]
        report.add(f"hps_lookup.b{batch}.stripe4_cost", stripe_cost,
                   f"x={stripe_cost:.2f}")


def pipeline_throughput(report: Report, tmp_root: str):
    """Two-stage serving-engine pipelining, batch 2048 over 4 tables.

    The serving regime of the companion HPS paper: the L2 is a REMOTE
    Redis-style cluster, so every coalesced miss fetch pays a network
    round trip (modeled as ``RTT_S`` of GIL-releasing latency on the
    fetch path — identically for every arm). Three engines on identical
    Zipf query streams (fresh HPS each so cache state evolves
    identically):

      stage_sync — host probe then BLOCK on the device scatter, table by
                   table, block on the pooled stack: zero overlap of any
                   kind (the paper's unpipelined reference);
      sequential — today's ``lookup`` loop + per-query materialize; XLA
                   async dispatch overlaps device work behind the host,
                   but the host stages (probe + remote fetch) serialize;
      pipelined  — ``lookup_stream``: the two host workers probe/fetch
                   ahead (table t+1's index probe runs while table t's
                   fetch waits on the remote L2) while the device
                   computes query i and the caller materializes i-1.

    The headline ``speedup`` row is pipelined vs stage_sync — the value
    of the overlap itself, which the engine provides without relying on
    the runtime's async dispatch; ``speedup_vs_async`` shows the win
    over the shipping sequential path, which comes from overlapping the
    remote fetches with host index work and device sync. Arms alternate
    per pass and each arm takes its MIN across passes, so shared-machine
    load epochs cannot bias one arm.
    """
    vocab, dim, T, batch, H = 30000, 128, 4, 2048, 8
    capacity, zipf_a, n_q, passes = 8192, 1.6, 4, 10
    RTT_S = 3e-3          # remote-L2 round trip per coalesced miss fetch
    rng = np.random.default_rng(0)
    pdb = PersistentDB(tmp_root)
    tabs = []
    for i in range(T):
        rows = rng.normal(size=(vocab, dim)).astype(np.float32)
        pdb.create_table("pipe", f"t{i}", vocab, dim, initial=rows)
        tabs.append(EmbeddingTableConfig(f"t{i}", vocab, dim, hotness=H))

    def make_queries(seed, n):
        r = np.random.default_rng(seed)
        return [((r.zipf(zipf_a, (batch, T, H)) - 1) % vocab)
                .astype(np.int32) for _ in range(n)]

    engines = {
        "stage_sync": lambda hps, qs: [np.asarray(hps.lookup_stage_sync(q))
                                       for q in qs],
        "sequential": lambda hps, qs: [np.asarray(
            hps.lookup(q, pipelined=False)) for q in qs],
        "pipelined": lambda hps, qs: list(hps.lookup_stream(qs)),
    }
    hpss = {name: HPS("pipe", tabs, pdb, cache_capacity=capacity)
            for name in engines}
    for hps in hpss.values():      # same simulated remote L2 in every arm
        for c in hps.caches.values():
            c.fetch_fn = (lambda orig: lambda ids:
                          (time.sleep(RTT_S), orig(ids))[1])(c.fetch_fn)
    for q in make_queries(50, 3):                          # warm jit+cache
        for hps in hpss.values():
            np.asarray(hps.lookup(q))
    t_arm: Dict[str, List[float]] = {name: [] for name in engines}
    for p in range(passes):
        qs = make_queries(100 + p, n_q)
        for name, run_arm in engines.items():              # interleaved
            t0 = time.perf_counter()
            run_arm(hpss[name], qs)
            t_arm[name].append(time.perf_counter() - t0)

    for hps in hpss.values():
        hps.close()
    mins = {name: min(ts) for name, ts in t_arm.items()}
    ids_per_q = batch * T * H
    for name, t in mins.items():
        report.add(f"hps_pipeline.b{batch}.{name}", t / n_q,
                   f"ids/s={n_q * ids_per_q / t:.0f}")
    speedup = mins["stage_sync"] / mins["pipelined"]
    report.add(f"hps_pipeline.b{batch}.speedup", speedup,
               f"x={speedup:.2f}")
    vs_async = mins["sequential"] / mins["pipelined"]
    report.add(f"hps_pipeline.b{batch}.speedup_vs_async", vs_async,
               f"x={vs_async:.2f}")


def serve_throughput(report: Report, tmp_root: str):
    """END-TO-END serving engines: submit() -> embeddings -> dense net
    -> delivered predictions, remote-L2 RTT regime, batch 1024 x 4
    tables.

    Three InferenceServer engines over identical pre-queued request
    streams (fresh HPS each so cache state evolves identically; every
    coalesced miss fetch pays the same Redis-style ``RTT_S``):

      stage_sync — drain a group, BLOCK on every device stage before
                   the next host stage, materialize, repeat: the
                   no-overlap reference submit path;
      sync       — drain -> one blocking predict() per group (the old
                   serve loop): XLA async dispatch overlaps device work
                   behind the host, but each group's remote fetches
                   serialize behind the previous group's materialize;
      stream     — the stream-fed pipeline: group i+1's probes + remote
                   fetches run on the HPS workers while group i's dense
                   net computes and group i-1's prediction materializes.

    The headline ``speedup`` row is stream vs stage_sync (the paper's
    pipelining claim measured at the PREDICTION, not the embedding);
    ``speedup_vs_sync`` is the win over the old shipping loop. Arms
    alternate per pass, MIN per arm across passes.
    """
    vocab, dim, T, batch, H = 30000, 32, 4, 1024, 4
    # n_q deep enough that the stream pipeline's fill/drain (one group
    # at each end) amortizes, as it does in a real request stream
    capacity, zipf_a, n_q, passes = 8192, 1.6, 12, 6
    RTT_S = 3e-3          # remote-L2 round trip per coalesced miss fetch
    rng = np.random.default_rng(0)
    pdb = PersistentDB(tmp_root)
    tabs = []
    for i in range(T):
        rows = rng.normal(size=(vocab, dim)).astype(np.float32)
        pdb.create_table("serve", f"t{i}", vocab, dim, initial=rows)
        tabs.append(EmbeddingTableConfig(f"t{i}", vocab, dim, hotness=H,
                                         strategy="data_parallel"))
    cfg = dataclasses.replace(
        RECSYS_ARCHS["dlrm-criteo"], tables=tuple(tabs),
        embedding_dim=dim, bottom_mlp=(64, dim), top_mlp=(128, 64, 1))

    def make_queries(seed, n):
        r = np.random.default_rng(seed)
        return [((r.zipf(zipf_a, (batch, T, H)) - 1) % vocab)
                .astype(np.int32) for _ in range(n)]

    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=batch)
        params = model.init(jax.random.PRNGKey(0))
        dense_params = {k: v for k, v in params.items()
                        if k != "embedding"}
        servers = {}
        for eng in ("stage_sync", "sync", "stream"):
            hps = HPS("serve", tabs, pdb, cache_capacity=capacity)
            for c in hps.caches.values():  # same simulated remote L2
                c.fetch_fn = (lambda orig: lambda ids:
                              (time.sleep(RTT_S), orig(ids))[1])(c.fetch_fn)
            servers[eng] = InferenceServer(model, dense_params, hps,
                                           max_batch=batch, engine=eng)
        dense_in = rng.normal(size=(batch, cfg.num_dense_features)) \
            .astype(np.float32)
        for q in make_queries(50, 2):                  # warm jit + cache
            for s in servers.values():
                s.predict(dense_in, q)
        for s in servers.values():
            s.reset_latencies()
            s.start()
        t_arm: Dict[str, List[float]] = {e: [] for e in servers}
        for p in range(passes):
            qs = make_queries(100 + p, n_q)
            for eng, s in servers.items():             # interleaved
                t0 = time.perf_counter()
                handles = [s.submit(dense_in, q) for q in qs]
                for h in handles:
                    out = h.get(timeout=600)
                    if isinstance(out, Exception):  # never time a
                        raise out                   # failed arm
                t_arm[eng].append(time.perf_counter() - t0)
        for s in servers.values():
            s.stop()
            s.hps.close()
    mins = {e: min(ts) for e, ts in t_arm.items()}
    for eng, t in mins.items():
        report.add(f"hps_serve.b{batch}.{eng}", t / n_q,
                   f"qps={n_q * batch / t:.0f}")
    speedup = mins["stage_sync"] / mins["stream"]
    report.add(f"hps_serve.b{batch}.speedup", speedup, f"x={speedup:.2f}")
    vs_sync = mins["sync"] / mins["stream"]
    report.add(f"hps_serve.b{batch}.speedup_vs_sync", vs_sync,
               f"x={vs_sync:.2f}")


def slo_latency_sweep(report: Report, tmp_root: str):
    """qps-vs-p99 with admission control ON vs OFF, remote-L2 regime.

    Three identically-provisioned stream servers (fresh HPS each, every
    coalesced miss fetch pays the same Redis-style ``RTT_S``) take the
    SAME seeded open-loop Zipf workload through the
    :class:`~repro.loadgen.driver.OpenLoopDriver` (submission on
    schedule, latency measured from the scheduled arrival — overload
    cannot hide in coordinated omission):

      admission_off — unbounded queue, no SLO: the legacy endpoint.
                      Under overload the queue grows without bound and
                      delivered p99 is the backlog, not the service.
      fixed_batch   — bounded queue + declared SLO, but fixed
                      ``max_batch`` coalescing: sheds at the bound, yet
                      admitted requests wait out the whole queue.
      deadline      — the full admission controller: deadline-aware
                      batch sizing (cut the group early when the oldest
                      request's slack is short) + expired-at-drain
                      shedding, so capacity is never spent on requests
                      already past their deadline.

    Offered rates adapt to the measured group service time (a moderate
    under-capacity point and a ~3x overload point), so the sweep lands
    in the same regime on any machine. The headline
    ``overload.deadline_vs_fixed`` row is the acceptance claim: at
    overload the deadline arm's delivered p99 must undercut fixed-batch
    coalescing (ratio > 1).
    """
    from repro.loadgen.driver import OpenLoopDriver
    from repro.loadgen.workload import ModelShape, Workload, WorkloadConfig

    vocab, dim, T, H = 30000, 32, 4, 4
    # 64-row requests: enough submits/s to overload the queue, few
    # enough that the open-loop submit thread never lags the schedule
    # by more than a few ms (submit lag would charge BOTH bounded arms
    # identically and mask the queue-wait difference under test)
    rows, max_co = 64, 4
    capacity, zipf_a = 4096, 1.6
    RTT_S = 3e-3          # remote-L2 round trip per coalesced miss fetch
    QUEUE_DEPTH = 128
    rng = np.random.default_rng(0)
    pdb = PersistentDB(tmp_root)
    tabs = []
    for i in range(T):
        data = rng.normal(size=(vocab, dim)).astype(np.float32)
        pdb.create_table("slo", f"t{i}", vocab, dim, initial=data)
        tabs.append(EmbeddingTableConfig(f"t{i}", vocab, dim, hotness=H,
                                         strategy="data_parallel"))
    cfg = dataclasses.replace(
        RECSYS_ARCHS["dlrm-criteo"], tables=tuple(tabs),
        embedding_dim=dim, bottom_mlp=(64, dim), top_mlp=(128, 64, 1))
    shape = ModelShape(vocab_sizes=(vocab,) * T, hotness=(H,) * T,
                       num_dense=cfg.num_dense_features)
    max_batch = rows * max_co

    # (queue_depth, use_slo, deadline_batching) per arm
    ARMS = {"admission_off": (None, False, False),
            "fixed_batch": (QUEUE_DEPTH, True, False),
            "deadline": (QUEUE_DEPTH, True, True)}

    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=max_batch)
        params = model.init(jax.random.PRNGKey(0))
        dense_params = {k: v for k, v in params.items()
                        if k != "embedding"}
        servers = {}
        for arm in ARMS:
            hps = HPS("slo", tabs, pdb, cache_capacity=capacity)
            for c in hps.caches.values():  # same simulated remote L2
                c.fetch_fn = (lambda orig: lambda ids:
                              (time.sleep(RTT_S), orig(ids))[1])(c.fetch_fn)
            servers[arm] = InferenceServer(model, dense_params, hps,
                                           max_batch=max_batch,
                                           engine="stream")

        # identical warmup per arm: jit every group shape the coalescer
        # can form, pull the Zipf hot set into L1, then warm the serve
        # loop's own (stream) path — all before admission is armed, so
        # no cold compile can expire a request
        warm_reqs = list(Workload(
            WorkloadConfig(qps=400.0, duration_s=0.1, rows=rows,
                           arrival="constant", seed=7, zipf_a=zipf_a),
            {"m": shape}))
        for s in servers.values():
            base = warm_reqs[0]
            for k in range(1, max_co + 1):
                s.predict(np.concatenate([base.dense] * k),
                          np.concatenate([base.cat] * k))
            for r in warm_reqs:
                s.predict(r.dense, r.cat)
            s.start()
            for rd in range(2):
                hs = [s.submit(r.dense, r.cat)
                      for r in warm_reqs[rd * 4:(rd + 1) * 4]]
                for h in hs:
                    out = h.get(timeout=600)
                    if isinstance(out, Exception):
                        raise out
            s.stop()

        # calibrate capacity by bursting requests through every STARTED
        # arm (identical bursts, so all three caches evolve through the
        # same state): the drain rate of the SECOND burst — hot head
        # cached, fresh Zipf tail still missing, exactly the live
        # regime — is the real serve capacity here, with coalescing,
        # RTT miss fetches, serve-loop overhead and GIL contention all
        # charged, none of which a bare hot-cache predict() would pay
        t_per_req = []
        for s in servers.values():
            s.start()
            for cal_seed, record in ((9, False), (10, True)):
                cal = list(Workload(
                    WorkloadConfig(qps=1000.0, duration_s=0.12,
                                   rows=rows, arrival="constant",
                                   seed=cal_seed, zipf_a=zipf_a),
                    {"m": shape}))
                t0 = time.perf_counter()
                hs = [s.submit(r.dense, r.cat) for r in cal]
                for h in hs:
                    out = h.get(timeout=600)
                    if isinstance(out, Exception):
                        raise out
                if record:
                    t_per_req.append(
                        (time.perf_counter() - t0) / len(cal))
            s.stop()
        per_req = sorted(t_per_req)[len(t_per_req) // 2]
        cap_rps = 1.0 / per_req
        group_ms = 1e3 * per_req * max_co             # per-group service
        slo_ms = max(30.0, 5 * group_ms)
        rates = {"moderate": 0.3 * cap_rps, "overload": 2.5 * cap_rps}

        for arm, s in servers.items():
            depth, use_slo, dead = ARMS[arm]
            s.set_admission(queue_depth=depth,
                            slo_ms=slo_ms if use_slo else None,
                            deadline_batching=dead)
            s.reset_serving_stats()
            s.start()

        p99s: Dict = {}
        for phase, qps in rates.items():
            dur = 2.5 if phase == "moderate" else 2.0
            # one pre-materialized stream, replayed identically per arm
            # (generation cost never lags the submission schedule)
            wl = list(Workload(
                WorkloadConfig(qps=qps, duration_s=dur, rows=rows,
                               seed=11 if phase == "moderate" else 13,
                               zipf_a=zipf_a),
                {"m": shape}))
            for arm, s in servers.items():
                drv = OpenLoopDriver(
                    (lambda srv: lambda _m, d, c: srv.submit(d, c))(s),
                    slo_ms=slo_ms, poll_s=4e-3, drain_timeout_s=120.0)
                res = drv.run(wl)["models"]["m"]
                cnt = s.counters()
                shed = cnt["requests_shed"] + cnt["requests_expired"]
                s.reset_serving_stats()
                p99 = res["latency_ms"]["p99"]
                p99s[(phase, arm)] = p99
                report.add(
                    f"hps_slo.{phase}.{arm}", p99 * 1e-3,
                    f"p99_ms={p99:.1f} offered_qps={qps:.0f} "
                    f"delivered_qps={res['delivered'] / dur:.0f} "
                    f"shed={shed} viol={cnt['slo_violations']} "
                    f"lost={res['lost']}")
        for s in servers.values():
            s.stop()
            s.hps.close()
    ratio = p99s[("overload", "fixed_batch")] \
        / max(p99s[("overload", "deadline")], 1e-9)
    report.add("hps_slo.overload.deadline_vs_fixed", ratio,
               f"x={ratio:.2f} fixed_batch p99 over deadline p99 "
               f"(>1 = deadline batching wins at overload)")


def budget_capacity_sweep(report: Report):
    """Fixed-HBM-budget L1 across payload dtypes — the compression
    claim measured where it pays: the SAME byte budget buys 2x (f16) /
    ~3.55x (int8, per-row scale included) resident rows, and under a
    Zipf stream against a remote L2 the extra residency becomes an L1
    hit-rate lift and a serve-throughput lift, not just smaller bytes.

    Every arm replays the identical pre-drawn Zipf stream against the
    identical remote L2 (each coalesced miss fetch pays ``RTT_S`` per
    256-row chunk, the Redis-style pipelined-MGET model). Only the L1
    byte budget is held fixed; capacity follows the dtype's row_bytes.
    """
    from repro.core.hps.payload_store import row_bytes
    vocab, dim = 60000, 32
    budget = 512 * 1024                    # L1 payload bytes, all arms
    zipf_a, batch, per_pass, passes = 1.1, 2048, 4, 4
    RTT_S, CHUNK = 3e-3, 64
    store = np.random.default_rng(0).normal(
        size=(vocab, dim)).astype(np.float32)

    def fetch(ids):                        # remote L2: RTT per chunk
        time.sleep(RTT_S * -(-len(ids) // CHUNK))
        return store[ids]

    rng = np.random.default_rng(2)
    slices = [[(rng.zipf(zipf_a, batch) - 1) % vocab
               for _ in range(per_pass)]
              for _ in range(passes + 2)]          # +2 warmup passes
    cap_f32 = budget // row_bytes(dim, "f32")
    hit_rates, times = {}, {}
    for dtype in ("f32", "f16", "int8"):
        cap = budget // row_bytes(dim, dtype)
        cache = DeviceEmbeddingCache(cap, dim, fetch_fn=fetch,
                                     payload_dtype=dtype)
        report.add(f"hps_budget.{dtype}.capacity", cap,
                   f"rows={cap} x_f32={cap / cap_f32:.2f}")
        cursor = {"i": 0}

        def run_pass(cache=cache, cursor=cursor):
            batches = slices[cursor["i"] % len(slices)]
            cursor["i"] += 1
            for s in batches:
                out = cache.query(s)
            jax.block_until_ready(out)

        times[dtype] = time_fn(run_pass, warmup=2, iters=passes)["min_s"]
        cnt = cache.counters()
        hit_rates[dtype] = cnt["hits"] / max(1, cnt["hits"] + cnt["misses"])
        report.add(f"hps_budget.{dtype}.l1_hit_rate", hit_rates[dtype],
                   f"rate={hit_rates[dtype]:.3f}")
        qps = per_pass * batch / times[dtype]
        report.add(f"hps_budget.{dtype}.serve", times[dtype],
                   f"ids/s={qps:.0f}")
    for dtype in ("f16", "int8"):
        lift = hit_rates[dtype] - hit_rates["f32"]
        report.add(f"hps_budget.{dtype}.hit_lift", lift,
                   f"+{lift:.3f} over f32 at equal bytes")
        sp = times["f32"] / times[dtype]
        report.add(f"hps_budget.{dtype}.speedup", sp, f"x={sp:.2f}")


def dump_l1_artifact(report: Report) -> None:
    """Persist the L1 rows for the roofline report's regression table."""
    rows = []
    for row in report.rows:
        name, us, derived = row.split(",", 2)
        if name.startswith(("hps_lookup.", "hps_pipeline.",
                            "hps_serve.", "hps_budget.", "hps_slo.")):
            rows.append({"name": name, "us_per_call": float(us),
                         "derived": derived})
    if rows:
        os.makedirs(os.path.dirname(HPS_LOOKUP_ARTIFACT), exist_ok=True)
        with open(HPS_LOOKUP_ARTIFACT, "w") as f:
            json.dump(rows, f, indent=1)


class CpuBaseline:
    """Dict-of-rows lookup + numpy MLP — no device, no cache."""

    def __init__(self, model, params):
        self.model = model
        logical = model.embedding.export_logical(params["embedding"])
        self.tables = {}
        g = model.embedding.groups["dp"]
        mega = np.asarray(logical["dp"])
        for i, (t, off) in enumerate(zip(g.tables, g.offsets)):
            end = g.offsets[i + 1] if i + 1 < g.num_tables else g.total_rows
            self.tables[i] = {j: mega[off + j] for j in range(end - off)}
        self.dense_params = jax.tree.map(
            np.asarray, {k: v for k, v in params.items()
                         if k != "embedding"})

    def predict(self, dense, cat):
        b, t, h = cat.shape
        d = next(iter(self.tables[0].values())).shape[0]
        emb = np.zeros((b, t, d), np.float32)
        for bi in range(b):
            for ti in range(t):
                for hi in range(h):
                    v = cat[bi, ti, hi]
                    if v >= 0:
                        emb[bi, ti] += self.tables[ti][int(v)]
        # numpy dense net (bottom mlp + interaction + top mlp)
        p = self.dense_params
        x = dense
        i = 0
        while f"w{i}" in p["bottom"]:
            x = np.maximum(x @ p["bottom"][f"w{i}"] + p["bottom"][f"b{i}"],
                           0)
            i += 1
        feats = np.concatenate([x[:, None, :], emb], axis=1)
        gram = np.einsum("bfd,bgd->bfg", feats, feats)
        iu, ju = np.tril_indices(feats.shape[1], -1)
        top_in = np.concatenate([x, gram[:, iu, ju]], axis=1)
        i = 0
        h_ = top_in
        n = len(p["top"]) // 2
        while f"w{i}" in p["top"]:
            h_ = h_ @ p["top"][f"w{i}"] + p["top"][f"b{i}"]
            if i < n - 1:
                h_ = np.maximum(h_, 0)
            i += 1
        return 1 / (1 + np.exp(-h_[:, 0]))


def run(report: Report, tmp_root: str = "artifacts/bench_hps"):
    lookup_throughput(report)
    budget_capacity_sweep(report)
    pipeline_throughput(report, tmp_root + "_pipe")
    serve_throughput(report, tmp_root + "_serve")
    slo_latency_sweep(report, tmp_root + "_slo")
    dump_l1_artifact(report)
    cfg0 = RECSYS_ARCHS["dlrm-criteo"]
    tables = tuple(dataclasses.replace(
        t, vocab_size=min(t.vocab_size, 30000), dim=32,
        strategy="data_parallel") for t in cfg0.tables[:8])
    cfg = dataclasses.replace(cfg0, tables=tables, embedding_dim=32,
                              bottom_mlp=(64, 32),
                              top_mlp=(128, 64, 1))
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=64)
        params = model.init(jax.random.PRNGKey(0))
        pdb = PersistentDB(tmp_root)
        deploy_from_training(model, params, pdb, "dlrm-bench")
        hps = HPS("dlrm-bench", cfg.tables, pdb, cache_capacity=4096)
        dense_params = {k: v for k, v in params.items()
                        if k != "embedding"}
        server = InferenceServer(model, dense_params, hps)
        baseline = CpuBaseline(model, params)

        for batch_size in (1, 16, 256, 2048):
            ds = SyntheticCTR(cfg, batch_size)
            b = ds.batch(0)
            # warm the cache with the zipf head
            for s in range(3):
                w = ds.batch(s + 100)
                server.predict(w["dense"], w["cat"])

            t_hps = time_fn(lambda: server.predict(b["dense"], b["cat"]),
                            iters=5)["min_s"]
            t_cpu = time_fn(lambda: baseline.predict(b["dense"], b["cat"]),
                            warmup=1, iters=3)["min_s"]
            report.add(f"hps_infer.b{batch_size}.hps", t_hps,
                       f"qps={batch_size / t_hps:.0f}")
            report.add(f"hps_infer.b{batch_size}.cpu_baseline", t_cpu,
                       f"qps={batch_size / t_cpu:.0f}")
            report.add(f"hps_infer.b{batch_size}.speedup", t_cpu / t_hps,
                       f"x={t_cpu / t_hps:.1f}")
        hit = np.mean(list(hps.stats()["l1_hit_rate"].values()))
        report.add("hps_infer.l1_hit_rate", hit, f"rate={hit:.3f}")
