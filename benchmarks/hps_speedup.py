"""Paper §3 claim — HPS inference speedup, batch-size dependent (5–62x).

Three inference embedding paths over a Zipf request stream:

  cpu_baseline — per-request python-dict lookups + numpy dense net
                 (the "CPU baseline implementation" of the paper),
  hps          — L1 device cache (hot hits) + VDB/PDB fall-through, jitted
                 dense net,
  device_full  — entire table resident on device (upper bound).

Reported per batch size, mirroring the paper's batch-dependent speedup
curve.

Additionally, ``lookup_throughput`` isolates the L1 cache itself: the
vectorized batched query (sorted-index probe, one coalesced fetch, one
scatter, one Pallas gather) against the seed's per-id implementation
(python dict probes + one ``payload.at[s].set`` dispatch per inserted
row), over the same Zipf id stream."""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, time_fn
from repro.configs.registry import RECSYS_ARCHS
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training


class SeedPerIdCache:
    """The seed L1 implementation, kept verbatim as the baseline under
    measurement: per-id python dict probes and one device dispatch per
    inserted row."""

    def __init__(self, capacity, dim, *, fetch_fn, decay=0.99):
        self.capacity = capacity
        self.fetch_fn = fetch_fn
        self.decay = decay
        self.payload = jnp.zeros((capacity, dim), jnp.float32)
        self._slot_of: Dict[int, int] = {}
        self._id_of = np.full(capacity, -1, np.int64)
        self._freq = np.zeros(capacity, np.float64)
        self._next_free = 0
        self._lock = threading.RLock()

    def query(self, ids):
        with self._lock:
            slots = np.empty(len(ids), np.int64)
            missing_idx = []
            for i, id_ in enumerate(map(int, ids)):
                s = self._slot_of.get(id_, -1)
                slots[i] = s
                if s < 0:
                    missing_idx.append(i)
                else:
                    self._freq[s] += 1.0
            if missing_idx:
                miss_ids = ids[missing_idx]
                rows = self.fetch_fn(miss_ids)
                ins = np.empty(len(miss_ids), np.int64)
                for k, (id_, row) in enumerate(
                        zip(map(int, miss_ids), rows)):
                    if id_ in self._slot_of:
                        ins[k] = self._slot_of[id_]
                        continue
                    if self._next_free < self.capacity:
                        s = self._next_free
                        self._next_free += 1
                    else:
                        self._freq *= self.decay
                        s = int(self._freq.argmin())
                        old = self._id_of[s]
                        if old >= 0:
                            del self._slot_of[old]
                    self._slot_of[id_] = s
                    self._id_of[s] = id_
                    self._freq[s] = 1.0
                    ins[k] = s
                    self.payload = self.payload.at[s].set(jnp.asarray(row))
                slots[missing_idx] = ins
            return jnp.take(self.payload, jnp.asarray(slots), axis=0)


def lookup_throughput(report: Report):
    """L1 query throughput, vectorized vs seed per-id, same Zipf stream.

    The cache (2k rows) sits in front of a 30k-row table, so at steady
    state every batch carries Zipf-tail misses — the realistic serving
    regime, where the seed pays one device dispatch per missed row while
    the batched cache pays one scatter per query.
    """
    vocab, dim, capacity = 30000, 32, 2048
    store = np.random.default_rng(0).normal(
        size=(vocab, dim)).astype(np.float32)
    fetch = lambda ids: store[ids]
    rng = np.random.default_rng(1)

    per_pass, passes = 4, 5
    for batch in (256, 2048):
        # pre-draw identical stream slices; each timed pass consumes a
        # fresh slice so eviction churn (not a warmed hit loop) is measured
        slices = [[(rng.zipf(1.2, batch) - 1) % vocab
                   for _ in range(per_pass)]
                  for _ in range(passes + 2)]      # +2 warmup passes
        impls = {"vectorized": DeviceEmbeddingCache(capacity, dim,
                                                    fetch_fn=fetch),
                 "per_id": SeedPerIdCache(capacity, dim, fetch_fn=fetch)}
        times = {}
        for name, cache in impls.items():
            cursor = {"i": 0}

            def run_pass(cache=cache, cursor=cursor):
                batches = slices[cursor["i"] % len(slices)]
                cursor["i"] += 1
                for s in batches:
                    out = cache.query(s)
                jax.block_until_ready(out)

            times[name] = time_fn(run_pass, warmup=2,
                                  iters=passes)["min_s"]
            qps = per_pass * batch / times[name]
            report.add(f"hps_lookup.b{batch}.{name}", times[name],
                       f"ids/s={qps:.0f}")
        speedup = times["per_id"] / times["vectorized"]
        report.add(f"hps_lookup.b{batch}.speedup", speedup,
                   f"x={speedup:.1f}")


class CpuBaseline:
    """Dict-of-rows lookup + numpy MLP — no device, no cache."""

    def __init__(self, model, params):
        self.model = model
        logical = model.embedding.export_logical(params["embedding"])
        self.tables = {}
        g = model.embedding.groups["dp"]
        mega = np.asarray(logical["dp"])
        for i, (t, off) in enumerate(zip(g.tables, g.offsets)):
            end = g.offsets[i + 1] if i + 1 < g.num_tables else g.total_rows
            self.tables[i] = {j: mega[off + j] for j in range(end - off)}
        self.dense_params = jax.tree.map(
            np.asarray, {k: v for k, v in params.items()
                         if k != "embedding"})

    def predict(self, dense, cat):
        b, t, h = cat.shape
        d = next(iter(self.tables[0].values())).shape[0]
        emb = np.zeros((b, t, d), np.float32)
        for bi in range(b):
            for ti in range(t):
                for hi in range(h):
                    v = cat[bi, ti, hi]
                    if v >= 0:
                        emb[bi, ti] += self.tables[ti][int(v)]
        # numpy dense net (bottom mlp + interaction + top mlp)
        p = self.dense_params
        x = dense
        i = 0
        while f"w{i}" in p["bottom"]:
            x = np.maximum(x @ p["bottom"][f"w{i}"] + p["bottom"][f"b{i}"],
                           0)
            i += 1
        feats = np.concatenate([x[:, None, :], emb], axis=1)
        gram = np.einsum("bfd,bgd->bfg", feats, feats)
        iu, ju = np.tril_indices(feats.shape[1], -1)
        top_in = np.concatenate([x, gram[:, iu, ju]], axis=1)
        i = 0
        h_ = top_in
        n = len(p["top"]) // 2
        while f"w{i}" in p["top"]:
            h_ = h_ @ p["top"][f"w{i}"] + p["top"][f"b{i}"]
            if i < n - 1:
                h_ = np.maximum(h_, 0)
            i += 1
        return 1 / (1 + np.exp(-h_[:, 0]))


def run(report: Report, tmp_root: str = "artifacts/bench_hps"):
    lookup_throughput(report)
    cfg0 = RECSYS_ARCHS["dlrm-criteo"]
    tables = tuple(dataclasses.replace(
        t, vocab_size=min(t.vocab_size, 30000), dim=32,
        strategy="data_parallel") for t in cfg0.tables[:8])
    cfg = dataclasses.replace(cfg0, tables=tables, embedding_dim=32,
                              bottom_mlp=(64, 32),
                              top_mlp=(128, 64, 1))
    mesh = make_test_mesh((1, 1))
    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=64)
        params = model.init(jax.random.PRNGKey(0))
        pdb = PersistentDB(tmp_root)
        deploy_from_training(model, params, pdb, "dlrm-bench")
        hps = HPS("dlrm-bench", cfg.tables, pdb, cache_capacity=4096)
        dense_params = {k: v for k, v in params.items()
                        if k != "embedding"}
        server = InferenceServer(model, dense_params, hps)
        baseline = CpuBaseline(model, params)

        for batch_size in (1, 16, 256, 2048):
            ds = SyntheticCTR(cfg, batch_size)
            b = ds.batch(0)
            # warm the cache with the zipf head
            for s in range(3):
                w = ds.batch(s + 100)
                server.predict(w["dense"], w["cat"])

            t_hps = time_fn(lambda: server.predict(b["dense"], b["cat"]),
                            iters=5)["min_s"]
            t_cpu = time_fn(lambda: baseline.predict(b["dense"], b["cat"]),
                            warmup=1, iters=3)["min_s"]
            report.add(f"hps_infer.b{batch_size}.hps", t_hps,
                       f"qps={batch_size / t_hps:.0f}")
            report.add(f"hps_infer.b{batch_size}.cpu_baseline", t_cpu,
                       f"qps={batch_size / t_cpu:.0f}")
            report.add(f"hps_infer.b{batch_size}.speedup", t_cpu / t_hps,
                       f"x={t_cpu / t_hps:.1f}")
        hit = np.mean(list(hps.stats()["l1_hit_rate"].values()))
        report.add("hps_infer.l1_hit_rate", hit, f"rate={hit:.3f}")
