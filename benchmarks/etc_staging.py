"""Paper §1 "Online training" — Embedding Training Cache staging throughput.

Measures rows/s for the host-side staging step (pull + evict + remap)
against both PS tiers (StagedPS host-memory, CachedPS disk memmap), at
several cache capacities, plus the hit behaviour on a Zipf stream."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Report
from repro.configs.base import EmbeddingTableConfig
from repro.core.etc.cache import EmbeddingTrainingCache
from repro.core.etc.parameter_server import CachedPS, StagedPS


def _zipf_ids(rng, vocab, size, a=1.2):
    u = rng.random(size)
    x = (u * ((vocab + 1.0) ** (1 - a) - 1.0) + 1.0) ** (1 / (1 - a))
    return np.clip(np.floor(x).astype(np.int64) - 1, 0, vocab - 1) \
        .astype(np.int32)


def run(report: Report, tmp_root: str = "artifacts/bench_etc"):
    vocab, dim, batch = 500_000, 64, 1024
    tabs = [EmbeddingTableConfig("t0", vocab, dim, hotness=2)]
    rng = np.random.default_rng(0)

    for ps_name, ps in (("staged", StagedPS(tabs)),
                        ("cached", CachedPS(tabs, tmp_root))):
        for cap in (4096, 65536):
            etc = EmbeddingTrainingCache(tabs, capacity=cap, ps=ps)
            params = etc.init_params()
            steps, t0 = 8, time.perf_counter()
            rows_seen = 0
            for s in range(steps):
                cat = _zipf_ids(rng, vocab, (batch, 1, 2))
                params, _ = etc.prepare(params, cat)
                rows_seen += (cat >= 0).sum()
            dt = time.perf_counter() - t0
            report.add(
                f"etc_staging.{ps_name}.cap{cap}", dt / steps,
                f"ids_per_s={rows_seen / dt:.0f} pulls={etc.pulls} "
                f"evictions={etc.evictions}")
