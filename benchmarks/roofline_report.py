"""Aggregate the dry-run artifacts into the §Roofline table.

Reads ``artifacts/dryrun/*.json`` and emits a markdown table with the
three roofline terms, the dominant bottleneck, the model-FLOPs ratio, and
the roofline fraction (model_flops-based MFU bound at the step-time lower
bound).

Also re-surfaces the HPS L1 lookup/pipeline numbers that
``benchmarks.hps_speedup`` persisted to ``artifacts/hps_lookup.json``:
the serving-path regressions ride along in ``bench_results.csv`` whenever
the roofline report runs, even if the (slow) HPS bench itself did not."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.launch.hlo_analysis import PEAK_FLOPS


def load_records(outdir: str = "artifacts/dryrun",
                 variant: Optional[str] = "baseline") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant and r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def roofline_fraction(rec: Dict) -> Optional[float]:
    """Useful-FLOPs MFU at the roofline lower bound: how close the step
    would run to peak if it hit every roofline term simultaneously."""
    a = rec.get("analysis")
    if not a or rec.get("status") != "ok":
        return None
    step = a["step_s_lower_bound"]
    if step <= 0:
        return None
    useful = rec["model_flops"] / rec["n_devices"]
    return useful / step / PEAK_FLOPS


def fmt_row(rec: Dict) -> str:
    a = rec.get("analysis", {})
    mem = rec.get("memory", {})
    if rec.get("status") == "skipped":
        return (f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"skipped ({rec.get('reason', '')[:40]}…) "
                "| | | | | |")
    if rec.get("status") != "ok":
        return (f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"ERROR | | | | | |")
    rf = roofline_fraction(rec)
    return ("| {arch} | {shape} | {mesh} | {tc:.2f} | {tm:.2f} | {tn:.2f} "
            "| {dom} | {ratio:.2f} | {rf:.1%} |").format(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        tc=a["compute_s"] * 1e3, tm=a["memory_s"] * 1e3,
        tn=a["collective_s"] * 1e3, dom=a["dominant"],
        ratio=rec.get("model_flops_ratio") or 0.0, rf=rf or 0.0)


HEADER = ("| arch | shape | mesh | Tcompute (ms) | Tmemory (ms) | "
          "Tcollective (ms) | dominant | model/HLO FLOPs | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|")


def table(outdir: str = "artifacts/dryrun", mesh: Optional[str] = None,
          variant: Optional[str] = "baseline") -> str:
    rows = [HEADER]
    for r in load_records(outdir, variant):
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(fmt_row(r))
    return "\n".join(rows)


def l1_lookup_rows(path: str = "artifacts/hps_lookup.json") -> List[Dict]:
    """The persisted HPS L1 lookup/pipeline rows (empty if never run)."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def loadtest_rows(path: str = "artifacts/loadtest.json") -> List[Dict]:
    """Flatten the last ``launch.loadtest`` run (empty if never run):
    one row per (phase, model) with the delivered latency picture and
    the admission counters, so SLO serving regressions ride along in
    ``bench_results.csv`` like the L1 serving numbers do."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    rows = []
    for phase, ph in sorted(data.get("phases", {}).items()):
        for model, m in sorted(ph.get("client", {})
                               .get("models", {}).items()):
            lat = m.get("latency_ms", {})
            srv = ph.get("server", {}).get(model, {})
            shed = srv.get("requests_shed", 0) \
                + srv.get("requests_expired", 0)
            rows.append({
                "name": f"{phase}.{model}",
                "p99_ms": lat.get("p99", 0.0),
                "derived": (f"p50_ms={lat.get('p50', 0):.1f} "
                            f"p999_ms={lat.get('p999', 0):.1f} "
                            f"delivered={m.get('delivered', 0)} "
                            f"shed={shed} "
                            f"slo_viol="
                            f"{srv.get('slo_violations', 0)}"),
            })
    return rows


def run(report):
    for row in l1_lookup_rows():
        # re-emit under the roofline namespace so the serving numbers
        # land in bench_results.csv alongside the step-time bounds
        report.add(f"roofline.l1.{row['name']}",
                   row["us_per_call"] * 1e-6, row["derived"])
    for row in loadtest_rows():
        report.add(f"roofline.loadtest.{row['name']}",
                   row["p99_ms"] * 1e-3, row["derived"])
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    if not ok:
        report.add("roofline.no_artifacts", 0.0,
                   "run repro.launch.dryrun first")
        return
    for r in ok:
        if r["mesh"] != "single":
            continue
        a = r["analysis"]
        rf = roofline_fraction(r)
        report.add(
            f"roofline.{r['arch']}.{r['shape']}",
            a["step_s_lower_bound"],
            f"dom={a['dominant']} frac={rf:.3f} ratio="
            f"{r.get('model_flops_ratio') or 0:.2f}")


if __name__ == "__main__":
    import sys
    print(table(mesh=sys.argv[1] if len(sys.argv) > 1 else None))
