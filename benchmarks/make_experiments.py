"""Regenerate the data-driven tables in EXPERIMENTS.md from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments
Writes EXPERIMENTS.md from the template blocks below + artifacts/dryrun.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline_report import (
    HEADER, fmt_row, load_records, roofline_fraction,
)

ARTS = "artifacts/dryrun"


def _load(variant):
    return [r for r in load_records(ARTS, variant=None)
            if r.get("variant") == variant]


def dryrun_section():
    base = _load("baseline")
    ok = [r for r in base if r.get("status") == "ok"]
    skipped = [r for r in base if r.get("status") == "skipped"]
    lm = [r for r in ok if r["shape"] != "train_65k"]
    rows = ["## §Dry-run", ""]
    rows.append(
        f"`python -m repro.launch.dryrun --all --mesh both` — "
        f"**{len(ok)} cells compiled OK** "
        f"({len(lm)} LM + {len(ok) - len(lm)} recsys), "
        f"{len(skipped)} skipped by policy (full-attention archs × "
        f"long_500k, per DESIGN.md §5). Meshes: single-pod (16, 16) = 256 "
        f"chips and multi-pod (2, 16, 16) = 512 chips; every cell lowers "
        f"AND compiles on both, proving the `pod` axis shards.")
    rows.append("")
    rows.append("Per-cell artifacts (memory_analysis, cost_analysis, "
                "collective schedule, trip-count-aware roofline terms) in "
                "`artifacts/dryrun/*.json`. Summary (single-pod, baseline "
                "variant):")
    rows.append("")
    rows.append("| arch | shape | compile_s | peak bytes/device | "
                "collective bytes/device/step | embed mode |")
    rows.append("|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "single":
            continue
        a = r["analysis"]
        rows.append(
            "| {} | {} | {} | {:.2f} GiB | {:.2f} GiB | {} |".format(
                r["arch"], r["shape"], r.get("compile_s", "-"),
                r["memory"]["peak_estimate_bytes"] / 2 ** 30,
                a["coll_bytes"] / 2 ** 30, r.get("embed_mode", "—")))
    rows.append("")
    rows.append("Multi-pod consistency: per-device FLOPs halve going "
                "256→512 chips for every train/prefill cell (verified in "
                "the artifacts; e.g. olmo-1b train_4k flops ratio "
                "multi/single = 0.50) — the `pod` axis carries data "
                "parallelism as designed.")
    return "\n".join(rows)


def roofline_section(variant="baseline", title="§Roofline"):
    recs = [r for r in _load(variant) if r.get("mesh") == "single"]
    rows = [f"## {title}", ""]
    rows.append(
        "Terms per device per step, from the partitioned HLO "
        "(trip-count-aware — see `launch/hlo_analysis.py`; "
        "`cost_analysis()` on XLA:CPU counts loop bodies once, so it is "
        "recorded per-cell for cross-checking but the table uses the "
        "analyzer). Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s "
        "ICI per link (TPU v5e).")
    rows.append("")
    rows.append("`roofline frac` = (MODEL_FLOPS / n_chips) / "
                "step_lower_bound / peak_FLOPs — the useful-compute MFU "
                "bound implied by the dominant term.")
    rows.append("")
    rows.append(HEADER)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        rows.append(fmt_row(r))
    return "\n".join(rows)


def opt_vs_base_table():
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in _load("baseline")}
    opt = {(r["arch"], r["shape"], r["mesh"]): r for r in _load("optimized")}
    rows = []
    rows.append("| arch | shape | baseline bound (ms) | optimized bound "
                "(ms) | speedup | baseline frac | optimized frac |")
    rows.append("|---|---|---|---|---|---|---|")
    for key in sorted(base):
        if key not in opt or key[2] != "single":
            continue
        b, o = base[key], opt[key]
        if b.get("status") != "ok" or o.get("status") != "ok":
            continue
        tb = b["analysis"]["step_s_lower_bound"]
        to = o["analysis"]["step_s_lower_bound"]
        fb = roofline_fraction(b) or 0
        fo = roofline_fraction(o) or 0
        rows.append("| {} | {} | {:.2f} | {:.2f} | {:.2f}x | {:.1%} | "
                    "{:.1%} |".format(
                        key[0], key[1], tb * 1e3, to * 1e3,
                        tb / to if to else 0, fb, fo))
    return "\n".join(rows)


def multipod_table():
    """Single-pod (256) vs multi-pod (512) scaling, optimized variant."""
    opt = {(r["arch"], r["shape"], r["mesh"]): r for r in _load("optimized")}
    rows = []
    rows.append("| arch | shape | single bound (ms) | multi bound (ms) | "
                "scaling | multi coll GiB |")
    rows.append("|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(opt.items()):
        if mesh != "single" or r.get("status") != "ok":
            continue
        m = opt.get((arch, shape, "multi"))
        if not m or m.get("status") != "ok":
            continue
        ts = r["analysis"]["step_s_lower_bound"]
        tm = m["analysis"]["step_s_lower_bound"]
        # ideal: multi bound = single/2 (2x devices) for fixed global work
        eff = (ts / tm) / 2.0 if tm else 0.0
        rows.append("| {} | {} | {:.2f} | {:.2f} | {:.0%} | {:.2f} |"
                    .format(arch, shape, ts * 1e3, tm * 1e3, eff,
                            m["analysis"]["coll_bytes"] / 2 ** 30))
    return "\n".join(rows)


def main():
    tmpl_path = "EXPERIMENTS.template.md"
    out = open(tmpl_path).read() if os.path.exists(tmpl_path) else ""
    body = out.replace("{{DRYRUN}}", dryrun_section()) \
              .replace("{{ROOFLINE}}", roofline_section()) \
              .replace("{{OPT_TABLE}}", opt_vs_base_table()) \
              .replace("{{MULTIPOD}}", multipod_table())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(body)
    print("wrote EXPERIMENTS.md",
          f"({len(body.splitlines())} lines)")


if __name__ == "__main__":
    main()
