"""Kernel microbenches: Pallas (interpret on CPU — correctness-speed only;
the BlockSpec tiling targets TPU) vs the pure-jnp oracle, over the shapes
that dominate the DLRM hot loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report, time_fn
from repro.kernels import ops, ref


def run(report: Report):
    key = jax.random.PRNGKey(0)

    for v, d, b, h in ((8192, 128, 512, 1), (65536, 128, 2048, 4)):
        table = jax.random.normal(key, (v, d), jnp.float32)
        rows = jax.random.randint(jax.random.fold_in(key, 1),
                                  (b, h), -1, v)
        jk = jax.jit(lambda t, r: ops.fused_embedding_lookup(t, r))
        jr = jax.jit(lambda t, r: ref.embedding_lookup_ref(t, r))
        tk = time_fn(jk, table, rows, iters=3)["min_s"]
        tr = time_fn(jr, table, rows, iters=3)["min_s"]
        report.add(f"kernel.lookup.V{v}xD{d}.pallas_interp", tk,
                   f"jnp_oracle_us={tr * 1e6:.1f}")

    for b, f, d in ((2048, 27, 128),):
        x = jax.random.normal(key, (b, f, d), jnp.float32)
        jk = jax.jit(lambda x: ops.dot_interaction(x))
        jr = jax.jit(lambda x: ref.dot_interaction_ref(x))
        tk = time_fn(jk, x, iters=3)["min_s"]
        tr = time_fn(jr, x, iters=3)["min_s"]
        report.add(f"kernel.interaction.B{b}xF{f}.pallas_interp", tk,
                   f"jnp_oracle_us={tr * 1e6:.1f}")
