"""Kernel microbenches: Pallas (interpret on CPU — correctness-speed only;
the BlockSpec tiling targets TPU) vs the pure-jnp oracle, over the shapes
that dominate the DLRM hot loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report, time_fn
from repro.kernels import ops, ref


def run(report: Report):
    key = jax.random.PRNGKey(0)

    for v, d, b, h in ((8192, 128, 512, 1), (65536, 128, 2048, 4)):
        table = jax.random.normal(key, (v, d), jnp.float32)
        rows = jax.random.randint(jax.random.fold_in(key, 1),
                                  (b, h), -1, v)
        jk = jax.jit(lambda t, r: ops.fused_embedding_lookup(t, r))
        jr = jax.jit(lambda t, r: ref.embedding_lookup_ref(t, r))
        tk = time_fn(jk, table, rows, iters=3)["min_s"]
        tr = time_fn(jr, table, rows, iters=3)["min_s"]
        report.add(f"kernel.lookup.V{v}xD{d}.pallas_interp", tk,
                   f"jnp_oracle_us={tr * 1e6:.1f}")

    for b, f, d in ((2048, 27, 128),):
        x = jax.random.normal(key, (b, f, d), jnp.float32)
        jk = jax.jit(lambda x: ops.dot_interaction(x))
        jr = jax.jit(lambda x: ref.dot_interaction_ref(x))
        tk = time_fn(jk, x, iters=3)["min_s"]
        tr = time_fn(jr, x, iters=3)["min_s"]
        report.add(f"kernel.interaction.B{b}xF{f}.pallas_interp", tk,
                   f"jnp_oracle_us={tr * 1e6:.1f}")

    # fused dequantize-gather (int8 L1 payload + per-row scales) vs the
    # two-dispatch reference: gather the int8 rows + scales first, THEN
    # dequantize in a second jitted op. The fused kernel folds the scale
    # into the one-hot before its single MXU pass, so the compressed
    # tile never materializes at f32 width between dispatches.
    for c, d, n in ((8192, 32, 2048), (16384, 64, 4096)):
        payload = jax.random.randint(jax.random.fold_in(key, 2),
                                     (c, d), -127, 128, jnp.int8)
        scales = jax.random.uniform(jax.random.fold_in(key, 3), (c,),
                                    jnp.float32, 0.01, 2.0)
        slots = jax.random.randint(jax.random.fold_in(key, 4),
                                   (n,), -1, c)

        def fused(p, sc, s):
            return ops.cache_gather(p, s, scales=sc, use_kernel=True)

        @jax.jit
        def gathered_then_dequant_rows(p, sc, s):
            valid = s >= 0
            safe = jnp.where(valid, s, 0)
            return (jnp.take(p, safe, axis=0),
                    jnp.take(sc, safe), valid)

        @jax.jit
        def dequant(rows, rsc, valid):
            out = rows.astype(jnp.float32) * rsc[:, None]
            return jnp.where(valid[:, None], out, 0.0)

        def two_dispatch(p, sc, s):
            rows, rsc, valid = gathered_then_dequant_rows(p, sc, s)
            return dequant(rows, rsc, valid)

        tk = time_fn(fused, payload, scales, slots, iters=3)["min_s"]
        tr = time_fn(two_dispatch, payload, scales, slots,
                     iters=3)["min_s"]
        report.add(f"kernel.dequant_gather.C{c}xD{d}.fused_interp", tk,
                   f"two_dispatch_us={tr * 1e6:.1f}")
