"""Paper §1 table analogue — the three embedding layer types compared.

Single-process measurement runs the three strategies on an 8-virtual-device
mesh IN A SUBPROCESS (collective code paths are real), reporting per-step
time and the modeled communication bytes from the planner's cost model.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import Report

BODY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import (DATA_PARALLEL, DISTRIBUTED, HYBRID,
                                LOCALIZED, EmbeddingTableConfig)
from repro.core.embedding import EmbeddingCollection
from repro.launch.mesh import make_test_mesh

B, T, H, V, D = 4096, 8, 4, 200_000, 64
mesh = make_test_mesh((4, 2))

def bench(strategy, comm):
    tabs = [EmbeddingTableConfig(f"t{i}", V, D, hotness=H,
                                 strategy=strategy, hot_fraction=0.02)
            for i in range(T)]
    with mesh:
        coll = EmbeddingCollection(tabs, mesh, comm=comm,
                                   capacity_factor=2.0,
                                   compute_dtype=jnp.bfloat16)
        params = coll.init(jax.random.PRNGKey(0))
        # zipf-ish ids so the hybrid hot cache sees hits
        u = jax.random.uniform(jax.random.PRNGKey(1), (B, T, H))
        ids = jnp.minimum((u ** 4 * V), V - 1).astype(jnp.int32)
        fn = jax.jit(lambda p, i: coll.lookup(p, i))
        fn(params, ids)[0].block_until_ready()
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            fn(params, ids).block_until_ready()
            times.append(time.perf_counter() - t0)
        return min(times)

rows = []
for strategy, comm in ((DATA_PARALLEL, "allgather_rs"),
                       (LOCALIZED, "allgather_rs"),
                       (DISTRIBUTED, "allgather_rs"),
                       (DISTRIBUTED, "all_to_all"),
                       (HYBRID, "allgather_rs"),
                       (HYBRID, "all_to_all")):
    t = bench(strategy, comm)
    print(f"ROW,{strategy}.{comm},{t*1e6:.1f}")
"""


def run(report: Report):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", BODY], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        report.add("embedding_strategies.FAILED", 0.0,
                   proc.stderr.strip().replace("\n", ";")[-200:])
        return
    for line in proc.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us = line.split(",")
            report.add(f"embedding_strategy.{name}", float(us) / 1e6,
                       "8dev_mesh B=4096 T=8 H=4 V=200k D=64")
