"""Paper table 1 analogue — DLRM training throughput.

The paper: HugeCTR on 8x A100 is 24.6x faster than PyTorch on 4x4-socket
CPU nodes. That ratio is hardware (HBM/MXU vs CPU) and cannot reproduce
on one CPU. What CAN be measured here, honestly:

  1. this module — the cost of the distribution engine itself at one
     device (framework step vs a plain-gather reference, both f32+SGD,
     both jitted): the overhead you pay when you don't need sharding;
  2. `embedding_strategies.py` (8 devices) — the paper's actual point:
     placement strategy changes step time ~4.6x at fixed work;
  3. `roofline_report.py` — the projected TPU-pod step time.

``dlrm_train.engine_overhead`` < ~1.15x is the target: the sharding
machinery (shard_map, mega-table indirection, mean-mask handling) must
be nearly free when degenerate.

The GENERIC-EXECUTOR arm: since the graph-API redesign every model's
dense net executes as a compiled ``DenseGraphProgram`` (one traced node
loop) instead of the hand-written fixed pipeline. Both lower to the
same jitted XLA computation, so ``dlrm_train.graph_overhead`` ~ 1.0x is
the regression bar; the pair of step times is persisted to
``artifacts/train_graph.json`` so a compile-path regression is visible
run over run."""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, time_fn
from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.train.train_step import build_train_step, init_opt_state


def _shrink(cfg, vocab_cap=40000, batch=2048):
    tables = tuple(dataclasses.replace(t, vocab_size=min(t.vocab_size,
                                                         vocab_cap))
                   for t in cfg.tables)
    return dataclasses.replace(cfg, tables=tables), batch


def _naive_f32_step(cfg, mesh):
    """Reference implementation: per-table python-loop gathers, f32.

    All tables are pinned data_parallel so the naive per-table loop can
    read one replicated mega-table (the planner would otherwise shard
    the larger ones)."""
    tables = tuple(dataclasses.replace(t, strategy="data_parallel")
                   for t in cfg.tables)
    model = RecsysModel(
        dataclasses.replace(cfg, dtype="f32", tables=tables), mesh,
        global_batch=2048)

    def loss_fn(params, batch):
        # per-table loop of gathers (no mega-table, no pooling fusion)
        outs = []
        logical = model.embedding.export_logical(params["embedding"])
        mega = logical.get("dp")
        offs = model.embedding.groups["dp"].offsets
        for i, t in enumerate(cfg.tables):
            ids = batch["cat"][:, i, :]
            valid = ids >= 0
            rows = jnp.where(valid, ids + offs[i], 0)
            vecs = mega[rows] * valid[..., None]
            outs.append(vecs.sum(1))
        emb = jnp.stack(outs, axis=1)
        logits = model.apply_dense(params, batch["dense"], emb)
        from repro.models.recsys.layers import bce_with_logits
        return bce_with_logits(logits, batch["label"])

    tcfg = TrainConfig(mixed_precision=False)
    from repro.optim.optimizers import make
    opt = make("sgd", tcfg)

    def step(params, opt_state, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        p, s = opt.update(g, opt_state, params)
        return p, s, loss

    return model, jax.jit(step), opt


def run(report: Report):
    mesh = make_test_mesh((1, 1))
    cfg0 = RECSYS_ARCHS["dlrm-criteo"]
    cfg, batch_size = _shrink(cfg0)
    ds = SyntheticCTR(cfg, batch_size)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    with mesh:
        # optimized path (f32 on CPU; same SGD as the naive reference)
        model = RecsysModel(dataclasses.replace(cfg, dtype="f32"), mesh,
                            global_batch=batch_size)
        params = model.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(dense_optimizer="sgd", sparse_optimizer="sgd",
                           mixed_precision=False)
        step = jax.jit(build_train_step(model, tcfg))
        opt_state = init_opt_state(params, tcfg)

        def opt_step():
            return step(params, opt_state, batch)

        t_opt = time_fn(opt_step, iters=4)["min_s"]
        report.add("dlrm_train.optimized", t_opt,
                   f"samples_per_s={batch_size / t_opt:.0f}")

        # naive reference
        nmodel, nstep, nopt = _naive_f32_step(cfg, mesh)
        nparams = nmodel.init(jax.random.PRNGKey(0))
        nopt_state = nopt.init(nparams)

        def naive_step():
            return nstep(nparams, nopt_state, batch)

        t_naive = time_fn(naive_step, iters=4)["min_s"]
        report.add("dlrm_train.naive_f32", t_naive,
                   f"samples_per_s={batch_size / t_naive:.0f}")
        report.add("dlrm_train.engine_overhead", t_opt / t_naive,
                   f"framework_vs_plain_x={t_opt / t_naive:.2f} "
                   "(1-device degenerate case; see embedding_strategies "
                   "for the multi-device win)")

        # generic executor vs the pre-refactor fixed pipeline: same
        # model, same params/opt state/batch — only the dense forward
        # differs (compiled DenseGraphProgram vs apply_dense_reference)
        rmodel = RecsysModel(dataclasses.replace(cfg, dtype="f32"), mesh,
                             global_batch=batch_size,
                             dense_executor="reference")
        rstep = jax.jit(build_train_step(rmodel, tcfg))

        def ref_step():
            return rstep(params, opt_state, batch)

        t_ref = time_fn(ref_step, iters=4)["min_s"]
        ratio = t_opt / t_ref
        report.add("dlrm_train.compiled_graph", t_opt,
                   f"samples_per_s={batch_size / t_opt:.0f}")
        report.add("dlrm_train.fixed_pipeline", t_ref,
                   f"samples_per_s={batch_size / t_ref:.0f}")
        report.add("dlrm_train.graph_overhead", ratio,
                   f"compiled_vs_fixed_x={ratio:.2f}")
    scaling = _mp_scaling(report)
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/train_graph.json", "w") as f:
        json.dump({"batch": batch_size,
                   "compiled_graph_s": t_opt,
                   "fixed_pipeline_s": t_ref,
                   "graph_overhead_x": ratio,
                   "mp_scaling": scaling}, f, indent=1)


#: subprocess body for one mesh arm: forced host devices must be set
#: before jax imports, so each mesh size gets its own interpreter
_MP_ARM = r"""
import os
os.environ["XLA_FLAGS"] = \
    "--xla_force_host_platform_device_count={n_dev}"
import json, time
import importlib
import jax
from repro.api import Solver

mod = importlib.import_module("repro.configs.dlrm_criteo")
m = mod.build_model(smoke=True, solver=Solver(
    batch_size={batch}, lr=1e-2, mesh_shape={shape}))
m.compile()
m.fit(steps=2)                       # warm the jitted sharded step
t0 = time.perf_counter()
hist = m.fit(steps={steps})
dt = (time.perf_counter() - t0) / {steps}
print("MP_ARM_RESULT " + json.dumps(
    {{"mesh": "{shape}", "devices": {n_dev}, "step_s": dt}}))
"""


def _mp_scaling(report: Report, batch: int = 512, steps: int = 8):
    """Multi-device scaling arm: the same graph-API ``fit()`` on forced
    host meshes of 1 / 2 / 4 devices. Host devices share the machine's
    cores, so the honest signal is the distribution-engine overhead per
    step staying bounded as the mesh grows — not a speedup (that needs
    real accelerators; see roofline_report for the projection)."""
    rows = []
    for shape in ((1, 1), (2, 1), (2, 2)):
        n_dev = shape[0] * shape[1]
        code = _MP_ARM.format(n_dev=n_dev, shape=shape, batch=batch,
                              steps=steps)
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True,
                              timeout=600)
        if proc.returncode != 0:
            report.add(f"dlrm_train.mp_{n_dev}dev", float("nan"),
                       f"FAILED: {proc.stderr.strip()[-200:]}")
            continue
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("MP_ARM_RESULT ")][-1]
        row = json.loads(line[len("MP_ARM_RESULT "):])
        rows.append(row)
        report.add(f"dlrm_train.mp_{n_dev}dev", row["step_s"],
                   f"mesh={row['mesh']} "
                   f"samples_per_s={batch / row['step_s']:.0f}")
    if len(rows) > 1:
        base = rows[0]["step_s"]
        worst = max(r["step_s"] / base for r in rows[1:])
        report.add("dlrm_train.mp_overhead", worst,
                   f"worst_mesh_vs_1dev_x={worst:.2f} (host devices "
                   "share cores; bounded overhead is the bar)")
    return rows
