"""Benchmark harness — one module per paper table/claim.

Usage:
  PYTHONPATH=src python -m benchmarks.run             # all benches
  PYTHONPATH=src python -m benchmarks.run kernel hps  # a subset

Prints ``name,us_per_call,derived`` CSV (also written to
``artifacts/bench_results.csv``)."""
from __future__ import annotations

import os
import sys

from benchmarks.common import Report

BENCHES = ("kernel", "train", "hps", "etc", "online", "strategies",
           "roofline")


def main() -> None:
    which = [a for a in sys.argv[1:] if not a.startswith("-")] or BENCHES
    report = Report()
    if "kernel" in which:
        from benchmarks import kernel_bench
        kernel_bench.run(report)
    if "train" in which:
        from benchmarks import train_throughput
        train_throughput.run(report)
    if "hps" in which:
        from benchmarks import hps_speedup
        hps_speedup.run(report)
    if "etc" in which:
        from benchmarks import etc_staging
        etc_staging.run(report)
    if "online" in which:
        from benchmarks import online_freshness
        online_freshness.run(report)
    if "strategies" in which:
        from benchmarks import embedding_strategies
        embedding_strategies.run(report)
    if "roofline" in which:
        from benchmarks import roofline_report
        roofline_report.run(report)
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench_results.csv", "w") as f:
        f.write(report.dump() + "\n")
    print(f"\n{len(report.rows)} rows -> artifacts/bench_results.csv")


if __name__ == "__main__":
    main()
