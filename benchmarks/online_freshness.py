"""Paper §3 "Online model updating" — the train->serve freshness loop.

Two claims measured:

* **Update freshness lag** — wall time from a pass boundary publishing
  its versioned update batch to the update being VISIBLE in live
  predictions (consumer versions reached it and a probe moved onto the
  freshly-trained oracle), via the full train-while-serving loop in
  ``repro.launch.online_train``.
* **ETC step overhead** — marginal seconds/step of ETC-staged training
  (host staging + PS traffic) vs the in-memory trainer on the same
  graph, jit compile cancelled out by differencing two run lengths.
"""
from __future__ import annotations

import time

from benchmarks.common import Report


def _fit_seconds(etc, steps: int) -> float:
    from repro.launch.online_train import build_model
    m = build_model(128)
    if etc is not None:
        m.solver.etc = etc
    m.compile()
    data_fn = m._reader_data_fn()
    t0 = time.perf_counter()
    m.fit(data_fn, steps=steps)
    return time.perf_counter() - t0


def run(report: Report):
    from repro.configs.base import ETCParams
    from repro.launch.online_train import run_online

    metrics = run_online(base_steps=20, online_steps=20, passes=2,
                         cache_rows=256, requests=5, verbose=False)
    report.add(
        "online.freshness_lag", metrics["freshness_lag_s"],
        f"polls={metrics['freshness_polls']} "
        f"versions={metrics['versions_published']} "
        f"msgs_applied={metrics['updates_applied']} "
        f"rows_refreshed={metrics['rows_refreshed']} "
        f"final_dist={metrics['final_dist']:.1e}")

    # marginal per-step cost: t(long) - t(short) cancels the compile
    short, long = 10, 30
    etc = ETCParams(cache_rows=256, passes=1)
    etc_s = (_fit_seconds(etc, long) - _fit_seconds(etc, short)) \
        / (long - short)
    mem_s = (_fit_seconds(None, long) - _fit_seconds(None, short)) \
        / (long - short)
    report.add("online.train_step.etc", etc_s,
               f"staging+ps_overhead_x={etc_s / max(mem_s, 1e-9):.2f}")
    report.add("online.train_step.inmem", mem_s, "")
