"""Shared benchmark helpers: timing, CSV rows, device sync."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np


def sync(tree):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return tree


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kwargs) -> Dict[str, float]:
    for _ in range(warmup):
        sync(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        sync(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    arr = np.asarray(times)
    return {"mean_s": float(arr.mean()), "min_s": float(arr.min()),
            "p50_s": float(np.percentile(arr, 50))}


class Report:
    """Collects ``name,us_per_call,derived`` CSV rows."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, seconds: float, derived: str = ""):
        self.rows.append(f"{name},{seconds * 1e6:.1f},{derived}")
        print(self.rows[-1], flush=True)

    def dump(self):
        return "\n".join(["name,us_per_call,derived"] + self.rows)
