"""Load-testing an ensemble deployment: open-loop traffic, latency
SLOs, and admission-controlled serving — end to end.

The walkthrough:

1. Train TWO recipes briefly and write one ensemble bundle
   (``api.deploy_ensemble`` — shared PDB/VDB/bus, per-model L1 caches).
2. Stand the bundle back up and arm each member's ADMISSION CONTROLLER:
   a bounded request queue, a declared latency SLO, and deadline-aware
   dynamic batching (grow groups toward ``max_batch`` while the oldest
   queued request's slack allows, cut early — and shed expired
   requests — when it doesn't).
3. Generate a SEEDED OPEN-LOOP workload: Poisson arrivals at a target
   qps, Zipf-skewed ids whose hot set drifts over time, and a 3:1
   traffic mix across the two models. Record it to a JSONL trace and
   drive the run from the replay — the trace IS the workload, so this
   exact run is reproducible anywhere.
4. Drive it open-loop (submission happens on schedule whether or not
   the servers keep up — late responses count against latency), then
   push a deliberate OVERLOAD phase and watch graceful shedding: typed
   ``ServerOverloaded`` rejections, never hung callers.
5. Print the per-model picture from both sides: client-observed
   p50/p99/p999 + delivered qps, and the servers' own shed / expiry /
   SLO-violation counters.

Run:  PYTHONPATH=src python examples/loadtest_ensemble.py
"""
import os
import tempfile

from repro.launch.loadtest import main as loadtest_main


def main():
    with tempfile.TemporaryDirectory(prefix="loadtest_demo_") as root:
        trace = os.path.join(root, "steady.jsonl")
        artifact = os.path.join(root, "loadtest.json")
        loadtest_main([
            # 1) demo deploy: 2-model ensemble bundle
            "--arch", "dlrm-criteo,dcn-criteo",
            "--train-steps", "10",
            "--deploy-dir", os.path.join(root, "bundle"),
            # 2) admission: bounded queue, 150ms SLO, deadline batching
            "--queue-depth", "32",
            "--slo-ms", "150",
            # 3) seeded workload: Poisson, drifting Zipf, 3:1 mix,
            #    recorded then replayed from the trace
            "--qps", "25", "--duration", "3", "--rows", "4",
            "--zipf-a", "1.2", "--drift-per-s", "0.02",
            "--mix", "dlrm-criteo-smoke=3,dcn-criteo-smoke=1",
            "--seed", "7",
            "--trace-out", trace,
            # 4) deliberate overload: watch sheds, not hangs
            "--overload-qps", "400", "--overload-duration", "1.5",
            "--artifacts", artifact,
            # (no --smoke-assert here: hot-set drift deliberately ages
            # the L1 caches, and a cold miss-batch shape can recompile
            # mid-phase — an occasional steady-phase expiry is the
            # drift regime working as intended, not a CI failure. The
            # CI loadtest-smoke job runs drift-free and asserts.)
        ])
        print(f"\ntrace was recorded and replayed from {trace}")


if __name__ == "__main__":
    main()
