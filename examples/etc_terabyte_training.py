"""Embedding Training Cache demo (paper §1 "Online training"):

train a model whose embedding tables DO NOT FIT in (simulated) device
memory — the ETC stages 4k-row working sets against a disk-backed
parameter server, exactly HugeCTR's Staged-PS/Cached-PS hierarchy.

Run:  PYTHONPATH=src python examples/etc_terabyte_training.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig, TrainConfig
from repro.core.etc.cache import EmbeddingTrainingCache, cached_lookup
from repro.core.etc.parameter_server import CachedPS
from repro.optim.sparse import rowwise_adagrad


def main():
    # 2 tables × 1M rows × 64 dims = 512 MB of f32 "model" vs a 4k-row cache
    vocab, dim, cap, batch = 1_000_000, 64, 1024, 512
    tabs = [EmbeddingTableConfig(f"t{i}", vocab, dim, hotness=2)
            for i in range(2)]

    with tempfile.TemporaryDirectory() as root:
        t0 = time.time()
        ps = CachedPS(tabs, root)      # disk-backed ground truth
        print(f"initialized {2 * vocab * dim * 4 / 2**20:.0f} MiB of "
              f"disk-backed tables in {time.time() - t0:.1f}s")
        etc = EmbeddingTrainingCache(tabs, capacity=cap, ps=ps)
        params = etc.init_params()
        print(f"device-resident cache: "
              f"{params['cache'].nbytes / 2**20:.1f} MiB "
              f"({cap} rows/table vs {vocab} total)")

        opt = rowwise_adagrad(TrainConfig(learning_rate=0.05))
        rng = np.random.default_rng(0)
        target_w = rng.normal(size=(dim,)).astype(np.float32)

        @jax.jit
        def train_step(params, remapped, labels):
            def loss_fn(p):
                pooled = cached_lookup(p, remapped)      # [B, T, D]
                logit = pooled.sum(1) @ jnp.asarray(target_w)
                return jnp.mean(
                    jnp.maximum(logit, 0) - logit * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))

            loss, g = jax.value_and_grad(loss_fn)(params)
            t, c, d_ = params["cache"].shape
            new_p, new_s = opt.update(
                {"x": g["cache"].reshape(t * c, d_)},
                {"acc": {"x": params["acc"].reshape(t * c)}},
                {"x": params["cache"].reshape(t * c, d_)})
            return {"cache": new_p["x"].reshape(t, c, d_),
                    "acc": new_s["acc"]["x"].reshape(t, c)}, loss

        def zipf(size):
            # a=1.6: hot head recurs often enough to learn within the demo
            u = rng.random(size)
            x = (u * ((vocab + 1.0) ** -0.6 - 1.0) + 1.0) ** (1 / -0.6)
            return np.clip(np.floor(x).astype(np.int64) - 1, 0,
                           vocab - 1).astype(np.int32)

        losses = []
        for i in range(60):
            cat = zipf((batch, 2, 2))
            params, remapped = etc.prepare(params, cat)  # host staging
            # planted signal: per-id parity — learnable purely through the
            # embedding rows, which is the point of the demo
            labels = (cat[:, 0, 0] % 2 == 0).astype(np.float32)
            params, loss = train_step(params, jnp.asarray(remapped),
                                      jnp.asarray(labels))
            losses.append(float(loss))
            if i % 10 == 0:
                print(f"step {i:3d} loss={losses[-1]:.4f} "
                      f"pulls={etc.pulls} evictions={etc.evictions}")

        etc.flush(params)
        ps.flush()
        first = float(np.mean(losses[:10]))
        last = float(np.mean(losses[-10:]))
        print(f"\nfinal: loss {first:.4f} -> {last:.4f} (10-step means); "
              f"{etc.pulls} rows pulled, {etc.evictions} evicted; "
              f"trained state persisted to disk ✓")
        assert last < first, "hot-id signal must be learnable"


if __name__ == "__main__":
    main()
