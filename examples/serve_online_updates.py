"""Online-training serving demo (paper Figure 2, blue + red paths).

A trainer keeps learning while an inference node serves:

  trainer --(Producer / Kafka-style bus)--> VDB + PDB --(refresh)--> L1

The script shows predictions drifting as online updates land, without the
server ever reloading the model.

Run:  PYTHONPATH=src python examples/serve_online_updates.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus, Producer
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training
from repro.train.train_step import build_train_step, init_opt_state


def main():
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    batch_size = 256
    bus = MessageBus()

    with mesh, tempfile.TemporaryDirectory() as root:
        # -- offline phase: initial train + deploy --------------------------
        model = RecsysModel(cfg, mesh, global_batch=batch_size)
        params = model.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(learning_rate=1e-2)
        step = jax.jit(build_train_step(model, tcfg))
        opt_state = init_opt_state(params, tcfg)
        data = SyntheticCTR(cfg, batch_size)
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, aux = step(params, opt_state, batch)

        pdb = PersistentDB(root)
        deploy_from_training(model, params, pdb, "online")
        hps = HPS("online", cfg.tables, pdb, cache_capacity=512, bus=bus)
        dense = {k: v for k, v in params.items() if k != "embedding"}
        # refresh is drained manually below (the serve loop isn't started,
        # so the server's own refresh_budget would not come into play)
        server = InferenceServer(model, dense, hps)

        probe = data.batch(777)
        p0 = server.predict(probe["dense"], probe["cat"])
        print(f"initial predictions: mean={p0.mean():.4f}")

        # -- online phase: keep training, stream updates --------------------
        producer = Producer(bus, "online")
        for i in range(10, 40):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, aux = step(params, opt_state, batch)
            if i % 10 == 9:
                # dump incremental updates: rows touched this window
                logical = model.embedding.export_logical(
                    params["embedding"])
                g = model.embedding.groups["dp"]
                mega = np.asarray(logical["dp"])
                for ti, (t, off) in enumerate(zip(g.tables, g.offsets)):
                    end = g.offsets[ti + 1] if ti + 1 < g.num_tables \
                        else g.total_rows
                    ids = np.unique(
                        np.asarray(batch["cat"])[:, ti, :].ravel())
                    ids = ids[ids >= 0]
                    producer.send(t.name, ids, mega[off + ids])
                producer.flush()
                # inference node polls the bus (updates land in L2/L3 and
                # mark the touched L1 rows dirty), then drains the
                # hotness-ordered refresh backlog in bounded chunks — the
                # same path the serve loop drives between batches
                applied = hps.apply_updates()
                refreshed = 0
                while hps.refresh_backlog():
                    refreshed += hps.refresh_step(budget=128)
                p = server.predict(probe["dense"], probe["cat"])
                drift = float(np.abs(p - p0).mean())
                print(f"window @step {i}: applied {applied} messages, "
                      f"refreshed {refreshed} L1 rows, "
                      f"prediction drift {drift:.5f}")
        assert drift > 0, "online updates must reach the server"
        print("online updates propagated trainer -> bus -> VDB/PDB -> L1 ✓")

        # -- the full L1/L2/L3 serving picture ------------------------------
        stats = hps.stats()
        hit = np.mean(list(stats["l1_hit_rate"].values()))
        l2 = stats["l2"]
        l3_rows = sum(stats["l3_fetches"]["rows"].values())
        print(f"L1: hit_rate={hit:.3f} over {len(hps.caches)} cached "
              f"tables; refresh: {stats['refresh']['rows_refreshed']} rows "
              f"in {stats['refresh']['chunks']} chunks, backlog "
              f"{stats['refresh']['backlog']}")
        print(f"L2: {stats['l2_hits']} hits / {stats['l2_misses']} misses; "
              f"{sum(t['rows'] for t in l2['tables'].values())} rows over "
              f"{len(l2['tables'])} tables x {l2['shards']} shard(s)")
        print(f"L3: {sum(stats['l3_fetches']['calls'].values())} fetches "
              f"({l3_rows} rows) fell through to the PDB")


if __name__ == "__main__":
    main()
