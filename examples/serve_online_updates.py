"""Online-training serving demo (paper Figure 2, blue + red paths).

A trainer keeps learning while an inference node serves TWO models from
one parameter-server process (the ensemble deployment unit: shared
PDB/VDB/bus, per-model L1 caches):

  trainer --(Producer / Kafka-style bus)--> VDB + PDB --(refresh)--> L1

The "online" model receives the update stream and its predictions drift;
the "static" model shares every storage level with it and must not move
at all — one model's updates never touch another's tables. Per-model
serving stats print at the end.

Run:  PYTHONPATH=src python examples/serve_online_updates.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus, Producer
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import (
    InferenceServer, MultiModelServer, deploy_from_training,
)
from repro.train.train_step import build_train_step, init_opt_state


def main():
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))
    batch_size = 256
    bus = MessageBus()

    with mesh, tempfile.TemporaryDirectory() as root:
        # -- offline phase: initial train + 2-model deploy ------------------
        model = RecsysModel(cfg, mesh, global_batch=batch_size)
        params = model.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(learning_rate=1e-2)
        step = jax.jit(build_train_step(model, tcfg))
        opt_state = init_opt_state(params, tcfg)
        data = SyntheticCTR(cfg, batch_size)
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, aux = step(params, opt_state, batch)

        # ONE storage backend, TWO deployed models: "online" gets the
        # update stream below, "static" is the same weights frozen —
        # it shares the PDB file store, the VolatileDB and the bus, yet
        # must never see the other model's updates
        pdb = PersistentDB(root)
        vdb = VolatileDB()
        dense = {k: v for k, v in params.items() if k != "embedding"}
        servers = {}
        for name in ("online", "static"):
            deploy_from_training(model, params, pdb, name)
            hps = HPS(name, cfg.tables, pdb, vdb=vdb, bus=bus,
                      cache_capacity=512)
            # refresh is drained manually below (the serve loops aren't
            # started, so the refresh_budget never comes into play)
            servers[name] = InferenceServer(model, dense, hps)
        server = MultiModelServer(servers, vdb=vdb, pdb=pdb, bus=bus)

        probe = data.batch(777)
        p0 = {name: server.predict(name, probe["dense"], probe["cat"])
              for name in server.models}
        print(f"initial predictions: "
              + " ".join(f"{n}.mean={p.mean():.4f}"
                         for n, p in p0.items()))

        # -- online phase: keep training, stream updates to ONE model -------
        producer = Producer(bus, "online")
        for i in range(10, 40):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, aux = step(params, opt_state, batch)
            if i % 10 == 9:
                # dump incremental updates: rows touched this window
                logical = model.embedding.export_logical(
                    params["embedding"])
                g = model.embedding.groups["dp"]
                mega = np.asarray(logical["dp"])
                for ti, (t, off) in enumerate(zip(g.tables, g.offsets)):
                    end = g.offsets[ti + 1] if ti + 1 < g.num_tables \
                        else g.total_rows
                    ids = np.unique(
                        np.asarray(batch["cat"])[:, ti, :].ravel())
                    ids = ids[ids >= 0]
                    producer.send(t.name, ids, mega[off + ids])
                producer.flush()
                # BOTH inference nodes poll the bus; only "online" has
                # matching topics, so only its L2/L3 rows change and
                # only its L1 rows go dirty — then drain the
                # hotness-ordered refresh backlog in bounded chunks,
                # the same path the serve loop drives between batches
                applied = {n: server[n].hps.apply_updates()
                           for n in server.models}
                refreshed = 0
                while server["online"].hps.refresh_backlog():
                    refreshed += server["online"].hps.refresh_step(
                        budget=128)
                p = {n: server.predict(n, probe["dense"], probe["cat"])
                     for n in server.models}
                drift = {n: float(np.abs(p[n] - p0[n]).mean())
                         for n in server.models}
                print(f"window @step {i}: applied {applied['online']} "
                      f"messages ({applied['static']} to static), "
                      f"refreshed {refreshed} L1 rows, drift "
                      + " ".join(f"{n}={d:.5f}"
                                 for n, d in drift.items()))
        assert drift["online"] > 0, "online updates must reach the server"
        assert drift["static"] == 0, \
            "the static model shares storage but must never drift"
        print("online updates propagated trainer -> bus -> VDB/PDB -> L1,"
              " static co-tenant untouched ✓")

        # -- the full L1/L2/L3 serving picture, PER MODEL -------------------
        for name, st in server.stats().items():
            s = st["hps"]
            hit = np.mean(list(s["l1_hit_rate"].values()))
            l2 = s["l2"]
            l3_rows = sum(s["l3_fetches"]["rows"].values())
            own = {t: v for t, v in l2["tables"].items()
                   if t.startswith(name + "/")}
            print(f"[{name}] L1: hit_rate={hit:.3f} over "
                  f"{len(server[name].hps.caches)} cached tables; "
                  f"refresh: {s['refresh']['rows_refreshed']} rows in "
                  f"{s['refresh']['chunks']} chunks, backlog "
                  f"{s['refresh']['backlog']}")
            print(f"[{name}] L2 (shared store, own namespace): "
                  f"{sum(t['rows'] for t in own.values())} rows over "
                  f"{len(own)} tables x {l2['shards']} shard(s); "
                  f"L3: {sum(s['l3_fetches']['calls'].values())} fetches "
                  f"({l3_rows} rows) fell through to the PDB")


if __name__ == "__main__":
    main()
