"""Assigned-architecture driver: pick any of the 10 LM archs (reduced to
CPU scale) and run a short pre-training loop with the hybrid (hot/cold)
vocab embedding — the paper's technique applied to LM token tables.

Run:  PYTHONPATH=src python examples/lm_pretrain_smoke.py --arch olmo-1b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import LM_ARCHS, reduce_for_smoke
from repro.launch.mesh import make_test_mesh
from repro.models.lm.backbone import LMModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=sorted(LM_ARCHS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduce_for_smoke(LM_ARCHS[args.arch])
    mesh = make_test_mesh((1, 1))
    print(f"arch={args.arch} (smoke-reduced): {cfg.num_layers}L "
          f"d={cfg.d_model} vocab={cfg.vocab_size} "
          f"pattern={cfg.block_pattern}")

    with mesh:
        model = LMModel(cfg, mesh, embed_mode="hybrid", hot_fraction=0.1,
                        q_chunk=32, k_chunk=32, loss_chunk=32)
        params = model.init(jax.random.PRNGKey(0))
        print(f"embed mode={model.embed_mode}: hot={model.hot_rows} rows "
              f"(replicated), cold={model.cold_rows} rows (sharded)")

        lr = 3e-3

        @jax.jit
        def step(params, tokens):
            def loss_fn(p):
                return model.train_loss(p, {"tokens": tokens})
            loss, g = jax.value_and_grad(loss_fn)(params)
            new = jax.tree.map(lambda p, gg: p - lr * gg.astype(p.dtype),
                               params, g)
            return new, loss

        rng = np.random.default_rng(0)
        # zipf tokens so the hot cache actually serves most lookups
        def batch():
            u = rng.random((args.batch, args.seq))
            a = 1.2
            x = (u * ((cfg.vocab_size + 1.) ** (1 - a) - 1.) + 1.) \
                ** (1 / (1 - a))
            return jnp.asarray(np.clip(x.astype(np.int64) - 1, 0,
                                       cfg.vocab_size - 1))

        losses = []
        for i in range(args.steps):
            params, loss = step(params, batch())
            losses.append(float(loss))
            if i % 10 == 0:
                print(f"step {i:3d}  loss={losses[-1]:.4f}")
        print(f"\nloss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(ln V = {np.log(cfg.vocab_size):.2f})")
        assert losses[-1] < losses[0], "no learning signal"
        print("OK")


if __name__ == "__main__":
    main()
