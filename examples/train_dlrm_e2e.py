"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred steps
with the full production substrate — fault-tolerant Trainer, async atomic
checkpoints, Zipf synthetic Criteo-like data, AUC eval, and an injected
mid-run failure to demonstrate checkpoint-restore + deterministic replay.

Run:  PYTHONPATH=src python examples/train_dlrm_e2e.py [--steps 300]
"""
import argparse
import dataclasses
import os
import shutil
import time

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.layers import auc
from repro.models.recsys.model import RecsysModel
from repro.train.trainer import Trainer


def build_cfg():
    """~100M parameters: 26 tables, capped vocabs, D=64."""
    base = RECSYS_ARCHS["dlrm-criteo"]
    tables = tuple(dataclasses.replace(
        t, vocab_size=min(t.vocab_size, 60_000), dim=64)
        for t in base.tables)
    cfg = dataclasses.replace(base, tables=tables, embedding_dim=64,
                              bottom_mlp=(256, 128, 64),
                              top_mlp=(512, 256, 1))
    n = cfg.total_embedding_params
    print(f"model: {cfg.num_tables} tables, {n / 1e6:.1f}M embedding params")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="artifacts/e2e_ckpt")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = build_cfg()
    mesh = make_test_mesh((1, 1))
    data = SyntheticCTR(cfg, args.batch)

    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=args.batch)
        tcfg = TrainConfig(learning_rate=5e-3)
        trainer = Trainer(model, tcfg, mesh, data.batch,
                          ckpt_dir=args.ckpt_dir, ckpt_interval=50)
        if args.inject_failure:
            armed = {"on": True}

            def inject(step):
                if step == args.steps // 2 and armed["on"]:
                    armed["on"] = False
                    print(f"*** injecting node failure at step {step} ***")
                    raise RuntimeError("injected failure")

            trainer.failure_injector = inject

        t0 = time.time()
        out = trainer.train(args.steps, log_every=25)
        dt = time.time() - t0

    hist = out["history"]
    print(f"\n{len(hist)} steps in {dt:.1f}s "
          f"({args.batch * len(hist) / dt:.0f} samples/s)")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"stragglers flagged: {out['stragglers']}")

    # -- eval AUC on held-out steps ----------------------------------------
    import jax.numpy as jnp
    params = out["params"]
    logits_all, labels_all = [], []
    fwd = jax.jit(model.apply)
    for s in range(10_000, 10_005):
        b = data.batch(s)
        logits_all.append(np.asarray(fwd(
            params, {k: jnp.asarray(v) for k, v in b.items()})))
        labels_all.append(b["label"])
    a = auc(np.concatenate(logits_all), np.concatenate(labels_all))
    print(f"held-out AUC: {a:.4f} (planted-signal synthetic data)")
    assert a > 0.6, "training failed to learn the planted signal"


if __name__ == "__main__":
    main()
