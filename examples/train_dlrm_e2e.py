"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred
steps through the graph API with the full production substrate —
fault-tolerant Trainer, async atomic checkpoints, Zipf synthetic
Criteo-like data, AUC eval, and an injected mid-run failure to
demonstrate checkpoint-restore + deterministic replay.

Run:  PYTHONPATH=src python examples/train_dlrm_e2e.py [--steps 300]
"""
import argparse
import shutil
import time

import numpy as np

from repro.api import (
    CreateSolver, DataReaderParams, DenseLayer, Input, Model,
    SparseEmbedding,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES
from repro.models.recsys.layers import auc


def build_model(batch: int, lr: float) -> Model:
    """~100M parameters: 26 tables, capped vocabs, D=64."""
    sizes = [min(v, 60_000) for v in CRITEO_VOCAB_SIZES]
    m = Model(CreateSolver(batch_size=batch, lr=lr, ckpt_interval=50),
              DataReaderParams(num_dense_features=13),
              name="dlrm-e2e")
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(
        vocab_sizes=sizes, dim=64, top_name="emb",
        table_names=[f"C{i + 1}" for i in range(len(sizes))]))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(256, 128, 64),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"],
                     units=(512, 256, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    cfg = m.to_recsys_config()
    print(f"model: {cfg.num_tables} tables, "
          f"{cfg.total_embedding_params / 1e6:.1f}M embedding params")
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="artifacts/e2e_ckpt")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    m = build_model(args.batch, lr=5e-3)
    m.compile()

    inject = None
    if args.inject_failure:
        armed = {"on": True}

        def inject(step):
            if step == args.steps // 2 and armed["on"]:
                armed["on"] = False
                print(f"*** injecting node failure at step {step} ***")
                raise RuntimeError("injected failure")

    t0 = time.time()
    hist = m.fit(steps=args.steps, ckpt_dir=args.ckpt_dir,
                 log_every=25, failure_injector=inject)
    dt = time.time() - t0

    print(f"\n{len(hist)} steps in {dt:.1f}s "
          f"({args.batch * len(hist) / dt:.0f} samples/s)")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"stragglers flagged: {m.stragglers}")

    # -- eval AUC on held-out steps ----------------------------------------
    from repro.data.synthetic import SyntheticCTR
    data = SyntheticCTR(m.cfg, args.batch)
    probs_all, labels_all = [], []
    for s in range(10_000, 10_005):
        b = data.batch(s)
        probs_all.append(m.predict(b))
        labels_all.append(b["label"])
    # AUC is rank-based, so probabilities work as well as logits
    a = auc(np.concatenate(probs_all), np.concatenate(labels_all))
    print(f"held-out AUC: {a:.4f} (planted-signal synthetic data)")
    assert a > 0.6, "training failed to learn the planted signal"


if __name__ == "__main__":
    main()
