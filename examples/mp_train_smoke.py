"""Model-parallel training smoke: fit on a (2,2) mesh, serve the result.

Forces 4 host devices (XLA_FLAGS must be set before jax initializes),
then drives the full MP path end to end:

  1. train a tiny DLRM with ``Solver(mesh_shape=(2, 2))`` — embeddings
     shard over the mesh per the placement planner, the dense net runs
     data-parallel, and the loss trajectory must match a single-device
     run of the same graph;
  2. deploy the mesh-trained model to a ps.json bundle;
  3. rebuild the server FROM THE BUNDLE ALONE and serve one prediction
     batch, cross-checked against the training-graph forward pass.

Run:  PYTHONPATH=src python examples/mp_train_smoke.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import tempfile

import numpy as np

from repro.api import (
    CreateSolver, DataReaderParams, DenseLayer, Input, Model,
    SparseEmbedding,
)
from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import build_server_from_config


def build(mesh_shape):
    solver = CreateSolver(batch_size=64, lr=1e-2, mesh_shape=mesh_shape)
    reader = DataReaderParams(source="synthetic", num_dense_features=13)
    m = Model(solver, reader, name="mp-smoke-dlrm")
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(vocab_sizes=[1000, 584, 1000, 306, 24, 634],
                          dim=16, top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(32, 16),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(32, 16, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    m.compile()
    return m


def main():
    import jax
    n_dev = len(jax.devices())
    if n_dev < 4:
        raise SystemExit(f"need 4 forced host devices, got {n_dev}; "
                         "set XLA_FLAGS before python starts")

    # -- 1. MP fit, checked against the single-device trajectory ------------
    mp = build((2, 2))
    print(f"mesh: {dict(mp.mesh.shape)} over {n_dev} devices")
    hist_mp = mp.fit(steps=10, log_every=5)
    ref = build((1, 1))
    hist_1d = ref.fit(steps=10)
    dev = max(abs(a["loss"] - b["loss"])
              for a, b in zip(hist_mp, hist_1d))
    if dev > 1e-5:
        raise SystemExit(f"MP loss trajectory deviates {dev} from the "
                         "single-device run")
    print(f"loss {hist_mp[0]['loss']:.4f} -> {hist_mp[-1]['loss']:.4f} "
          f"(matches 1-device run, max dev {dev:.2e})")

    # -- 2./3. deploy the mesh-trained model, serve from the bundle ---------
    with tempfile.TemporaryDirectory() as root:
        mp.deploy(root, cache_capacity=512)
        server, loaded = build_server_from_config(
            os.path.join(root, "ps.json"))
        data = SyntheticCTR(loaded.cfg, 64)
        req = data.batch(999)
        with loaded.mesh:
            preds = server.predict(req["dense"], req["cat"])
        want = mp.predict(req)
        if preds.shape != (64,):
            raise SystemExit(f"expected 64 predictions, got {preds.shape}")
        err = float(np.abs(preds - want).max())
        if err > 1e-6:
            raise SystemExit(f"bundle-served predictions deviate {err} "
                             "from the training-graph forward pass")
        print(f"served {preds.shape[0]} predictions from the rebuilt "
              f"bundle (max dev vs training graph {err:.2e})")
    print("mp-train-smoke OK")


if __name__ == "__main__":
    main()
