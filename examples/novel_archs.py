"""Novel architectures through the generic dense-graph compiler.

The graph API no longer pattern-matches a menu of recipes: any valid
layer DAG compiles into a ``DenseGraphProgram`` and runs through the
same training, deployment and serving stack as the paper models. This
example drives TWO architectures that exist nowhere in the codebase as
model-specific code:

  * a two-tower residual model (``configs/twotower_criteo.py``) —
    multiply / reduce_sum dot-product logit + residual MLP head,
  * a DCN-v2-style parallel cross+deep hybrid
    (``configs/crossdeep_criteo.py``) — per-branch logit heads plus a
    sliced low-order linear branch,

each: declared -> compiled -> trained -> JSON round-tripped -> deployed
to a relocatable bundle -> served from the REBUILT server (bit-exact
with the in-process deploy) -> exported and replayed in pure numpy.

Run:  PYTHONPATH=src python examples/novel_archs.py
"""
import os
import tempfile

import numpy as np

from repro.api import Model, Solver
from repro.configs import crossdeep_criteo, twotower_criteo
from repro.data.synthetic import SyntheticCTR
from repro.export import export_recsys, load_exported, run_exported
from repro.launch.serve import build_server_from_config


def drive(build_model, steps: int = 15, batch: int = 64) -> None:
    m = build_model(smoke=True, solver=Solver(batch_size=batch, lr=1e-2))
    cfg = m.to_recsys_config()
    print(f"\n=== {m.name}: lowers to model={cfg.model!r} "
          f"({len(cfg.dense_graph) - 1} compiled layers) ===")
    m.compile()
    m.summary()
    data = SyntheticCTR(m.cfg, batch)
    hist = m.fit(data.batch, steps=steps)
    print(f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    req = data.batch(990)
    want = m.predict(req)

    with tempfile.TemporaryDirectory() as root:
        # JSON round-trip reproduces the exact same lowered config
        gpath = os.path.join(root, "graph.json")
        m.graph_to_json(gpath)
        assert Model.from_json(gpath).to_recsys_config() == cfg

        # deploy -> rebuild from the bundle alone -> bit-exact serving
        dep = os.path.join(root, "dep")
        server = m.deploy(dep, cache_capacity=512)
        got = server.predict(req["dense"], req["cat"])
        rebuilt, _ = build_server_from_config(
            os.path.join(dep, "ps.json"))
        got2 = rebuilt.predict(req["dense"], req["cat"])
        np.testing.assert_array_equal(got2, got)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
        print(f"served {len(got2)} predictions from the rebuilt bundle "
              "(bit-exact with in-process deploy)")

        # portable export replays under pure numpy
        with m.mesh:
            exp = export_recsys(m.model, dict(m.params),
                                os.path.join(root, "exp"), m.name)
        graph, weights = load_exported(exp)
        np_preds = run_exported(graph, weights, req)
        np.testing.assert_allclose(np_preds, want, rtol=2e-2, atol=2e-2)
        print(f"numpy executor parity over {len(graph['nodes'])} "
              "portable nodes")


def main():
    drive(twotower_criteo.build_model)
    drive(crossdeep_criteo.build_model)
    print("\nboth novel graphs trained, round-tripped, deployed, "
          "served and exported with zero per-arch lowering code")


if __name__ == "__main__":
    main()
