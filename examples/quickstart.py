"""Quickstart: the paper's workflow in ~60 lines.

  1. build a DLRM with the HugeCTR-style embedding engine (planner picks
     localized / distributed / hybrid placement per table),
  2. train a few steps on synthetic Zipf CTR data,
  3. deploy to the Hierarchical Parameter Server and serve predictions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training
from repro.train.train_step import build_train_step, init_opt_state


def main():
    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS["dlrm-criteo"])
    mesh = make_test_mesh((1, 1))          # CPU demo; prod = (16, 16)
    batch_size = 256

    with mesh:
        # -- 1. model + embedding placement ---------------------------------
        model = RecsysModel(cfg, mesh, global_batch=batch_size)
        for name, group in model.embedding.groups.items():
            print(f"embedding group {name!r}: {group.num_tables} tables, "
                  f"{group.total_rows} rows ({group.strategy})")
        params = model.init(jax.random.PRNGKey(0))

        # -- 2. train --------------------------------------------------------
        tcfg = TrainConfig(learning_rate=1e-2)
        step = jax.jit(build_train_step(model, tcfg))
        opt_state = init_opt_state(params, tcfg)
        data = SyntheticCTR(cfg, batch_size)
        for i in range(20):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, aux = step(params, opt_state, batch)
            if i % 5 == 0:
                print(f"step {i:3d}  loss={float(aux['loss']):.4f}")

        # -- 3. deploy + serve ------------------------------------------------
        with tempfile.TemporaryDirectory() as root:
            pdb = PersistentDB(root)
            deploy_from_training(model, params, pdb, "quickstart")
            hps = HPS("quickstart", cfg.tables, pdb, cache_capacity=512)
            dense = {k: v for k, v in params.items() if k != "embedding"}
            server = InferenceServer(model, dense, hps)
            warm = data.batch(998)
            server.predict(warm["dense"], warm["cat"])   # jit + cache warmup
            server.latencies_ms.clear()
            req = data.batch(999)
            preds = server.predict(req["dense"], req["cat"])
            print(f"served {len(preds)} predictions; "
                  f"p50 latency = {server.latency_percentiles()['p50']:.2f} ms; "
                  f"L1 hit rate = "
                  f"{np.mean(list(hps.stats()['l1_hit_rate'].values())):.2f}")


if __name__ == "__main__":
    main()
