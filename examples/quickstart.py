"""Quickstart: the paper's workflow through the graph API, in ~60 lines.

  1. declare a DLRM as a HugeCTR-style layer graph (Solver + Input +
     SparseEmbedding + DenseLayers wired by tensor names),
  2. compile (the graph lowers onto the embedding planner + trainer)
     and train a few steps on synthetic Zipf CTR data,
  3. deploy: write the ps.json serving bundle, then reconstruct the
     HPS-backed server FROM THE BUNDLE ALONE and serve predictions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.api import (
    CreateSolver, DataReaderParams, DenseLayer, Input, Model,
    SparseEmbedding,
)
from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import build_server_from_config


def main():
    # -- 1. declare the model graph -----------------------------------------
    solver = CreateSolver(batch_size=256, lr=1e-2)
    reader = DataReaderParams(source="synthetic", num_dense_features=13)
    m = Model(solver, reader, name="quickstart-dlrm")
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(vocab_sizes=[1000, 584, 1000, 306, 24, 634],
                          dim=16, top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(32, 16),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(32, 16, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))

    # -- 2. compile (lowering) + train ---------------------------------------
    m.compile()
    m.summary()
    for name, group in m.model.embedding.groups.items():
        print(f"embedding group {name!r}: {group.num_tables} tables, "
              f"{group.total_rows} rows ({group.strategy})")
    hist = m.fit(steps=20, log_every=5)
    print(f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")

    # -- 3. deploy: bundle -> config-driven server ---------------------------
    with tempfile.TemporaryDirectory() as root:
        m.deploy(root, cache_capacity=512)   # pdb/ graph.json dense.npz ps.json
        server, loaded = build_server_from_config(
            os.path.join(root, "ps.json"))
        data = SyntheticCTR(loaded.cfg, 256)
        warm = data.batch(998)
        server.predict(warm["dense"], warm["cat"])  # jit + cache warmup
        server.reset_latencies()
        req = data.batch(999)
        preds = server.predict(req["dense"], req["cat"])
        want = m.predict(req)
        np.testing.assert_allclose(preds, want, rtol=2e-2, atol=2e-2)
        hit = np.mean(list(server.hps.stats()["l1_hit_rate"].values()))
        print(f"served {len(preds)} predictions from the ps.json bundle; "
              f"p50 latency = "
              f"{server.latency_percentiles()['p50']:.2f} ms; "
              f"L1 hit rate = {hit:.2f}")
        print("config-driven server matches the training forward pass")


if __name__ == "__main__":
    main()
