"""ETC-staged training — the Embedding Training Cache as a first-class
training backend behind the graph API (HugeCTR's ``wdl_etc`` low-level
workflow).

A run is split into ``ETCParams.passes`` keyset-staged passes. For each
pass the trainer (1) extracts the pass's keyset by replaying the
stateless reader and presents it to the cache up front (hottest ids win
when the keyset exceeds capacity), (2) trains with the jitted
dense+sparse step over the cache arrays — the device never holds more
than ``cache_rows`` embedding rows per table — and (3) at the pass
boundary flushes the cache through the parameter server (the durability
point; ``ps="cached"`` fsyncs) and, when a publisher is attached, ships
the pass's rows as ONE versioned online update to the live serving side.

Initial weights mirror ``Trainer.init_state`` (same PRNG seed, same
split), so an ETC run whose cache covers every vocab matches the
in-memory ``fit()`` oracle to float tolerance — the parity contract
``tests/test_etc_parity.py`` pins.

Concurrency: the trainer (and its ETC/PS) is confined to the training
thread. The only shared object is the :class:`UpdatePublisher`, which
carries its own lock contract — the live serving stack sees updates by
value over the message bus, never these arrays.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ETCParams
from repro.core.etc.cache import EmbeddingTrainingCache, cached_lookup
from repro.core.etc.parameter_server import CachedPS, StagedPS
from repro.models.recsys import layers
from repro.models.recsys.dense_graph import GraphError
from repro.models.recsys.model import import_logical_tables, logical_tables
from repro.optim.optimizers import clip_by_global_norm
from repro.train.train_step import build_optimizers, split_params

_CHUNK = 1 << 16       # rows per PS pull/push when moving whole tables


class OnlineTrainer:

    def __init__(self, model, etc_cfg: ETCParams, *, ps=None,
                 publisher=None, seed: Optional[int] = None):
        if model._model is None:
            model.compile()
        rmodel = model._model
        if rmodel.wide is not None or rmodel.extra:
            raise GraphError(
                "ETC-staged training supports single-collection models "
                "only (no wide branch, no extra embedding groups yet) — "
                "drop Solver.etc or simplify the graph")
        self.model = model
        self.cfg = etc_cfg
        self.tcfg = model._tcfg
        self.tables = model.cfg.tables
        self.publisher = publisher
        self.seed = model.solver.seed if seed is None else seed
        self.ps = ps if ps is not None else self._build_ps()
        self.etc = EmbeddingTrainingCache(self.tables, etc_cfg.cache_rows,
                                          self.ps)
        # start from the weights the in-memory path would use: params
        # already held (load()/previous fit()), else a fresh init with
        # the run seed — the parity contract depends on this
        if model._params is None:
            with model.mesh:
                model._params = rmodel.init(jax.random.PRNGKey(self.seed))
        sparse_p, dense_p = split_params(model._params)
        self._emb_template = sparse_p["embedding"]
        self._dense = dense_p
        self._seed_ps(rmodel.embedding, self._emb_template)
        self._step_fn, self._dense_opt = self._build_step()
        self._dstate = self._dense_opt.init(dense_p)
        self._cache_params = self.etc.init_params()
        self.pass_log: List[Dict] = []

    def _build_ps(self):
        if self.cfg.ps == "cached":
            return CachedPS(self.tables, self.cfg.ps_root, seed=self.seed)
        return StagedPS(self.tables, seed=self.seed,
                        shards=self.cfg.ps_shards)

    def _seed_ps(self, collection, emb_params) -> None:
        """Write the model's initial (or loaded) embedding weights into
        the PS, zeroing the optimizer accumulator — incremental passes
        then continue FROM the deployed model, not from a fresh init."""
        full = logical_tables(collection, emb_params)
        for t in self.tables:
            rows = np.asarray(full[t.name], np.float32)
            for lo in range(0, rows.shape[0], _CHUNK):
                hi = min(rows.shape[0], lo + _CHUNK)
                ids = np.arange(lo, hi, dtype=np.int64)
                self.ps.push(t.name, ids, rows[lo:hi])
                self.ps.push_state(t.name, ids,
                                   np.zeros(hi - lo, np.float32))

    # -- the jitted device step ------------------------------------------------

    def _build_step(self):
        rmodel = self.model._model
        tcfg = self.tcfg
        dense_opt, sparse_opt = build_optimizers(tcfg)

        @jax.jit
        def step(dense_p, dstate, cache_p, dense_x, label, remapped):
            def loss_fn(dp, cp):
                emb = cached_lookup(cp, remapped)
                logits = rmodel.apply_dense(dp, dense_x, emb)
                return layers.bce_with_logits(logits, label)
            loss, (gd, gc) = jax.value_and_grad(
                loss_fn, argnums=(0, 1))(dense_p, cache_p)
            # same update math as train_step._apply_updates: global-norm
            # clip over the DENSE grads only, rowwise adagrad on the
            # embedding rows (here: the [T*C, D]-reshaped cache)
            gd, _ = clip_by_global_norm(gd, tcfg.grad_clip)
            new_dense, new_dstate = dense_opt.update(gd, dstate, dense_p)
            t, c, d = cache_p["cache"].shape
            flat, sstate = sparse_opt.update(
                {"x": gc["cache"].reshape(t * c, d)},
                {"acc": {"x": cache_p["acc"].reshape(t * c)}},
                {"x": cache_p["cache"].reshape(t * c, d)})
            new_cache = {"cache": flat["x"].reshape(t, c, d),
                         "acc": sstate["acc"]["x"].reshape(t, c)}
            return new_dense, new_dstate, new_cache, loss

        return step, dense_opt

    # -- keyset-staged passes ---------------------------------------------------

    def _stage_keyset(self, data_fn: Callable[[int], Dict],
                      step_range) -> None:
        """Present the pass's keyset to the cache before training on it
        (HugeCTR presents each pass's keyset file the same way). The
        stateless reader is replayed to collect ids; when a table's
        keyset exceeds capacity the hottest ids win and mid-pass staging
        handles the tail."""
        per_table: List[List[np.ndarray]] = [[] for _ in self.tables]
        for s in step_range:
            cat = np.asarray(data_fn(s)["cat"])
            for ti in range(len(self.tables)):
                ids = cat[:, ti, :].ravel()
                per_table[ti].append(ids[ids >= 0])
        staged = []
        for ti in range(len(self.tables)):
            ids = np.concatenate(per_table[ti]) if per_table[ti] \
                else np.empty(0, np.int64)
            uniq, counts = np.unique(ids, return_counts=True)
            cap = min(self.etc.capacity, self.tables[ti].vocab_size)
            if uniq.size > cap:
                uniq = uniq[np.argsort(counts)[::-1][:cap]]
            staged.append(np.sort(uniq).astype(np.int64))
        width = max((s.size for s in staged), default=0)
        if width == 0:
            return
        cat = np.full((1, len(self.tables), width), -1, np.int64)
        for ti, s in enumerate(staged):
            cat[0, ti, :s.size] = s
        self._cache_params, _ = self.etc.prepare(self._cache_params, cat)

    def end_pass(self) -> Optional[int]:
        """Pass boundary: flush the cache through the PS (durability
        point) and publish the pass's FULL touched keyset as one
        versioned update — pulled from the PS after the flush, so rows
        evicted mid-pass carry their trained values too (the resident
        set alone under-reports the pass)."""
        self.etc.flush(self._cache_params)
        if hasattr(self.ps, "flush"):
            self.ps.flush()
        if self.publisher is None:
            return None
        updates = {}
        for ti, t in enumerate(self.etc.tables):
            ids = self.etc.drain_touched(ti)
            if ids.size:
                updates[t.name] = (ids, self.ps.pull(t.name, ids))
        return self.publisher.publish(updates)

    # -- train ------------------------------------------------------------------

    def fit(self, data_fn: Callable[[int], Dict], steps: int, *,
            log_every: int = 0) -> List[Dict]:
        bounds = np.linspace(0, steps, self.cfg.passes + 1).astype(int)
        history: List[Dict] = []
        for p in range(self.cfg.passes):
            lo, hi = int(bounds[p]), int(bounds[p + 1])
            if hi <= lo:
                continue
            self._stage_keyset(data_fn, range(lo, hi))
            for s in range(lo, hi):
                batch = data_fn(s)
                self._cache_params, remapped = self.etc.prepare(
                    self._cache_params, np.asarray(batch["cat"]))
                (self._dense, self._dstate, self._cache_params,
                 loss) = self._step_fn(
                    self._dense, self._dstate, self._cache_params,
                    jnp.asarray(batch["dense"]),
                    jnp.asarray(batch["label"]),
                    jnp.asarray(remapped))
                history.append({"step": s, "loss": float(loss),
                                "time": time.time()})
                if log_every and (s + 1) % log_every == 0:
                    print(f"[etc pass {p + 1}/{self.cfg.passes}] step "
                          f"{s + 1}/{steps} loss {float(loss):.4f}")
            version = self.end_pass()
            self.pass_log.append({"pass": p, "steps": (lo, hi),
                                  "version": version})
        return history

    # -- export back into the graph-API world ------------------------------------

    def export_params(self) -> Dict:
        """Full param tree (dense + embedding) with the trained PS
        contents imported back into the collection layout — the result
        feeds ``predict()``/``save()``/``deploy()`` with no knowledge of
        the ETC. Call after ``fit()`` (which ends on a flush)."""
        tables = {}
        for t in self.tables:
            rows = np.empty((t.vocab_size, t.dim), np.float32)
            for lo in range(0, t.vocab_size, _CHUNK):
                hi = min(t.vocab_size, lo + _CHUNK)
                rows[lo:hi] = self.ps.pull(
                    t.name, np.arange(lo, hi, dtype=np.int64))
            tables[t.name] = rows
        with self.model.mesh:
            emb = import_logical_tables(self.model._model.embedding,
                                        self._emb_template, tables)
        params = dict(self._dense)
        params["embedding"] = emb
        return params
