"""Versioned online-update publisher — the training half of the
train->serve freshness loop.

Each :meth:`UpdatePublisher.publish` call is one atomic freshness unit:
every table's rows go out on the existing ``hps.<model>.<table>`` topics
stamped with the same monotonically increasing version. The serving side
certifies application through ``Consumer.last_versions[table] >= v``
(bus drained into L2/L3, touched L1 rows queued for refresh), and
:func:`repro.online.freshness.wait_visible` closes the loop by probing
live predictions.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hps.message_bus import MessageBus, _serialize


class UpdatePublisher:
    """Publishes ``{table: (ids, rows)}`` update sets with one version
    per set.

    Thread safety: ``publish()`` runs on the training thread while
    freshness probes on other threads read :meth:`last_version` /
    :meth:`publish_time`. The version counter and the publish log are
    guarded by ``_lock``; ALL bus IO happens outside it — a reader must
    never wait behind a bus publish (LOCK002).
    """

    # Checked by `python -m repro.analysis`.
    _GUARDED_BY = {"_version": "_lock", "_log": "_lock"}

    def __init__(self, bus: MessageBus, model: str, *,
                 max_batch_rows: int = 4096):
        self.bus = bus
        self.model = model
        self.max_batch_rows = max_batch_rows
        self._lock = threading.Lock()
        self._version = 0
        self._log: List[Dict] = []

    def publish(self, updates: Dict[str, Tuple[np.ndarray, np.ndarray]]
                ) -> int:
        """Publish one versioned update set; returns its version."""
        with self._lock:
            self._version += 1
            version = self._version
        total = 0
        tables: List[str] = []
        for table in sorted(updates):
            ids, rows = updates[table]
            ids = np.asarray(ids, np.int64)
            rows = np.asarray(rows, np.float32)
            if ids.size == 0:
                continue
            topic = self.bus.topic(self.model, table)
            for lo in range(0, ids.size, self.max_batch_rows):
                hi = min(ids.size, lo + self.max_batch_rows)
                self.bus.publish(
                    topic, _serialize(ids[lo:hi], rows[lo:hi], version))
            total += int(ids.size)
            tables.append(table)
        rec = {"version": version, "tables": tables, "rows": total,
               "published_at": time.monotonic()}
        with self._lock:
            self._log.append(rec)
        return version

    def publish_cache(self, etc, params) -> int:
        """Publish every row resident in an EmbeddingTrainingCache — the
        pass-boundary feed (resident == touched this pass + survivors)."""
        updates = {t.name: etc.dirty_rows(params, ti)
                   for ti, t in enumerate(etc.tables)}
        return self.publish(updates)

    # -- read side (freshness probes) ----------------------------------------

    def last_version(self) -> int:
        with self._lock:
            return self._version

    def publish_time(self, version: int) -> Optional[float]:
        """``time.monotonic()`` at which ``version`` finished publishing
        (None if that version never completed)."""
        with self._lock:
            for rec in reversed(self._log):
                if rec["version"] == version:
                    return rec["published_at"]
        return None

    def history(self) -> List[Dict]:
        with self._lock:
            return [dict(r) for r in self._log]
