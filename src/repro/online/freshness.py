"""Freshness probes: publish -> visible-in-prediction lag on a LIVE
server.

The freshness contract has two halves. The storage half: every table's
``Consumer.last_versions`` reaching ``v`` means update ``v`` is applied
to the server's L2/L3 and its L1 rows are queued for refresh. The
serving half: a probe prediction actually changing means the refreshed
rows reached the L1 payload a query reads. :func:`wait_visible` requires
BOTH, and the measured lag (from the publisher's timestamp) is the
paper's update-freshness metric.

Probes go through ``server.submit`` — the real admission/batching path —
so every poll also drives the serving loop's ``_refresh_tick``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import numpy as np


def probe_prediction(server, dense: np.ndarray, cat: np.ndarray, *,
                     timeout_s: float = 10.0) -> np.ndarray:
    """One probe through the live serving queue."""
    out = server.submit(dense, cat).get(timeout=timeout_s)
    if isinstance(out, Exception):
        raise out
    return np.asarray(out)


def wait_visible(server, publisher, version: int, dense: np.ndarray,
                 cat: np.ndarray, *,
                 baseline: Optional[np.ndarray] = None,
                 tables: Optional[Sequence[str]] = None,
                 timeout_s: float = 30.0,
                 poll_interval_s: float = 0.005) -> Dict:
    """Block until update ``version`` is visible in live predictions.

    Visibility requires the consumer versions of ``tables`` (default:
    whatever tables have consumed updates) to reach ``version`` AND,
    when a ``baseline`` prediction is given, a probe prediction that
    differs from it. Returns ``{"lag_s", "polls", "prediction"}`` with
    the lag measured from ``publisher.publish_time(version)``.
    """
    t0 = publisher.publish_time(version)
    start = time.monotonic()
    deadline = start + timeout_s
    polls = 0
    while True:
        polls += 1
        pred = probe_prediction(server, dense, cat, timeout_s=timeout_s)
        versions = server.update_versions()
        need = list(tables) if tables is not None else list(versions)
        applied = bool(versions) and \
            all(versions.get(t, -1) >= version for t in need)
        changed = baseline is None or not np.allclose(pred, baseline)
        if applied and changed:
            return {"lag_s": time.monotonic() -
                    (t0 if t0 is not None else start),
                    "polls": polls, "prediction": pred}
        if time.monotonic() >= deadline:
            raise TimeoutError(
                f"update v{version} not visible after {timeout_s:.0f}s "
                f"(versions={versions}, prediction_changed={changed})")
        time.sleep(poll_interval_s)
