"""Online training: ETC-staged passes + the live train->serve freshness
loop (paper §1 "Online training" / §3 "Online model updating").

The pieces:

* :class:`~repro.online.trainer.OnlineTrainer` — the Embedding Training
  Cache as a first-class training backend: keyset-staged passes, the
  parameter server as the durable tier, dense+sparse optimizers running
  on the cache arrays.
* :class:`~repro.online.publisher.UpdatePublisher` — turns each pass's
  flushed dirty rows into versioned updates on the existing MessageBus
  topics, consumed by a LIVE ``InferenceServer``.
* :mod:`~repro.online.freshness` — probes measuring the publish ->
  visible-in-prediction lag against the live server.
"""
from repro.online.publisher import UpdatePublisher
from repro.online.trainer import OnlineTrainer
from repro.online.freshness import probe_prediction, wait_visible

__all__ = ["UpdatePublisher", "OnlineTrainer", "probe_prediction",
           "wait_visible"]
