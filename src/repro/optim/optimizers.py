"""Dense optimizers (SGD / Adam / AdamW) — minimal, pytree-based, pjit-safe.

API: ``opt = make(name, TrainConfig)``; ``state = opt.init(params)``;
``params, state = opt.update(grads, state, params, lr_scale)``.
All math is elementwise/rowwise so parameter shardings are preserved.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def make(name: str, cfg: TrainConfig) -> Optimizer:
    if name == "sgd":
        return _sgd(cfg)
    if name == "adam":
        return _adam(cfg, weight_decay=0.0)
    if name == "adamw":
        return _adam(cfg, weight_decay=cfg.weight_decay)
    raise ValueError(name)


def _sgd(cfg: TrainConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr_scale=1.0):
        lr = cfg.learning_rate * lr_scale
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
        return new, {"step": state["step"] + 1}

    return Optimizer(init, update)


def _adam(cfg: TrainConfig, weight_decay: float) -> Optimizer:
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, lr_scale=1.0):
        step = state["step"] + 1
        lr = cfg.learning_rate * lr_scale
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            upd_ = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            if weight_decay:
                upd_ = upd_ + weight_decay * p.astype(jnp.float32)
            return (p - lr * upd_).astype(p.dtype), mu, nu

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        flat_nu = tdef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, n)
               for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        new_nu = tdef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "mu": new_mu, "nu": new_nu}

    return Optimizer(init, update)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
