"""Sparse (embedding) optimizers — row-wise AdaGrad, HugeCTR's default.

State is one accumulator scalar per *row* (V floats for a [V, D] table),
so optimizer memory for TB-scale tables stays ~D× smaller than Adam.
All ops are row-wise: a table sharded over mesh axes keeps its sharding.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim.optimizers import Optimizer


def rowwise_adagrad(cfg: TrainConfig, initial_accumulator: float = 0.0
                    ) -> Optimizer:
    eps = 1e-10

    def init(params):
        def acc(p):
            if p.ndim == 2:
                return jnp.full((p.shape[0],), initial_accumulator,
                                jnp.float32)
            return jnp.zeros(p.shape[:1], jnp.float32)
        return {"acc": jax.tree.map(acc, params)}

    def update(grads, state, params, lr_scale=1.0):
        lr = cfg.learning_rate * lr_scale

        def upd(p, g, a):
            g = g.astype(jnp.float32)
            a = a + jnp.mean(g * g, axis=tuple(range(1, g.ndim)))
            scale = lr / (jnp.sqrt(a) + eps)
            new_p = p.astype(jnp.float32) - scale[:, None] * g \
                if g.ndim == 2 else p - scale * g
            return new_p.astype(p.dtype), a

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_a = tdef.flatten_up_to(state["acc"])
        out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, flat_a)]
        return (tdef.unflatten([o[0] for o in out]),
                {"acc": tdef.unflatten([o[1] for o in out])})

    return Optimizer(init, update)


def make_sparse(name: str, cfg: TrainConfig) -> Optimizer:
    if name == "rowwise_adagrad":
        return rowwise_adagrad(cfg)
    if name == "sgd":
        from repro.optim.optimizers import make
        return make("sgd", cfg)
    raise ValueError(name)
