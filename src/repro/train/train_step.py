"""Train-step builders.

Two distribution modes:

``gspmd``  — value_and_grad under jit with NamedShardings; XLA inserts the
             gradient all-reduce for replicated dense params and the
             embedding collectives come from the collection's shard_map.

``manual`` — the whole grad computation runs inside ONE shard_map over the
             full mesh: dense-gradient psum is explicit (so its dtype is a
             config knob — ``grad_allreduce_dtype="bf16"`` is the paper's
             "compressed parameter" idea applied to gradient traffic), and
             every embedding collective is the strategy's own.

Loss-scaling convention for manual mode (see the derivation in this file's
history / DESIGN.md §4): each device contributes ``local_mean / N_devices``;
MP-sharded embedding grads are then correct *without* any psum (the
collective transposes accumulate across devices), while replicated params
need one psum over ALL mesh axes.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

from repro.configs.base import TrainConfig
from repro.optim import optimizers as dense_opt_lib
from repro.optim.sparse import make_sparse
from repro.optim.optimizers import clip_by_global_norm

SPARSE_KEYS = ("embedding", "wide_embedding")


def is_sparse_key(k: str) -> bool:
    """True for param-tree keys owned by an embedding collection: the
    two legacy keys plus the N-group ``embedding@<group>`` keys."""
    return k in SPARSE_KEYS or k.startswith("embedding@")


def split_params(params: Dict) -> Tuple[Dict, Dict]:
    sparse = {k: v for k, v in params.items() if is_sparse_key(k)}
    dense = {k: v for k, v in params.items() if not is_sparse_key(k)}
    return sparse, dense


def build_optimizers(tcfg: TrainConfig):
    return (dense_opt_lib.make(tcfg.dense_optimizer, tcfg),
            make_sparse(tcfg.sparse_optimizer, tcfg))


def _apply_updates(params, grads, opt_state, dense_opt, sparse_opt, tcfg):
    sparse_p, dense_p = split_params(params)
    sparse_g = {k: grads[k] for k in sparse_p}
    dense_g = {k: grads[k] for k in dense_p}
    dense_g, gnorm = clip_by_global_norm(dense_g, tcfg.grad_clip)
    new_dense, dstate = dense_opt.update(dense_g, opt_state["dense"],
                                         dense_p)
    new_sparse, sstate = sparse_opt.update(sparse_g, opt_state["sparse"],
                                           sparse_p)
    new_params = {**new_dense, **new_sparse}
    return new_params, {"dense": dstate, "sparse": sstate}, gnorm


def init_opt_state(params: Dict, tcfg: TrainConfig) -> Dict:
    dense_opt, sparse_opt = build_optimizers(tcfg)
    sparse_p, dense_p = split_params(params)
    return {"dense": dense_opt.init(dense_p),
            "sparse": sparse_opt.init(sparse_p)}


# ---------------------------------------------------------------------------
# GSPMD mode
# ---------------------------------------------------------------------------

def build_train_step(model, tcfg: TrainConfig) -> Callable:
    dense_opt, sparse_opt = build_optimizers(tcfg)

    def loss_fn(params, batch):
        if tcfg.microbatches <= 1:
            return model.loss_fn(params, batch)
        # gradient accumulation happens in grad-land below
        return model.loss_fn(params, batch)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            loss, grads = _accumulated_grads(model, params, batch,
                                             tcfg.microbatches)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, gnorm = _apply_updates(
            params, grads, opt_state, dense_opt, sparse_opt, tcfg)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def _accumulated_grads(model, params, batch, k: int):
    b = batch["label"].shape[0]
    mb = b // k

    def one(i):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        micro = {kk: sl(v) for kk, v in batch.items()}
        return jax.value_and_grad(model.loss_fn)(params, micro)

    def body(carry, i):
        loss_acc, grad_acc = carry
        loss, grads = one(i)
        grad_acc = jax.tree.map(lambda a, g: a + g / k, grad_acc, grads)
        return (loss_acc + loss / k, grad_acc), ()

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g),
                                    jnp.arange(k))
    return loss, grads


def jit_train_step(model, tcfg: TrainConfig, mesh):
    """Fully-sharded jit: params/opt by their shardings, batch by DP."""
    from repro.data.pipeline import batch_shardings
    step = build_train_step(model, tcfg)
    p_sh = model.param_shardings()
    rep = NamedSharding(mesh, P())

    def opt_shardings(params_sh):
        sparse_sh, dense_sh = split_params(params_sh)
        acc_sh = {
            k: {kk: NamedSharding(
                mesh, P(*vv.spec[:1]))  # row-wise state follows rows
                for kk, vv in v.items()}
            for k, v in sparse_sh.items()}
        return {
            "dense": jax.tree.map(lambda _: rep, {"_": 0}) and {
                "step": rep,
                **({"mu": jax.tree.map(lambda s: s, dense_sh),
                    "nu": jax.tree.map(lambda s: s, dense_sh)}
                   if tcfg.dense_optimizer in ("adam", "adamw") else {}),
            },
            "sparse": {"acc": acc_sh},
        }

    o_sh = opt_shardings(p_sh)
    b_sh = batch_shardings(mesh)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Manual mode (explicit collectives; compressed gradient all-reduce)
# ---------------------------------------------------------------------------

def build_manual_train_step(model, tcfg: TrainConfig, mesh) -> Callable:
    dense_opt, sparse_opt = build_optimizers(tcfg)
    n_dev = int(np.prod(mesh.devices.shape))
    all_axes = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    ar_dtype = jnp.bfloat16 if tcfg.grad_allreduce_dtype == "bf16" \
        else jnp.float32

    emb_specs = {key: coll.param_specs()
                 for key, coll in model.collections().items()}

    def param_specs(params):
        specs = {}
        for k, v in params.items():
            if k in emb_specs:
                specs[k] = emb_specs[k]
            else:
                specs[k] = jax.tree.map(lambda _: P(), v)
        return specs

    def grad_shard_fn(params, batch):
        # per-device loss scaled so that summing over every device gives
        # the global-mean loss (see module docstring)
        def scaled_loss(p):
            return model.loss_fn(p, batch, manual=True) / n_dev

        loss, grads = jax.value_and_grad(scaled_loss)(params)
        # replicated params: explicit (optionally compressed) all-reduce;
        # MP-sharded embedding tables are already correct.
        def fix(path_key, g, spec):
            if spec == P() or all(s is None for s in spec):
                return jax.lax.psum(g.astype(ar_dtype),
                                    all_axes).astype(jnp.float32)
            return g

        specs = param_specs(params)
        grads = jax.tree.map(
            lambda g, s: fix(None, g, s), grads, specs,
            is_leaf=lambda x: isinstance(x, P))
        loss = jax.lax.psum(loss, all_axes)
        return loss, grads

    def train_step(params, opt_state, batch):
        specs = param_specs(params)
        from repro.data.pipeline import batch_shardings  # specs only
        b_spec = {"dense": P(dp_axes, None), "cat": P(dp_axes, None, None),
                  "label": P(dp_axes)}
        loss, grads = compat.shard_map(
            grad_shard_fn, mesh=mesh,
            in_specs=(specs, b_spec),
            out_specs=(P(), specs),
            check_vma=False,
        )(params, batch)
        new_params, new_state, gnorm = _apply_updates(
            params, grads, opt_state, dense_opt, sparse_opt, tcfg)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
