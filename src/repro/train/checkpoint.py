"""Sharded, atomic, async checkpointing with integrity checks.

Layout:  <dir>/step_<N>/
           manifest.json   — tree structure, shapes, dtypes, crc32s, meta
           arrays.npz      — flattened key-path -> ndarray

Writes go to ``<dir>/.tmp_step_<N>`` then ``os.rename`` (atomic on POSIX),
so a crash mid-save never corrupts the latest checkpoint. ``AsyncSaver``
snapshots device arrays synchronously (cheap) and does file IO on a
background thread — the HugeCTR-style overlap of IO with compute.

Arrays are stored *logically* (embedding mega-tables unpadded, de-striped)
so a checkpoint restores onto any mesh size — see ``trainer.Trainer`` for
the export/import hooks (elastic scaling).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


#: public name — the flat key-path form is also the dense-weights format
#: of the serving deployment bundle (api.Model.deploy / launch.serve)
flatten_tree = _flatten


def _treedef_template(tree):
    return jax.tree.map(lambda _: 0, tree)


def save(directory: str, step: int, tree: Any, *,
         meta: Optional[Dict] = None, keep_last: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = os.path.join(directory, f".tmp_step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {
        "step": step,
        "meta": meta or {},
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes())}
                   for k, v in flat.items()},
        "template": _template_json(tree),
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _cleanup(directory, keep_last)
    return final


def _template_json(tree):
    def conv(t):
        if isinstance(t, dict):
            return {k: conv(v) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            return [conv(v) for v in t]
        return None
    return conv(tree)


def _cleanup(directory: str, keep_last: int):
    steps = sorted(list_checkpoints(directory))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def list_checkpoints(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def load(directory: str, step: int, *, verify: bool = True
         ) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Returns (flat arrays by key-path, manifest)."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {k: data[k] for k in data.files}
    if verify:
        for k, info in manifest["arrays"].items():
            crc = zlib.crc32(np.ascontiguousarray(flat[k]).tobytes())
            if crc != info["crc32"]:
                raise IOError(f"checkpoint corruption in {k} @ step {step}")
    return flat, manifest


def unflatten_like(template, flat: Dict[str, np.ndarray]):
    """Rebuild a pytree with ``template``'s structure from flat key-paths."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree.structure(template)
    leaves = []
    for path, _ in leaves_with_path:
        key = "/".join(_path_str(p) for p in path)
        leaves.append(flat[key])
    return jax.tree.unflatten(treedef, leaves)


class AsyncSaver:
    """Snapshot-on-call, write-on-thread checkpointing."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None):
        self.wait()
        # snapshot NOW — np.array (not asarray!) so host-numpy leaves are
        # copied too: asarray aliases them and later in-place mutation
        # (donated buffers, optimizer updates) would corrupt the save
        host_tree = jax.tree.map(np.array, tree)

        def work():
            try:
                save(self.directory, step, host_tree, meta=meta,
                     keep_last=self.keep_last)
            except BaseException as e:
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
