"""Fault-tolerant training loop.

Production behaviours implemented (and unit-tested):
  * checkpoint/restart — async atomic checkpoints every ``ckpt_interval``;
    on (injected or real) step failure the trainer restores the newest
    valid checkpoint and *replays* — the data pipeline is stateless
    (``batch(step)``), so replay is deterministic.
  * straggler mitigation — per-step wall-time watchdog: steps slower than
    ``straggler_factor ×`` the running median are counted and surfaced in
    metrics (at pod scale this signal feeds the scheduler; here it is the
    bookkeeping + hook).
  * elastic scaling — checkpoints store logical (mesh-independent) arrays;
    ``Trainer.restore`` re-imports them for whatever mesh it runs on.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import put_batch
from repro.train import checkpoint as ckpt_lib
from repro.train.train_step import (
    build_manual_train_step, build_train_step, init_opt_state,
    jit_train_step,
)


class Trainer:

    def __init__(self, model, tcfg: TrainConfig, mesh, data_fn: Callable,
                 *, ckpt_dir: Optional[str] = None, ckpt_interval: int = 50,
                 mode: str = "gspmd", straggler_factor: float = 3.0):
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.data_fn = data_fn            # step -> host batch dict
        self.ckpt_dir = ckpt_dir
        self.ckpt_interval = ckpt_interval
        self.saver = ckpt_lib.AsyncSaver(ckpt_dir) if ckpt_dir else None
        self.straggler_factor = straggler_factor
        self.step_times: List[float] = []
        self.stragglers = 0
        n_dev = int(np.prod(mesh.devices.shape))
        #: model-parallel placement: on a real multi-device mesh the
        #: params live in their per-strategy shardings and the step is
        #: jitted with explicit in/out shardings, so the embedding
        #: collectives actually span devices (ROADMAP item: MP training
        #: through the graph API)
        self._shardings = model.param_shardings() \
            if n_dev > 1 and hasattr(model, "param_shardings") else None
        if mode == "manual":
            step_fn = build_manual_train_step(model, tcfg, mesh)
            self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        elif self._shardings is not None:
            self._step = jit_train_step(model, tcfg, mesh)
        else:
            step_fn = build_train_step(model, tcfg)
            self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        #: test hook: callable(step) that may raise to simulate a failure
        self.failure_injector: Optional[Callable[[int], None]] = None

    # -- state ----------------------------------------------------------------

    def _place(self, params):
        """Move params into their MP shardings (no-op on one device)."""
        if self._shardings is None:
            return params
        return jax.device_put(params, self._shardings)

    def init_state(self, seed: int = 0):
        params = self._place(self.model.init(jax.random.PRNGKey(seed)))
        opt_state = init_opt_state(params, self.tcfg)
        return params, opt_state

    def _export(self, params):
        from repro.models.recsys.model import export_logical_params
        return export_logical_params(self.model, params)

    def _import(self, params):
        from repro.models.recsys.model import import_logical_params
        return import_logical_params(self.model, params)

    def save(self, step: int, params, opt_state):
        if self.saver is None:
            return
        tree = {"params": self._export(params), "opt": opt_state}
        self.saver.save(step, tree, meta={"step": step})

    def restore(self, params_template, opt_template):
        """Load newest checkpoint; returns (step, params, opt_state) or None.

        Templates may be real arrays OR ShapeDtypeStructs — only the tree
        structure is used (safe even after buffer donation).
        """
        if self.ckpt_dir is None:
            return None
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return None
        flat, manifest = ckpt_lib.load(self.ckpt_dir, step)
        template = {
            "params": jax.eval_shape(self._export, params_template),
            "opt": opt_template,
        }
        tree = ckpt_lib.unflatten_like(template, flat)
        params = self._place(self._import(tree["params"]))
        return step, params, tree["opt"]

    # -- loop -----------------------------------------------------------------

    def train(self, num_steps: int, *, seed: int = 0,
              log_every: int = 0, initial_state=None) -> Dict:
        """``initial_state=(params, opt_state)`` seeds the loop with
        already-loaded weights (``opt_state=None`` re-inits the
        optimizer) — the ``Model.load`` resume path. A newer checkpoint
        in ``ckpt_dir`` still takes precedence."""
        if initial_state is not None:
            params, opt_state = initial_state
            params = self._place(params)
            if opt_state is None:
                opt_state = init_opt_state(params, self.tcfg)
        else:
            params, opt_state = self.init_state(seed)
        start = 0
        restored = self.restore(params, opt_state)
        if restored is not None:
            start, params, opt_state = restored
            start += 1
        history = []
        step = start
        while step < num_steps:
            try:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                t0 = time.perf_counter()
                batch = put_batch(self.data_fn(step), self.mesh)
                params, opt_state, metrics = self._step(params, opt_state,
                                                        batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self._watch_stragglers(dt)
                history.append({"step": step, "loss": loss, "time": dt})
                if log_every and step % log_every == 0:
                    print(f"step {step}: loss={loss:.4f} ({dt*1e3:.1f} ms)")
                if self.saver and step % self.ckpt_interval == 0:
                    self.save(step, params, opt_state)
                step += 1
            except (ckpt_lib.os.error, RuntimeError, ValueError) as e:
                # node failure path: restore + replay
                restored = self.restore(params, opt_state)
                if restored is None:
                    params, opt_state = self.init_state(seed)
                    step = 0
                else:
                    rstep, params, opt_state = restored
                    step = rstep + 1
        if self.saver:
            self.save(num_steps - 1, params, opt_state)
            self.saver.wait()
        return {"params": params, "opt_state": opt_state,
                "history": history, "stragglers": self.stragglers}

    def _watch_stragglers(self, dt: float):
        if len(self.step_times) >= 5:
            med = float(np.median(self.step_times[-50:]))
            if dt > self.straggler_factor * med:
                self.stragglers += 1
        self.step_times.append(dt)
