"""Load-test launcher: seeded open-loop traffic against a deployment
bundle, with latency SLOs and admission-controlled serving.

Stands a bundle back up exactly like ``launch.serve`` (ps.json is all it
needs), arms each member's admission controller (bounded queue +
declared SLO + deadline-aware batching), then drives a seeded open-loop
workload (Poisson or constant-rate arrivals, Zipf popularity with
optional hot-set drift, multi-model mix) through the
:class:`~repro.loadgen.driver.OpenLoopDriver` — submission happens at
the SCHEDULED offsets whether or not the server keeps up, so overload
shows up as tail latency and sheds instead of silently slowing the
benchmark (no coordinated omission).

Two phases run by default: a ``steady`` phase at ``--qps`` and, when
``--overload-qps`` is set, an ``overload`` phase pushing the offered
rate past capacity so the admission controller's shedding is visible.
The per-phase, per-model picture — client-observed p50/p99/p999,
delivered-qps series, shed / SLO-violation / expiry counts from BOTH
sides (driver-observed and server counters) — persists to
``artifacts/loadtest.json`` (re-surfaced into
``artifacts/bench_results.csv`` by ``benchmarks/roofline_report.py``).

  # demo: train 2 recipes briefly, deploy an ensemble bundle, load-test it
  PYTHONPATH=src python -m repro.launch.loadtest \
      --arch dlrm-criteo,dcn-criteo --qps 30 --duration 3 \
      --slo-ms 100 --queue-depth 64 --overload-qps 400

  # load-test an existing bundle; record the workload for exact replay
  PYTHONPATH=src python -m repro.launch.loadtest --config /path/ps.json \
      --qps 50 --duration 5 --trace-out /tmp/steady.jsonl

  # replay a recorded trace (the trace IS the workload)
  PYTHONPATH=src python -m repro.launch.loadtest --config /path/ps.json \
      --trace-in /tmp/steady.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from repro.configs.registry import RECSYS_RECIPES
from repro.launch.serve import _train_and_deploy, build_server_from_config
from repro.loadgen.driver import OpenLoopDriver
from repro.loadgen.workload import (ModelShape, Workload, WorkloadConfig,
                                    record_trace, replay_trace)

LOADTEST_ARTIFACT = "artifacts/loadtest.json"


def _parse_mix(spec: Optional[str]) -> Optional[Dict[str, float]]:
    """``"dlrm=3,dcn=1"`` -> ``{"dlrm": 3.0, "dcn": 1.0}``."""
    if not spec:
        return None
    out = {}
    for part in spec.split(","):
        name, _, w = part.partition("=")
        out[name.strip()] = float(w) if w else 1.0
    return out


def _stand_up(ps_path: str, *, cache_capacity):
    """Bundle -> servers (admission NOT yet armed) + model shapes."""
    from repro.serve.server import MultiModelServer

    built, loaded = build_server_from_config(
        ps_path, cache_capacity=cache_capacity)
    if isinstance(built, MultiModelServer):
        servers = {name: built[name] for name in built.models}
        models = loaded
        submit = built.submit
    else:
        servers, models = {loaded.name: built}, {loaded.name: loaded}
        submit = lambda _model, dense, cat: built.submit(dense, cat)
    shapes = {n: ModelShape.from_config(m.cfg)
              for n, m in models.items()}
    return built, servers, models, shapes, submit


def _warmup(servers, models, rows: int, max_coalesce: int) -> None:
    """Compile every code path the measured phases will hit, off the
    clock — BEFORE admission is armed, so a multi-second cold compile
    can never expire a warmup request.

    Two rounds: the sync ``predict`` path compiles every group shape
    the batcher can form (the coalescer concatenates whole requests, so
    group row counts are ``rows * k`` for ``k`` in 1..max_coalesce),
    then bursts through ``submit`` warm the serve loop's OWN path (the
    stream pipeline compiles separately from ``predict``). Servers come
    back STOPPED so the caller can arm admission and restart."""
    from repro.data.synthetic import SyntheticCTR
    data = {n: SyntheticCTR(models[n].cfg, rows) for n in servers}
    for n, s in servers.items():
        base = data[n].batch(10_000)
        for k in range(1, max_coalesce + 1):
            dense = np.concatenate([base["dense"]] * k)
            cat = np.concatenate([base["cat"]] * k)
            s.predict(dense, cat)
    for s in servers.values():
        s.start()
    for r in range(3):
        handles = []
        for n, s in servers.items():
            for k in range(max_coalesce):
                req = data[n].batch(30_000 + 10 * r + k)
                handles.append(s.submit(req["dense"], req["cat"]))
        for h in handles:
            out = h.get(timeout=300)
            if isinstance(out, BaseException):
                raise out
    for s in servers.values():
        s.stop()
        s.reset_serving_stats()


def _run_phase(name: str, driver: OpenLoopDriver, requests, servers,
               trace_out: Optional[str] = None) -> Dict:
    """One driver run + both-sides stats; resets server counters so the
    next phase starts clean."""
    if trace_out:
        n = record_trace(trace_out, requests)
        print(f"[{name}] recorded {n} requests -> {trace_out}")
        requests = replay_trace(trace_out)
    t0 = time.time()
    client = driver.run(requests)
    dt = time.time() - t0
    server_side = {}
    for n, s in servers.items():
        c = s.counters()
        server_side[n] = {
            "requests_delivered": c["requests_delivered"],
            "requests_shed": c["requests_shed"],
            "requests_expired": c["requests_expired"],
            "slo_violations": c["slo_violations"],
            "groups_served": c["groups_served"],
            "latency_ms": s.latency_percentiles(),
        }
        s.reset_serving_stats()
    print(f"[{name}] {client['scheduled']} scheduled in {dt:.1f}s "
          f"(max submit lag {client['max_submit_lag_ms']:.1f}ms)")
    for n, m in client["models"].items():
        lat = m["latency_ms"]
        sheds = server_side[n]["requests_shed"] \
            + server_side[n]["requests_expired"]
        print(f"[{name}][{n}] delivered={m['delivered']} "
              f"shed={m['shed_observed']} (server-side {sheds}) "
              f"lost={m['lost']} "
              f"p50={lat['p50']:.1f} p99={lat['p99']:.1f} "
              f"p999={lat['p999']:.1f}ms "
              f"slo_violations={m['slo_violations_observed']}")
    return {"client": client, "server": server_side}


def _smoke_assert(result: Dict, artifact: str) -> None:
    """The CI loadtest-smoke contract, as explicit raises (asserts
    vanish under ``python -O``): p99 measured, no sheds at low load,
    sheds observed in the deliberate overload phase, artifact written."""
    steady = result["phases"].get("steady")
    if not steady:
        raise SystemExit("smoke: no steady phase in result")
    for n, m in steady["client"]["models"].items():
        if m["delivered"] <= 0:
            raise SystemExit(f"smoke: model {n!r} delivered nothing")
        if m["latency_ms"]["p99"] <= 0:
            raise SystemExit(f"smoke: model {n!r} reports no p99")
        if m["lost"] > 0:
            raise SystemExit(f"smoke: model {n!r} lost {m['lost']} "
                             "responses to the drain timeout")
        sheds = steady["server"][n]["requests_shed"] \
            + steady["server"][n]["requests_expired"]
        if sheds > 0:
            raise SystemExit(f"smoke: model {n!r} shed {sheds} at "
                             "steady (under-capacity) load")
    over = result["phases"].get("overload")
    if over is not None:
        total_shed = sum(
            s["requests_shed"] + s["requests_expired"]
            for s in over["server"].values())
        if total_shed <= 0:
            raise SystemExit("smoke: deliberate overload phase shed "
                             "nothing — admission control inert?")
    if not os.path.exists(artifact):
        raise SystemExit(f"smoke: artifact {artifact} not written")
    print("smoke assertions passed: p99 reported, zero sheds at low "
          "load" + ("" if over is None
                    else f", {total_shed} sheds under overload"))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Open-loop load test against a deployment bundle "
                    "with latency SLOs and admission-controlled serving")
    ap.add_argument("--config", default=None,
                    help="ps.json of an existing deployment bundle")
    ap.add_argument("--arch", default="dlrm-criteo",
                    help="demo mode (no --config): train+deploy these "
                         "recipes first (comma-separated; 2+ archs "
                         "deploy an ensemble bundle)")
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--deploy-dir", default=None)
    ap.add_argument("--cache-capacity", type=int, default=None)
    # workload
    ap.add_argument("--qps", type=float, default=30.0,
                    help="offered request rate of the steady phase")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="steady-phase length in seconds")
    ap.add_argument("--rows", type=int, default=4,
                    help="rows per request")
    ap.add_argument("--arrival", default="poisson",
                    choices=("poisson", "constant"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--zipf-a", type=float, default=1.2)
    ap.add_argument("--drift-per-s", type=float, default=0.0,
                    help="fraction of the vocab the hot set shifts per "
                         "second (0 = stationary popularity)")
    ap.add_argument("--mix", default=None,
                    help="model traffic weights, e.g. 'dlrm=3,dcn=1' "
                         "(default: uniform over deployed models)")
    ap.add_argument("--trace-out", default=None,
                    help="record the steady workload to this JSONL "
                         "trace, then drive the run from the replay")
    ap.add_argument("--trace-in", default=None,
                    help="drive the steady phase from a recorded trace "
                         "instead of generating a workload")
    # admission / SLO
    ap.add_argument("--slo-ms", type=float, default=100.0,
                    help="declared per-request latency SLO")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="admission queue bound per model (0 = "
                         "unbounded)")
    ap.add_argument("--no-deadline-batching", action="store_true",
                    help="fixed max_batch coalescing instead of "
                         "deadline-aware batch sizing + expiry drops")
    ap.add_argument("--max-coalesce", type=int, default=4,
                    help="max requests per coalesced group (sets "
                         "max_batch = rows * this; every resulting "
                         "group shape is compiled during warmup)")
    # overload phase
    ap.add_argument("--overload-qps", type=float, default=None,
                    help="offered rate of a second, deliberately "
                         "overloaded phase (default: skip the phase)")
    ap.add_argument("--overload-duration", type=float, default=2.0)
    ap.add_argument("--drain-timeout", type=float, default=60.0)
    ap.add_argument("--artifacts", default=LOADTEST_ARTIFACT)
    ap.add_argument("--smoke-assert", action="store_true",
                    help="CI gate: fail unless p99 is reported, the "
                         "steady phase shed nothing and the overload "
                         "phase (if run) shed something")
    args = ap.parse_args(argv)

    ps_path = args.config
    if ps_path is None:
        archs = [a.strip() for a in args.arch.split(",") if a.strip()]
        known = tuple(sorted(RECSYS_RECIPES))
        bad = [a for a in archs if a not in known]
        if bad:
            ap.error(f"unknown arch(es) {bad}; choose from {known}")
        deploy_dir = args.deploy_dir or tempfile.mkdtemp(prefix="hps_")
        ps_path = _train_and_deploy(archs, args.train_steps,
                                    max(args.rows, 16), deploy_dir,
                                    args.cache_capacity)
        print(f"deployment bundle: {deploy_dir}")

    built, servers, models, shapes, submit = _stand_up(
        ps_path, cache_capacity=args.cache_capacity)
    for s in servers.values():
        s.max_batch = args.rows * args.max_coalesce

    driver = OpenLoopDriver(submit, slo_ms=args.slo_ms,
                            drain_timeout_s=args.drain_timeout)
    phases = {}
    with next(iter(models.values())).mesh:
        _warmup(servers, models, args.rows, args.max_coalesce)
        for s in servers.values():    # arm admission on the warm,
            s.set_admission(          # stopped servers, then restart
                queue_depth=args.queue_depth or None,
                slo_ms=args.slo_ms,
                deadline_batching=not args.no_deadline_batching)
            s.start()
        try:
            if args.trace_in:
                steady_reqs = replay_trace(args.trace_in)
            else:
                steady_cfg = WorkloadConfig(
                    qps=args.qps, duration_s=args.duration,
                    rows=args.rows, arrival=args.arrival,
                    seed=args.seed, zipf_a=args.zipf_a,
                    drift_per_s=args.drift_per_s,
                    mix=_parse_mix(args.mix))
                steady_reqs = Workload(steady_cfg, shapes)
            phases["steady"] = _run_phase("steady", driver, steady_reqs,
                                          servers,
                                          trace_out=args.trace_out)
            if args.overload_qps is not None:
                over_cfg = WorkloadConfig(
                    qps=args.overload_qps,
                    duration_s=args.overload_duration, rows=args.rows,
                    arrival=args.arrival, seed=args.seed + 1,
                    zipf_a=args.zipf_a, drift_per_s=args.drift_per_s,
                    mix=_parse_mix(args.mix))
                phases["overload"] = _run_phase(
                    "overload", driver, Workload(over_cfg, shapes),
                    servers)
        finally:
            # close, not stop: every still-queued handle gets the typed
            # rejection — the driver's drain already collected the rest
            built.close()

    result = {
        "ps_config": os.path.abspath(ps_path),
        "workload": {
            "qps": args.qps, "duration_s": args.duration,
            "rows": args.rows, "arrival": args.arrival,
            "seed": args.seed, "zipf_a": args.zipf_a,
            "drift_per_s": args.drift_per_s, "mix": _parse_mix(args.mix),
            "overload_qps": args.overload_qps,
        },
        "admission": {
            "slo_ms": args.slo_ms, "queue_depth": args.queue_depth,
            "deadline_batching": not args.no_deadline_batching,
        },
        "phases": phases,
    }
    os.makedirs(os.path.dirname(args.artifacts) or ".", exist_ok=True)
    with open(args.artifacts, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.artifacts}")

    if args.smoke_assert:
        _smoke_assert(result, args.artifacts)


if __name__ == "__main__":
    main()
