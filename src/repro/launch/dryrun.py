import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces a JSON artifact under ``artifacts/dryrun/``
holding ``memory_analysis``, ``cost_analysis`` (loop-blind, kept for
cross-checking), the trip-count-aware HLO roofline terms, analytic model
FLOPs, and the collective-bytes breakdown. ``--mesh both`` proves the
single-pod (16×16) and multi-pod (2×16×16) shardings.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --arch dlrm-criteo --shape train_65k \
      --variant a2a --comm all_to_all
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    LM_SHAPES, LM_SHAPE_BY_NAME, ShapeConfig, TrainConfig, shape_applicable,
)
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import lm_input_specs, lm_step_fn, recsys_input_specs

RECSYS_SHAPES = (ShapeConfig("train_65k", "train", 1, 65536),)


# ---------------------------------------------------------------------------
# Analytic model FLOPs (for the "useful compute" ratio)
# ---------------------------------------------------------------------------

def analytic_lm_flops(cfg, shape: ShapeConfig) -> float:
    n_act = cfg.active_param_count
    d, hd = cfg.d_model, cfg.resolved_head_dim
    v = cfg.vocab_size
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        attn = 2.0 * shape.global_batch * cfg.num_heads * hd \
            * (shape.seq_len ** 2) * (cfg.num_layers if not
                                      cfg.block_pattern[0].startswith("rg")
                                      else cfg.num_layers // 3)
        return 6.0 * n_act * toks + 6.0 * d * v * toks + 3.0 * attn
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        attn = 2.0 * shape.global_batch * cfg.num_heads * hd \
            * (shape.seq_len ** 2) * cfg.num_layers
        return 2.0 * n_act * toks + attn
    # decode: one token vs seq_len cache
    b = shape.global_batch
    s = min(shape.seq_len, 10 ** 9)
    attn_layers = sum(1 for k in (cfg.block_pattern
                                  * (cfg.num_layers //
                                     len(cfg.block_pattern) + 1))
                      [:cfg.num_layers] if "attn" in k)
    window = cfg.local_attn_window if "local_attn" in cfg.block_pattern \
        else s
    attn = 4.0 * b * cfg.num_heads * hd * min(s, window) * attn_layers
    return 2.0 * n_act * b + 2.0 * d * v * b + attn


def analytic_recsys_flops(cfg, batch: int) -> float:
    def mlp_flops(dims, in_dim):
        f, cur = 0.0, in_dim
        for o in dims:
            f += 2.0 * batch * cur * o
            cur = o
        return f
    t, d = cfg.num_tables, cfg.embedding_dim
    f = mlp_flops(cfg.bottom_mlp, cfg.num_dense_features)
    flat = cfg.num_dense_features + t * d
    if cfg.model == "dlrm":
        ft = t + 1
        f += 2.0 * batch * ft * ft * d
        f += mlp_flops(cfg.top_mlp, cfg.bottom_mlp[-1] + ft * (ft - 1) // 2)
    else:
        f += mlp_flops(cfg.top_mlp, flat)
    return 3.0 * f   # fwd + bwd


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------

def _sharded_sds(tree, shardings):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings)


def run_lm_cell(arch: str, shape_name: str, mesh_kind: str,
                outdir: str, *, variant: str = "baseline",
                model_kwargs: Optional[Dict] = None,
                dump_hlo: bool = False) -> Dict:
    from repro.models.lm.backbone import LMModel
    from repro.optim.optimizers import make as make_opt

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = LM_ARCHS[arch]
    shape = LM_SHAPE_BY_NAME[shape_name]
    record: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "variant": variant, "kind": shape.kind}
    if not shape_applicable(cfg, shape):
        record["status"] = "skipped"
        record["reason"] = ("full-attention arch: O(S^2) at 524k seq "
                            "is out of assignment scope (DESIGN.md §5)")
        _write(outdir, record)
        return record

    kw = dict(model_kwargs or {})
    # large-vocab archs need smaller loss chunks to bound logits memory
    kw.setdefault("loss_chunk", 256 if cfg.vocab_size > 100_000 else 512)
    kw.setdefault("q_chunk", 2048 if shape.seq_len >= 32768 else 1024)
    kw.setdefault("k_chunk", 2048 if shape.seq_len >= 32768 else 1024)
    if shape.kind == "train":
        kw.setdefault("remat", "full")
    model = LMModel(cfg, mesh, **kw)
    record["embed_mode"] = model.embed_mode
    record["fsdp"] = model.fsdp

    with mesh:
        t0 = time.time()
        params_sds = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        params_sds = _sharded_sds(params_sds, model.param_shardings())
        step = lm_step_fn(model, shape)
        specs = lm_input_specs(model, shape, mesh)
        if shape.kind == "train":
            tcfg = TrainConfig()
            opt = make_opt("adamw", tcfg)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            rep = NamedSharding(mesh, P())
            opt_sh = {"step": rep,
                      "mu": model.param_shardings(),
                      "nu": model.param_shardings()}
            opt_sds = _sharded_sds(opt_sds, opt_sh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, specs["batch"])
        elif shape.kind == "prefill":
            lowered = jax.jit(step).lower(params_sds, specs["batch"])
        else:
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params_sds, specs["tokens"], specs["cache"], specs["pos"])
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)
    _finish(record, compiled, analytic_lm_flops(cfg, shape), mesh,
            outdir, dump_hlo)
    return record


def run_recsys_cell(arch: str, shape_name: str, mesh_kind: str,
                    outdir: str, *, variant: str = "baseline",
                    comm: str = "allgather_rs",
                    embed_shard: str = "all",
                    dump_hlo: bool = False) -> Dict:
    from repro.models.recsys.model import RecsysModel
    from repro.train.train_step import build_train_step, init_opt_state
    from repro.data.pipeline import batch_shardings

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = RECSYS_ARCHS[arch]
    shape = next(s for s in RECSYS_SHAPES if s.name == shape_name)
    batch = shape.global_batch
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant, "kind": "train", "comm": comm}
    tcfg = TrainConfig()
    with mesh:
        t0 = time.time()
        model = RecsysModel(cfg, mesh, global_batch=batch, comm=comm,
                            embed_shard_axes=embed_shard)
        params_sds = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        params_sds = _sharded_sds(params_sds, model.param_shardings())
        opt_sds = jax.eval_shape(
            lambda p: init_opt_state(p, tcfg), params_sds)
        rep = NamedSharding(mesh, P())

        def opt_sharding(path, leaf):
            # row-wise accumulators follow their table's row sharding
            keys = [str(getattr(p, "key", "")) for p in path]
            if "acc" in keys and len(leaf.shape) == 1:
                tab = keys[-1]
                group = keys[-2]
                psh = model.param_shardings()
                src = psh.get(group, {}).get(tab) if group in psh else None
                if src is not None and len(src.spec) >= 1:
                    return NamedSharding(mesh, P(src.spec[0]))
            return rep

        opt_sds = jax.tree_util.tree_map_with_path(
            lambda pa, l: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=opt_sharding(pa, l)),
            opt_sds)
        b_sh = batch_shardings(mesh)
        h = max(t.hotness for t in cfg.tables)
        batch_sds = {
            "dense": jax.ShapeDtypeStruct(
                (batch, cfg.num_dense_features), jnp.float32,
                sharding=b_sh["dense"]),
            "cat": jax.ShapeDtypeStruct(
                (batch, cfg.num_tables, h), jnp.int32, sharding=b_sh["cat"]),
            "label": jax.ShapeDtypeStruct(
                (batch,), jnp.float32, sharding=b_sh["label"]),
        }
        step = build_train_step(model, tcfg)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            params_sds, opt_sds, batch_sds)
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)
    _finish(record, compiled, analytic_recsys_flops(cfg, batch), mesh,
            outdir, dump_hlo)
    return record


def _finish(record, compiled, model_flops, mesh, outdir, dump_hlo):
    mem = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_estimate_bytes": mem.argument_size_in_bytes
        + mem.output_size_in_bytes + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes,
    }
    try:
        ca = compiled.cost_analysis()
        record["xla_cost_analysis"] = {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
        }
    except Exception:
        record["xla_cost_analysis"] = None
    hlo = compiled.as_text()
    record["hlo_len"] = len(hlo)
    analysis = hlo_analysis.analyze_text(hlo)
    record["analysis"] = analysis
    n_dev = int(np.prod(mesh.devices.shape))
    record["n_devices"] = n_dev
    record["model_flops"] = model_flops
    hlo_global = analysis["flops"] * n_dev
    record["model_flops_ratio"] = (model_flops / hlo_global
                                   if hlo_global else None)
    record["status"] = "ok"
    if dump_hlo:
        import gzip
        path = os.path.join(outdir, _name(record) + ".hlo.txt.gz")
        with gzip.open(path, "wt") as f:
            f.write(hlo)
    _write(outdir, record)


def _name(record):
    return (f"{record['arch']}__{record['shape']}__{record['mesh']}"
            f"__{record['variant']}")


def _write(outdir, record):
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, _name(record) + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    a = record.get("analysis", {})
    mem = record.get("memory", {})
    if record["status"] == "ok":
        print(f"[{record['mesh']}] {record['arch']} × {record['shape']} "
              f"({record['variant']}): compile={record['compile_s']}s "
              f"Tc={a['compute_s']*1e3:.2f}ms Tm={a['memory_s']*1e3:.2f}ms "
              f"Tn={a['collective_s']*1e3:.2f}ms dom={a['dominant']} "
              f"peak={mem['peak_estimate_bytes']/2**30:.2f}GiB "
              f"ratio={record.get('model_flops_ratio') or 0:.3f}",
              flush=True)
    else:
        print(f"[{record['mesh']}] {record['arch']} × {record['shape']}: "
              f"{record['status']} ({record.get('reason', '')})",
              flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--comm", default="allgather_rs")
    ap.add_argument("--embed-shard", default="all", choices=["all", "model"])
    ap.add_argument("--embed-mode", default="auto")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--embed-axes", default=None,
                    help="comma list, e.g. pod,data,model")
    ap.add_argument("--attn-partition", default=None,
                    choices=["auto", "heads", "seq"])
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in LM_ARCHS:
            for shp in LM_SHAPES:
                cells.append(("lm", arch, shp.name))
        for arch in RECSYS_ARCHS:
            for shp in RECSYS_SHAPES:
                cells.append(("recsys", arch, shp.name))
    else:
        kind = "recsys" if args.arch in RECSYS_ARCHS else "lm"
        shapes = [args.shape] if args.shape else \
            ([s.name for s in LM_SHAPES] if kind == "lm"
             else [s.name for s in RECSYS_SHAPES])
        cells = [(kind, args.arch, s) for s in shapes]

    mkw = {}
    if args.embed_mode != "auto":
        mkw["embed_mode"] = args.embed_mode
    if args.remat:
        mkw["remat"] = args.remat
    if args.loss_chunk:
        mkw["loss_chunk"] = args.loss_chunk
    if args.q_chunk:
        mkw["q_chunk"] = args.q_chunk
    if args.embed_axes:
        mkw["embed_shard_axes"] = tuple(args.embed_axes.split(","))
    if args.attn_partition:
        mkw["attn_partition"] = args.attn_partition

    failures = []
    for kind, arch, shp in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shp}__{mesh_kind}__{args.variant}"
            path = os.path.join(args.out, name + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"skip existing {name}", flush=True)
                continue
            try:
                if kind == "lm":
                    run_lm_cell(arch, shp, mesh_kind, args.out,
                                variant=args.variant, model_kwargs=mkw,
                                dump_hlo=args.dump_hlo)
                else:
                    run_recsys_cell(arch, shp, mesh_kind, args.out,
                                    variant=args.variant, comm=args.comm,
                                    embed_shard=args.embed_shard,
                                    dump_hlo=args.dump_hlo)
            except Exception as e:
                failures.append((arch, shp, mesh_kind, repr(e)))
                print(f"FAIL {arch} × {shp} [{mesh_kind}]: {e}",
                      flush=True)
                traceback.print_exc()
                record = {"arch": arch, "shape": shp, "mesh": mesh_kind,
                          "variant": args.variant, "status": "error",
                          "reason": repr(e)}
                _write(args.out, record)
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
