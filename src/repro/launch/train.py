"""Production training launcher.

Builds the mesh from flags (or the production config), constructs the
model for ``--arch``, and drives the fault-tolerant Trainer with async
checkpoints. On a real TPU pod each host runs this same script under
``jax.distributed``; on CPU it runs the reduced smoke config so the full
path is exercisable anywhere.

  PYTHONPATH=src python -m repro.launch.train --arch dlrm-criteo \
      --steps 200 --batch 1024 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 20
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import (
    LM_ARCHS, RECSYS_RECIPES, reduce_for_smoke,
)
from repro.launch.mesh import make_production_mesh, make_test_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=sorted(LM_ARCHS) + sorted(RECSYS_RECIPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--mode", default="gspmd", choices=["gspmd", "manual"])
    ap.add_argument("--comm", default="auto",
                    choices=["auto", "allgather_rs", "all_to_all"],
                    help="embedding collective recipe: 'auto' picks "
                         "all_to_all for one-hot models with large "
                         "tables and allgather_rs otherwise")
    ap.add_argument("--grad-ar-dtype", default="f32",
                    choices=["f32", "bf16"],
                    help="bf16 = compressed gradient all-reduce")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="auto",
                    help="'auto' | 'single' | 'multi' | 'RxC'")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.mesh == "auto":
        mesh = make_test_mesh((n_dev, 1)) if n_dev < 256 else \
            make_production_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    else:
        r, c = (int(x) for x in args.mesh.split("x"))
        mesh = make_test_mesh((r, c))
    print(f"mesh: {dict(mesh.shape)} over {n_dev} devices")

    if args.arch in RECSYS_RECIPES:
        # recsys models go through the graph API front door: the recipe
        # module declares the layer graph, compile() lowers it — novel
        # graphs (twotower/crossdeep) run through the generic compiled
        # program, the paper recipes through their canonical configs
        import importlib

        from repro.api import Solver

        recipe = importlib.import_module(RECSYS_RECIPES[args.arch])
        solver = Solver(batch_size=args.batch, lr=args.lr,
                        grad_allreduce_dtype=args.grad_ar_dtype,
                        mode=args.mode, comm=args.comm,
                        ckpt_interval=args.ckpt_interval)
        model = recipe.build_model(smoke=args.smoke or n_dev == 1,
                                   solver=solver, mesh=mesh)
        model.compile()
        model.summary()
        hist = model.fit(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         log_every=args.log_every)
        losses = [h["loss"] for h in hist]
        print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
              f"{model.stragglers} stragglers flagged")
        return

    # LM path
    import jax.numpy as jnp
    from repro.models.lm.backbone import LMModel

    cfg = LM_ARCHS[args.arch]
    if args.smoke or n_dev == 1:
        cfg = reduce_for_smoke(cfg)
    with mesh:
        model = LMModel(cfg, mesh,
                        q_chunk=min(args.seq, 128),
                        k_chunk=min(args.seq, 128),
                        loss_chunk=min(args.seq, 128))
        params = model.init(jax.random.PRNGKey(0))
        print(f"arch {cfg.name}: embed_mode={model.embed_mode} "
              f"attn_partition={model.attn_partition}")

        @jax.jit
        def step(params, tokens):
            loss, g = jax.value_and_grad(model.train_loss)(
                params, {"tokens": tokens})
            new = jax.tree.map(
                lambda p, gg: p - args.lr * gg.astype(p.dtype), params, g)
            return new, loss

        rng = np.random.default_rng(0)
        for i in range(args.steps):
            tokens = jnp.asarray(rng.integers(
                0, cfg.vocab_size, (args.batch, args.seq)))
            params, loss = step(params, tokens)
            if i % args.log_every == 0:
                print(f"step {i:4d} loss={float(loss):.4f}")
        print(f"done: final loss {float(loss):.4f} "
              f"(ln V = {np.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
