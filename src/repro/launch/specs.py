"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

No device allocation happens here — stand-ins are weak-type-correct and
carry NamedShardings so ``jax.jit(...).lower()`` sees the production
layout.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, RecsysConfig, ShapeConfig
from repro.models.lm.backbone import LMModel


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is not None and spec is not None:
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.ShapeDtypeStruct(shape, dtype)


def lm_input_specs(model: LMModel, shape: ShapeConfig,
                   mesh: Optional[Mesh] = None) -> Dict:
    """Returns kwargs (as a dict) for the step function of ``shape.kind``."""
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    dp = tuple(a for a in (mesh.axis_names if mesh else ("data",))
               if a != "model")
    dp_n = int(np.prod([mesh.shape[a] for a in dp])) if mesh else 1
    if b % dp_n != 0:
        dp = None              # e.g. long_500k with global_batch=1
    tok_spec = P(dp, None)
    if shape.kind in ("train", "prefill"):
        s_text = s - (cfg.frontend_seq if cfg.frontend == "vision" else 0)
        batch = {"tokens": _sds((b, s_text), jnp.int32, mesh, tok_spec)}
        if cfg.frontend == "vision":
            batch["patches"] = _sds((b, cfg.frontend_seq, cfg.d_model),
                                    jnp.bfloat16, mesh,
                                    P(dp, None, None) if mesh else None)
        if cfg.frontend == "audio":
            batch["frames"] = _sds((b, max(s // 8, 16), cfg.d_model),
                                   jnp.bfloat16, mesh,
                                   P(dp, None, None) if mesh else None)
        return {"batch": batch}
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    if mesh is not None:
        cache_specs = model.cache_specs(b)
        cache = jax.tree.map(
            lambda sds_, sp: _sds(sds_.shape, sds_.dtype, mesh, sp),
            cache, cache_specs)
    return {
        "tokens": _sds((b, 1), jnp.int32, mesh,
                       tok_spec if mesh else None),
        "cache": cache,
        "pos": _sds((b,), jnp.int32, mesh, P(dp) if mesh else None),
    }


def lm_step_fn(model: LMModel, shape: ShapeConfig, tcfg=None):
    """The function to lower for this cell."""
    if shape.kind == "train":
        from repro.configs.base import TrainConfig
        from repro.optim.optimizers import make
        tcfg = tcfg or TrainConfig()
        opt = make("adamw", tcfg)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.train_loss)(params,
                                                               batch)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        return train_step
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        return prefill_step

    def serve_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)

    return serve_step


def recsys_input_specs(cfg: RecsysConfig, batch: int,
                       mesh: Optional[Mesh] = None) -> Dict:
    dp = tuple(a for a in (mesh.axis_names if mesh else ("data",))
               if a != "model")
    h = max(t.hotness for t in cfg.tables)
    mk = lambda shape, dt, spec: _sds(shape, dt, mesh, spec)
    return {
        "dense": mk((batch, cfg.num_dense_features), jnp.float32,
                    P(dp, None)),
        "cat": mk((batch, cfg.num_tables, h), jnp.int32, P(dp, None, None)),
        "label": mk((batch,), jnp.float32, P(dp)),
    }
