"""Config-driven serving launcher (paper Figure 2, the ps.json path).

A deployment bundle written by ``api.Model.deploy`` — ``ps.json`` +
``graph.json`` + ``dense.npz`` + the ``pdb/`` table files — is all this
launcher needs: no Python object from training is required.
``build_server_from_config`` reconstructs the model graph from JSON,
re-lowers it (config hash verified), reloads the dense weights, reopens
the PDB tables (wide twins included) and stands up the
``HPS`` + ``InferenceServer``.

  # serve an existing bundle
  PYTHONPATH=src python -m repro.launch.serve --config /path/ps.json \
      --requests 50 --batch 64

  # demo: train a recipe for a few steps, deploy, then serve THROUGH
  # the written bundle (wdl exercises the two-HPS wide path)
  PYTHONPATH=src python -m repro.launch.serve --arch dlrm-criteo \
      --requests 50 --batch 64
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import tempfile
import time

import numpy as np

from repro.configs.base import (
    HPSConfig, hps_config_from_dict, recsys_config_hash,
)


def load_ps_config(path: str) -> HPSConfig:
    with open(path) as f:
        return hps_config_from_dict(json.load(f))


def build_server_from_config(ps_path: str, *, mesh=None, vdb=None,
                             bus=None):
    """ps.json -> ready InferenceServer (the Triton-ensemble analogue).

    Returns ``(server, model)`` — the api.Model is handed back so the
    caller can cross-check predictions or introspect the graph.
    """
    from repro.api import Model
    from repro.core.hps.hps import HPS
    from repro.core.hps.persistent_db import PersistentDB
    from repro.models.recsys.model import wide_tables
    from repro.serve.server import InferenceServer
    from repro.train import checkpoint as ck

    import jax

    base = os.path.dirname(os.path.abspath(ps_path))
    hcfg = load_ps_config(ps_path)

    m = Model.from_json(os.path.join(base, hcfg.graph_path), mesh=mesh)
    m.compile()
    if hcfg.config_hash and \
            recsys_config_hash(m.cfg) != hcfg.config_hash:
        raise ValueError(f"{ps_path}: graph does not lower to the "
                         "deployed config (hash mismatch)")

    # dense weights: flat key-paths -> the model's param tree (minus
    # embeddings, which live in the parameter server)
    data = np.load(os.path.join(base, hcfg.dense_weights_path))
    flat = {k: data[k] for k in data.files}
    with m.mesh:
        dummy = jax.eval_shape(
            lambda: m.model.init(jax.random.PRNGKey(0)))
    template = {k: v for k, v in dummy.items()
                if k not in ("embedding", "wide_embedding")}
    dense = ck.unflatten_like(template, flat)

    pdb = PersistentDB(os.path.join(base, hcfg.pdb_root))
    for t in hcfg.tables:
        pdb.open_table(hcfg.model, t.name)
    hps = HPS(hcfg.model, hcfg.tables, pdb, vdb=vdb, bus=bus,
              cache_capacity=hcfg.cache_capacity,
              cache_shards=hcfg.cache_shards)
    wide_hps = None
    if hcfg.wide:
        wtabs = wide_tables(m.cfg)
        for t in wtabs:
            pdb.open_table(hcfg.model, t.name)
        # shares bus/VDB/striping with the deep HPS so online updates
        # reach the wide L1 too
        wide_hps = HPS(hcfg.model, wtabs, pdb, vdb=vdb, bus=bus,
                       cache_capacity=hcfg.cache_capacity,
                       cache_shards=hcfg.cache_shards)
    server = InferenceServer(m.model, dense, hps, wide_hps=wide_hps,
                             max_batch=hcfg.max_batch,
                             refresh_budget=hcfg.refresh_budget)
    return server, m


def _train_and_deploy(arch: str, train_steps: int, batch: int,
                      deploy_dir: str, cache_capacity: int) -> str:
    """Demo path: train a recipe briefly via the graph API, write the
    deployment bundle, return the ps.json path."""
    from repro.api import Solver
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_"))
    m = mod.build_model(smoke=True,
                        solver=Solver(batch_size=batch, lr=1e-2))
    m.compile()
    hist = m.fit(steps=train_steps)
    print(f"trained {train_steps} steps, "
          f"loss={hist[-1]['loss']:.4f}")
    m.deploy(deploy_dir, cache_capacity=cache_capacity)
    return os.path.join(deploy_dir, "ps.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="ps.json of an existing deployment bundle")
    ap.add_argument("--arch", default="dlrm-criteo",
                    choices=["dlrm-criteo", "dcn-criteo",
                             "deepfm-criteo", "wdl-criteo"],
                    help="demo mode: train+deploy this recipe first")
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--cache-capacity", type=int, default=2048)
    ap.add_argument("--deploy-dir", default=None)
    args = ap.parse_args()

    ps_path = args.config
    if ps_path is None:
        deploy_dir = args.deploy_dir or tempfile.mkdtemp(prefix="hps_")
        ps_path = _train_and_deploy(args.arch, args.train_steps,
                                    args.batch, deploy_dir,
                                    args.cache_capacity)
        print(f"deployment bundle: {deploy_dir}")

    from repro.data.synthetic import SyntheticCTR
    server, m = build_server_from_config(ps_path)
    data = SyntheticCTR(m.cfg, args.batch)

    with m.mesh:
        warm = data.batch(10_000)
        server.predict(warm["dense"], warm["cat"])
        server.latencies_ms.clear()
        server.start()
        t0 = time.time()
        handles = []
        for r in range(args.requests):
            req = data.batch(20_000 + r)
            handles.append(server.submit(req["dense"], req["cat"]))
        outs = [h.get(timeout=300) for h in handles]
        dt = time.time() - t0
        server.stop()

    n = sum(len(o) for o in outs)
    pct = server.latency_percentiles()
    stats = server.hps.stats()
    print(f"served {n} predictions in {dt:.2f}s ({n / dt:.0f} qps)")
    print(f"latency ms: p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
          f"p99={pct['p99']:.1f}")
    print(f"L1 hit rate: "
          f"{np.mean(list(stats['l1_hit_rate'].values())):.3f}; "
          f"L2 hits={stats['l2_hits']} misses={stats['l2_misses']}")


if __name__ == "__main__":
    main()
