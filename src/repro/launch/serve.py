"""Config-driven serving launcher (paper Figure 2, the ps.json path).

A deployment bundle written by ``api.Model.deploy`` — ``ps.json`` +
``graph.json`` + ``dense.npz`` + the ``pdb/`` table files — is all this
launcher needs: no Python object from training is required.
``build_server_from_config`` reconstructs the model graph from JSON,
re-lowers it (config hash verified), reloads the dense weights, reopens
the PDB tables (wide twins included) and stands up the
``HPS`` + ``InferenceServer``.

An ENSEMBLE bundle written by ``api.deploy_ensemble`` holds several
models behind one ps.json (format ``repro-ps-ensemble-v1``); the same
entry point then stands up a ``MultiModelServer`` — per-model L1 caches
and serve loops over ONE shared PersistentDB, ONE shared VolatileDB and
ONE shared message bus — bit-exact with per-model in-process servers.

  # serve an existing bundle (single-model or ensemble)
  PYTHONPATH=src python -m repro.launch.serve --config /path/ps.json \
      --requests 50 --batch 64

  # demo: train a recipe for a few steps, deploy, then serve THROUGH
  # the written bundle (wdl exercises the two-HPS wide path)
  PYTHONPATH=src python -m repro.launch.serve --arch dlrm-criteo \
      --requests 50 --batch 64

  # demo: 2-model ensemble bundle, one storage backend, per-model stats
  PYTHONPATH=src python -m repro.launch.serve \
      --arch dlrm-criteo,dcn-criteo --requests 10 --batch 32
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import tempfile
import time
from typing import Optional

import numpy as np

from repro.configs.base import (
    EnsembleConfig, HPSConfig, ps_config_from_dict, recsys_config_hash,
)
from repro.configs.registry import RECSYS_RECIPES


def load_ps_config(path: str):
    """ps.json -> :class:`HPSConfig` or :class:`EnsembleConfig`."""
    with open(path) as f:
        return ps_config_from_dict(json.load(f))


def _build_model_server(base: str, hcfg: HPSConfig, pdb, *, mesh=None,
                        vdb=None, bus=None,
                        cache_capacity: Optional[int] = None,
                        payload_dtype: Optional[str] = None):
    """One model's HPS(+wide)+InferenceServer over an open PDB: reload
    the graph + dense weights from the bundle, then hand off to the same
    ``Model._build_server`` wiring the in-process deploy path uses."""
    import dataclasses

    from repro.api import Model
    from repro.models.recsys.model import wide_tables
    from repro.train import checkpoint as ck
    from repro.train.train_step import is_sparse_key

    import jax

    if cache_capacity is not None:      # operator override of the
        hcfg = dataclasses.replace(     # bundle's (hotness-sized) L1
            hcfg, cache_capacity=cache_capacity)
    if payload_dtype is not None:       # operator override of the L1
        hcfg = dataclasses.replace(     # storage precision (safe: the
            hcfg, payload_dtype=payload_dtype)  # PDB/VDB rows stay f32)
    m = Model.from_json(os.path.join(base, hcfg.graph_path), mesh=mesh)
    m.compile()
    if hcfg.config_hash and \
            recsys_config_hash(m.cfg) != hcfg.config_hash:
        raise ValueError(f"model {hcfg.model!r}: graph does not lower "
                         "to the deployed config (hash mismatch)")
    if m.name != hcfg.model:    # storage is namespaced by this name
        raise ValueError(f"{hcfg.graph_path}: graph name {m.name!r} != "
                         f"deployed model name {hcfg.model!r}")

    # dense weights: flat key-paths -> the model's param tree (minus
    # embeddings, which live in the parameter server)
    data = np.load(os.path.join(base, hcfg.dense_weights_path))
    flat = {k: data[k] for k in data.files}
    with m.mesh:
        dummy = jax.eval_shape(
            lambda: m.model.init(jax.random.PRNGKey(0)))
    template = {k: v for k, v in dummy.items() if not is_sparse_key(k)}
    dense = ck.unflatten_like(template, flat)

    for t in hcfg.tables:
        pdb.open_table(hcfg.model, t.name)
    if hcfg.wide:
        for t in wide_tables(m.cfg):
            pdb.open_table(hcfg.model, t.name)
    for g in m.cfg.extra_groups:        # N-group models: one table set
        for t in g.tables:              # (and later one HPS) per group
            pdb.open_table(hcfg.model, t.name)
    return m._build_server(pdb, hcfg, dense, vdb=vdb, bus=bus), m


def build_server_from_config(ps_path: str, *, mesh=None, vdb=None,
                             bus=None, cache_capacity=None,
                             payload_dtype: Optional[str] = None,
                             cache_budget: Optional[int] = None,
                             rebalance_interval_s: Optional[float] = None):
    """ps.json -> ready server (the Triton-ensemble analogue).

    Single-model bundles return ``(InferenceServer, api.Model)``;
    ensemble bundles return ``(MultiModelServer, {name: api.Model})`` —
    every member model served from ONE PersistentDB process, one shared
    VolatileDB and one shared message bus. The models are handed back so
    the caller can cross-check predictions or introspect the graphs.

    ``cache_capacity`` overrides the bundle's per-model L1 sizes (an
    ensemble bundle carries hotness-proportional sizes by default): an
    ``int`` applies to every model, a ``{model_name: rows}`` dict pins
    specific members and leaves the rest on their bundled value.

    ``payload_dtype`` overrides the bundle's L1 storage precision for
    every member (bundles deployed before the knob existed read back as
    ``"f32"``). ``cache_budget`` + ``rebalance_interval_s`` arm the
    ensemble's observed-miss-pressure budget rebalancer (opt-in, see
    :class:`~repro.serve.server.MultiModelServer`); single-model bundles
    ignore them.
    """
    from repro.core.hps.persistent_db import PersistentDB
    from repro.core.hps.volatile_db import VolatileDB
    from repro.serve.server import MultiModelServer

    base = os.path.dirname(os.path.abspath(ps_path))
    cfg = load_ps_config(ps_path)

    def _cap(model_name):
        if isinstance(cache_capacity, dict):
            return cache_capacity.get(model_name)
        return cache_capacity

    if isinstance(cfg, HPSConfig):
        pdb = PersistentDB(os.path.join(base, cfg.pdb_root))
        return _build_model_server(base, cfg, pdb, mesh=mesh, vdb=vdb,
                                   bus=bus, cache_capacity=_cap(cfg.model),
                                   payload_dtype=payload_dtype)

    assert isinstance(cfg, EnsembleConfig)
    pdb = PersistentDB(os.path.join(base, cfg.models[0].pdb_root))
    vdb = vdb if vdb is not None else VolatileDB()    # shared L2
    from repro.core.hps.message_bus import MessageBus
    bus = bus if bus is not None else MessageBus()    # shared bus
    servers, models = {}, {}
    for hcfg in cfg.models:
        servers[hcfg.model], models[hcfg.model] = _build_model_server(
            base, hcfg, pdb, mesh=mesh, vdb=vdb, bus=bus,
            cache_capacity=_cap(hcfg.model), payload_dtype=payload_dtype)
    return MultiModelServer(servers, vdb=vdb, pdb=pdb, bus=bus,
                            cache_budget=cache_budget,
                            rebalance_interval_s=rebalance_interval_s), \
        models


def _train_model(arch: str, train_steps: int, batch: int):
    """Train one recipe briefly via the graph API (novel graph archs
    included — they compile through the generic dense-graph program)."""
    from repro.api import Solver
    mod = importlib.import_module(RECSYS_RECIPES[arch])
    m = mod.build_model(smoke=True,
                        solver=Solver(batch_size=batch, lr=1e-2))
    m.compile()
    hist = m.fit(steps=train_steps)
    print(f"[{m.name}] trained {train_steps} steps, "
          f"loss={hist[-1]['loss']:.4f}")
    return m


def _train_and_deploy(archs, train_steps: int, batch: int,
                      deploy_dir: str,
                      cache_capacity: Optional[int],
                      payload_dtype: str = "f32") -> str:
    """Demo path: train the recipes briefly, write ONE deployment
    bundle (single-model or ensemble), return the ps.json path.
    ``cache_capacity=None`` lets ensembles size per-model L1 caches
    from table hotness; ``payload_dtype`` persists in the bundle's
    ps.json, so the rebuilt server serves the same precision mode."""
    models = [_train_model(a, train_steps, batch) for a in archs]
    if len(models) == 1:
        models[0].deploy(deploy_dir,
                         cache_capacity=cache_capacity or 2048,
                         payload_dtype=payload_dtype)
    else:
        from repro.api import deploy_ensemble
        deploy_ensemble(models, deploy_dir,
                        cache_capacity=cache_capacity,
                        payload_dtype=payload_dtype)
    return os.path.join(deploy_dir, "ps.json")


def _serve_bundle(ps_path: str, requests: int, batch: int, *,
                  sanitize: bool = False,
                  payload_dtype: Optional[str] = None) -> None:
    """Stand the bundle back up, push requests through ``submit`` and
    print the serving picture (per model for ensembles).

    ``sanitize=True`` arms the hot-path sanitizer over the measured
    phase and fails the run unless the serve loops performed exactly ONE
    device->host sync per delivered group and ZERO post-warmup
    recompiles — the pipeline invariants, enforced in CI.
    ``payload_dtype`` overrides the bundle's L1 storage precision."""
    from contextlib import nullcontext

    from repro.data.synthetic import SyntheticCTR
    from repro.serve.server import MultiModelServer

    built, loaded = build_server_from_config(ps_path,
                                             payload_dtype=payload_dtype)
    if isinstance(built, MultiModelServer):
        servers = {name: built[name] for name in built.models}
        models = loaded
    else:
        servers, models = {loaded.name: built}, {loaded.name: loaded}

    data = {n: SyntheticCTR(m.cfg, batch) for n, m in models.items()}
    outs = {n: [] for n in servers}
    with next(iter(models.values())).mesh:
        for n, s in servers.items():          # warm jit off the clock
            warm = data[n].batch(10_000)
            s.predict(warm["dense"], warm["cat"])
            if sanitize:
                # pin one request per coalesced group so "one sync per
                # group" is countable against the delivered groups
                s.max_batch = batch
            s.start()
        if sanitize:                          # warm the serve-loop path
            for r in range(2):
                warm_handles = [
                    s.submit(req["dense"], req["cat"])
                    for n, s in servers.items()
                    for req in (data[n].batch(30_000 + r),)]
                for h in warm_handles:
                    h.get(timeout=300)
        for s in servers.values():
            s.reset_latencies()

        if sanitize:
            from repro.analysis import HotPathMonitor
            mon = HotPathMonitor("serve-smoke")
        else:
            mon = None
        t0 = time.time()
        with mon if mon is not None else nullcontext():
            handles = []
            for r in range(requests):
                for n, s in servers.items():
                    req = data[n].batch(20_000 + r)
                    handles.append((n, s.submit(req["dense"],
                                                req["cat"])))
            for n, h in handles:
                out = h.get(timeout=300)
                if isinstance(out, Exception):  # a failed group delivers
                    raise out                   # its exception — surface
                outs[n].append(out)
        dt = time.time() - t0
        for s in servers.values():
            s.stop()

    if mon is not None:
        groups = sum(s.counters()["groups_served"]
                     for s in servers.values())
        summ = mon.summary()
        if summ["syncs"] != groups or summ["compiles"] != 0:
            raise SystemExit(
                f"hot-path sanitizer: expected {groups} host syncs (one "
                f"per served group) and 0 recompiles; observed "
                f"{summ['syncs']} syncs ({summ['d2h']} d2h, "
                f"{summ['block']} block) and {summ['compiles']} "
                "compile(s)")
        print(f"sanitizer: {summ['syncs']} host syncs over {groups} "
              "served groups, 0 post-warmup recompiles")

    total = sum(len(o) for os_ in outs.values() for o in os_)
    print(f"served {total} predictions over {len(servers)} model(s) "
          f"in {dt:.2f}s ({total / dt:.0f} qps)")
    for n, s in servers.items():
        # one full prediction batch per model from the rebuilt server,
        # or the bundle round-trip is broken — the CI serve-smoke job's
        # pass/fail signal, so an explicit raise (asserts vanish
        # under python -O)
        if not outs[n] or any(len(o) != batch for o in outs[n]):
            raise SystemExit(
                f"model {n!r}: expected {requests} responses of "
                f"{batch} rows, got {[len(o) for o in outs[n]]}")
        pct = s.latency_percentiles()
        stats = s.hps.stats()
        hit = np.mean(list(stats["l1_hit_rate"].values()))
        print(f"[{n}] {len(outs[n])} responses; latency ms: "
              f"p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
              f"p99={pct['p99']:.1f}; L1 hit rate {hit:.3f}; "
              f"L2 hits={stats['l2_hits']} misses={stats['l2_misses']}; "
              f"L3 fetches={sum(stats['l3_fetches']['calls'].values())}")

    _crosscheck_compressed(ps_path, servers, models, data,
                           override=payload_dtype)


#: max-abs prediction deviation a compressed bundle may show against an
#: f32-reference rebuild of the same bundle (post-sigmoid outputs)
_PAYLOAD_TOL = {"f16": 0.05, "int8": 0.1}


def _crosscheck_compressed(ps_path: str, servers, models, data, *,
                           override: Optional[str] = None) -> None:
    """Compressed-payload bundles: rebuild an f32-reference server from
    the SAME bundle (the dtype override re-pulls full-precision rows
    from the shared PDB) and require one prediction batch per compressed
    model to stay within quantization tolerance. Runs after the measured
    phase, so its extra compiles/syncs never trip the sanitizer."""
    cfg = load_ps_config(ps_path)
    members = cfg.models if isinstance(cfg, EnsembleConfig) else (cfg,)
    dtypes = {m.model: override or m.payload_dtype for m in members}
    if all(dt == "f32" for dt in dtypes.values()):
        return
    from repro.serve.server import MultiModelServer
    ref_built, _ = build_server_from_config(ps_path, payload_dtype="f32")
    if isinstance(ref_built, MultiModelServer):
        refs = {name: ref_built[name] for name in ref_built.models}
    else:
        refs = {next(iter(servers)): ref_built}
    with next(iter(models.values())).mesh:
        for n, s in servers.items():
            if dtypes[n] == "f32":
                continue
            req = data[n].batch(77_000)
            got = s.predict(req["dense"], req["cat"])
            want = refs[n].predict(req["dense"], req["cat"])
            dev = float(np.abs(got - want).max())
            tol = _PAYLOAD_TOL[dtypes[n]]
            if dev > tol:       # explicit raise: asserts vanish under -O
                raise SystemExit(
                    f"model {n!r}: {dtypes[n]} payload predictions "
                    f"deviate {dev:.4f} from the f32 reference "
                    f"(tolerance {tol})")
            print(f"[{n}] {dtypes[n]} payload within {tol} of the f32 "
                  f"reference rebuild (max abs dev {dev:.5f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="ps.json of an existing deployment bundle")
    ap.add_argument("--arch", default="dlrm-criteo",
                    help="demo mode: train+deploy these recipes first "
                         "(comma-separated list of "
                         f"{'|'.join(sorted(RECSYS_RECIPES))}; 2+ archs "
                         "deploy an ensemble bundle; twotower/crossdeep "
                         "are novel graphs served via the generic "
                         "compiler)")
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--cache-capacity", type=int, default=None,
                    help="per-model L1 rows (default: 2048 for a single "
                         "model; hotness-proportional for ensembles)")
    ap.add_argument("--payload-dtype", default=None,
                    choices=("f32", "f16", "int8"),
                    help="L1 payload storage precision: baked into the "
                         "bundle in demo mode, or an override when "
                         "serving an existing --config bundle; non-f32 "
                         "modes additionally cross-check one prediction "
                         "per model against an f32-reference rebuild")
    ap.add_argument("--deploy-dir", default=None)
    ap.add_argument("--sanitize", action="store_true",
                    help="arm the hot-path sanitizer over the measured "
                         "phase: fail unless every served group cost "
                         "exactly one device->host sync and zero "
                         "post-warmup recompiles")
    args = ap.parse_args()

    ps_path = args.config
    if ps_path is None:
        archs = [a.strip() for a in args.arch.split(",") if a.strip()]
        known = tuple(sorted(RECSYS_RECIPES))
        bad = [a for a in archs if a not in known]
        if bad:
            ap.error(f"unknown arch(es) {bad}; choose from {known}")
        deploy_dir = args.deploy_dir or tempfile.mkdtemp(prefix="hps_")
        ps_path = _train_and_deploy(archs, args.train_steps, args.batch,
                                    deploy_dir, args.cache_capacity,
                                    payload_dtype=args.payload_dtype
                                    or "f32")
        print(f"deployment bundle: {deploy_dir}")
        payload_override = None          # the bundle already carries it
    else:
        payload_override = args.payload_dtype

    _serve_bundle(ps_path, args.requests, args.batch,
                  sanitize=args.sanitize,
                  payload_dtype=payload_override)


if __name__ == "__main__":
    main()
