"""Production serving launcher: train-or-load a recsys model, deploy it
into the Hierarchical Parameter Server, and serve a synthetic request
stream through the batched inference server (paper Figure 2).

  PYTHONPATH=src python -m repro.launch.serve --arch dlrm-criteo \
      --requests 50 --batch 64
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import RECSYS_ARCHS, reduce_recsys_for_smoke
from repro.core.hps.hps import HPS
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB
from repro.data.synthetic import SyntheticCTR
from repro.launch.mesh import make_test_mesh
from repro.models.recsys.model import RecsysModel
from repro.serve.server import InferenceServer, deploy_from_training
from repro.train.train_step import build_train_step, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    # wdl/deepfm need a second (wide) HPS — served via the synchronous
    # path in tests; the CLI covers the no-wide models
    ap.add_argument("--arch", default="dlrm-criteo",
                    choices=["dlrm-criteo", "dcn-criteo"])
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--cache-capacity", type=int, default=2048)
    ap.add_argument("--pdb-root", default=None)
    args = ap.parse_args()

    cfg = reduce_recsys_for_smoke(RECSYS_ARCHS[args.arch])
    mesh = make_test_mesh((1, 1))

    with mesh:
        model = RecsysModel(cfg, mesh, global_batch=args.batch)
        params = model.init(jax.random.PRNGKey(0))
        data = SyntheticCTR(cfg, args.batch)
        tcfg = TrainConfig(learning_rate=1e-2)
        step = jax.jit(build_train_step(model, tcfg))
        opt = init_opt_state(params, tcfg)
        for i in range(args.train_steps):
            import jax.numpy as jnp
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt, aux = step(params, opt, batch)
        print(f"trained {args.train_steps} steps, "
              f"loss={float(aux['loss']):.4f}")

        root = args.pdb_root or tempfile.mkdtemp(prefix="hps_")
        pdb = PersistentDB(root)
        deploy_from_training(model, params, pdb, args.arch)
        hps = HPS(args.arch, cfg.tables, pdb,
                  vdb=VolatileDB(shards=2),
                  cache_capacity=args.cache_capacity)
        dense = {k: v for k, v in params.items()
                 if k not in ("embedding", "wide_embedding")}
        server = InferenceServer(model, dense, hps)

        # warm + serve
        warm = data.batch(10_000)
        server.predict(warm["dense"], warm["cat"])
        server.latencies_ms.clear()
        server.start()
        t0 = time.time()
        handles = []
        for r in range(args.requests):
            req = data.batch(20_000 + r)
            handles.append(server.submit(req["dense"], req["cat"]))
        outs = [h.get(timeout=300) for h in handles]
        dt = time.time() - t0
        server.stop()

        n = sum(len(o) for o in outs)
        pct = server.latency_percentiles()
        stats = hps.stats()
        print(f"served {n} predictions in {dt:.2f}s "
              f"({n / dt:.0f} qps)")
        print(f"latency ms: p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
              f"p99={pct['p99']:.1f}")
        print(f"L1 hit rate: "
              f"{np.mean(list(stats['l1_hit_rate'].values())):.3f}; "
              f"L2 hits={stats['l2_hits']} misses={stats['l2_misses']}")


if __name__ == "__main__":
    main()
