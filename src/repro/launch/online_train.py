"""Train-while-serving front door — the paper's online-training loop
end to end, in one process and with the freshness contract asserted.

The sequence:

1. train a small CTR model offline and ``deploy()`` it with an external
   VolatileDB + MessageBus, so the returned ``InferenceServer`` is LIVE
   (its consumer subscribes to ``hps.<model>.<table>``);
2. serve a Zipf request stream and record a baseline probe prediction;
3. run N incremental ETC-staged passes on NEW data — the
   ``OnlineTrainer`` seeds its parameter server from the deployed
   weights, trains through the fixed-capacity cache, and each pass
   boundary publishes ONE versioned update batch onto the bus;
4. wait until the last version is visible in LIVE predictions (consumer
   versions reached it AND the probe moved) and then until the probe
   converges onto the freshly-trained oracle — trained embeddings under
   the DEPLOYED dense net, because online updates refresh embeddings
   only. No redeploy, no restart, no server object rebuilt.

``--sanitize`` arms the hot-path sanitizer over the serving window
(probes + request stream, WITH the consumer loop applying updates and
draining refreshes mid-window) and fails unless the loop performed
exactly one device->host sync per served group and zero post-warmup
recompiles — the ETC passes themselves run outside the window, since a
train step's loss readback is a legitimate sync.

  PYTHONPATH=src python -m repro.launch.online_train --passes 3
  PYTHONPATH=src python -m repro.launch.online_train --sanitize
  PYTHONPATH=src python -m repro.launch.online_train --ps cached
"""
from __future__ import annotations

import argparse
import tempfile
import time
from contextlib import nullcontext
from typing import Dict, Optional

import numpy as np

from repro.api import (CreateSolver, DataReaderParams, DenseLayer, Input,
                       Model, SparseEmbedding)
from repro.configs.base import ETCParams
from repro.core.hps.message_bus import MessageBus
from repro.core.hps.volatile_db import VolatileDB
from repro.online import (OnlineTrainer, UpdatePublisher,
                          probe_prediction, wait_visible)

#: live predictions must land this close to the oracle — updates travel
#: by value, so the residual is serving-stack float noise only (the HPS
#: pooled gather rounds multi-hot sums in a different order than the
#: training collection; cf. the 2e-2 tolerance in test_serve)
_CONVERGE_TOL = 5e-3


def build_model(batch: int = 128, *, vocab: int = 600, dim: int = 16,
                seed: int = 0, lr: float = 5e-2) -> Model:
    """Small single-collection CTR graph on the synthetic Zipf reader —
    big enough that an ETC cache smaller than the vocab actually evicts."""
    solver = CreateSolver(batch_size=batch, lr=lr, seed=seed)
    reader = DataReaderParams(source="synthetic", num_dense_features=8)
    m = Model(solver, reader, name="online-demo")
    m.add(Input(dense_dim=8))
    m.add(SparseEmbedding(vocab_sizes=[vocab, vocab // 2], dim=dim,
                          top_name="emb", hotness=2))
    m.add(DenseLayer("mlp", ["dense", "emb"], ["logit"], units=(32, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m


def run_online(*, base_steps: int = 30, online_steps: int = 30,
               passes: int = 3, cache_rows: int = 256,
               requests: int = 20, batch: int = 128, ps: str = "staged",
               ps_root: Optional[str] = None,
               deploy_dir: Optional[str] = None, sanitize: bool = False,
               verbose: bool = True) -> Dict:
    """The full loop; returns the freshness/overhead metrics dict."""
    say = print if verbose else (lambda *a, **k: None)
    m = build_model(batch)
    m.compile()
    data_fn = m._reader_data_fn()
    hist = m.fit(data_fn, steps=base_steps)
    say(f"offline: {base_steps} steps, loss={hist[-1]['loss']:.4f}")

    vdb, bus = VolatileDB(), MessageBus()
    deploy_dir = deploy_dir or tempfile.mkdtemp(prefix="online-train-")
    if ps == "cached" and ps_root is None:
        ps_root = tempfile.mkdtemp(prefix="online-ps-")
    server = m.deploy(deploy_dir, cache_capacity=1024, vdb=vdb, bus=bus)
    deployed_dense = m.dense_params()     # the net the LIVE server runs
    probe = data_fn(10_000)
    table_names = [t.name for t in m.cfg.tables]

    import jax
    metrics: Dict = {}
    with m.mesh:
        server.predict(probe["dense"], probe["cat"])  # warm off-loop jit
        server.max_batch = batch      # one request == one served group
        server.start()
        for r in range(2):            # warm the serve-loop path
            w = data_fn(30_000 + r)
            out = server.submit(w["dense"], w["cat"]).get(timeout=300)
            if isinstance(out, Exception):
                raise out
        baseline = probe_prediction(server, probe["dense"],
                                    probe["cat"], timeout_s=300)

        # ---- incremental ETC passes, publishing at each boundary ----
        # (runs while the server keeps serving, but OUTSIDE any
        # sanitizer window: loss readback is a legitimate host sync)
        publisher = UpdatePublisher(bus, m.name)
        etc_cfg = ETCParams(cache_rows=cache_rows, ps=ps,
                            ps_root=ps_root, passes=passes)
        ot = OnlineTrainer(m, etc_cfg, publisher=publisher)
        t0 = time.perf_counter()
        ohist = ot.fit(lambda s: data_fn(base_steps + s), online_steps)
        etc_s_per_step = (time.perf_counter() - t0) / max(1, online_steps)
        m._params = ot.export_params()
        say(f"online: {online_steps} steps in {passes} passes, "
            f"loss={ohist[-1]['loss']:.4f}, published "
            f"v1..v{publisher.last_version()}")

        # the oracle the live server must converge to: freshly-trained
        # embeddings under the DEPLOYED dense net
        logits = m.model.apply(
            {**deployed_dense, "embedding": m._params["embedding"]},
            {"dense": probe["dense"], "cat": probe["cat"]})
        oracle = np.asarray(jax.nn.sigmoid(logits))

        server.reset_latencies()
        if sanitize:
            from repro.analysis import HotPathMonitor
            mon = HotPathMonitor("online-train")
        else:
            mon = None
        with mon if mon is not None else nullcontext():
            res = wait_visible(server, publisher,
                               publisher.last_version(),
                               probe["dense"], probe["cat"],
                               baseline=baseline, tables=table_names,
                               timeout_s=300)
            # versions applied -> L2/L3 hold the rows; keep probing
            # while the bounded refresh drains the remaining L1 backlog
            final = res["prediction"]
            deadline = time.monotonic() + 300
            while np.abs(final - oracle).max() > _CONVERGE_TOL:
                if time.monotonic() >= deadline:
                    raise SystemExit(
                        f"live predictions stuck "
                        f"{np.abs(final - oracle).max():.2e} from the "
                        f"oracle (tol {_CONVERGE_TOL})")
                final = probe_prediction(server, probe["dense"],
                                         probe["cat"], timeout_s=300)
            for r in range(requests):      # keep serving, fresh rows in
                w = data_fn(20_000 + r)
                out = server.submit(w["dense"], w["cat"]).get(timeout=300)
                if isinstance(out, Exception):
                    raise out
        counters = server.counters()
        server.stop()

    d_base = float(np.abs(baseline - oracle).max())
    d_final = float(np.abs(final - oracle).max())
    if d_base <= d_final:
        raise SystemExit(
            f"freshness loop did not move the live predictions toward "
            f"the oracle: baseline dist {d_base:.2e} <= final "
            f"{d_final:.2e}")
    if mon is not None:
        groups = counters["groups_served"]
        summ = mon.summary()
        if summ["syncs"] != groups or summ["compiles"] != 0:
            raise SystemExit(
                f"hot-path sanitizer: expected {groups} host syncs "
                f"(one per served group, consumer loop active) and 0 "
                f"recompiles; observed {summ['syncs']} syncs "
                f"({summ['d2h']} d2h, {summ['block']} block) and "
                f"{summ['compiles']} compile(s)")
        say(f"sanitizer: {summ['syncs']} syncs over {groups} served "
            "groups with the consumer loop active, 0 recompiles")

    metrics.update({
        "freshness_lag_s": res["lag_s"], "freshness_polls": res["polls"],
        "versions_published": publisher.last_version(),
        "updates_applied": counters["updates_applied"],
        "rows_refreshed": counters["rows_refreshed"],
        "etc_s_per_step": etc_s_per_step,
        "baseline_dist": d_base, "final_dist": d_final,
        "etc_evictions": ot.etc.evictions, "etc_pulls": ot.etc.pulls,
    })
    say(f"freshness: v{metrics['versions_published']} visible in live "
        f"predictions {res['lag_s'] * 1e3:.1f}ms after publish "
        f"({res['polls']} probes); baseline->oracle dist "
        f"{d_base:.2e} -> {d_final:.2e}; "
        f"{metrics['updates_applied']} update msgs applied, "
        f"{metrics['rows_refreshed']} L1 rows refreshed; ETC "
        f"{etc_s_per_step * 1e3:.1f}ms/step "
        f"({ot.etc.pulls} pulls, {ot.etc.evictions} evictions)")
    return metrics


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--base-steps", type=int, default=30)
    ap.add_argument("--online-steps", type=int, default=30)
    ap.add_argument("--passes", type=int, default=3)
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--ps", choices=("staged", "cached"),
                    default="staged")
    ap.add_argument("--sanitize", action="store_true",
                    help="fail unless the serving window holds the "
                    "hot-path invariants with the consumer loop active")
    a = ap.parse_args(argv)
    run_online(base_steps=a.base_steps, online_steps=a.online_steps,
               passes=a.passes, cache_rows=a.cache_rows,
               requests=a.requests, batch=a.batch, ps=a.ps,
               sanitize=a.sanitize)


if __name__ == "__main__":
    main()
