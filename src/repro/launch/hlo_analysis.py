"""Trip-count-aware HLO analyzer for the roofline report.

``compiled.cost_analysis()`` on XLA:CPU counts while-loop bodies ONCE
(verified empirically — a 7-iteration scan reports 1 iteration of FLOPs),
so this module re-derives the three roofline inputs by walking the
*partitioned* (per-device) HLO text:

  * FLOPs       — dots from contraction dims (2·K·|out|), elementwise ops
    at 1 flop/element, reduces at |input|; fusion bodies attributed once
    per call site.
  * HBM bytes   — fusion-boundary traffic: operands + result of every
    top-level instruction (inside-fusion values live in registers/VMEM,
    which is exactly the TPU memory model).
  * collective bytes — per collective kind: all-reduce/all-to-all/
    reduce-scatter/collective-permute count operand bytes, all-gather
    counts result bytes (the amount crossing links per device).

While loops multiply their body's tallies by the trip count parsed from
``backend_config known_trip_count`` (fallback: the s32 constant in the
loop condition; fallback: 1 with a warning flag).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "power", "select", "compare", "and", "or", "xor",
    "negate", "abs", "floor", "ceil", "sign", "sine", "cosine", "clamp",
    "atan2", "remainder", "round-nearest-afz", "round-nearest-even",
    "logistic", "cbrt", "erf", "not", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "is-finite",
}

_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
    "rng-get-and-update-state", "custom-call",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def type_bytes(t: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    t = t.strip()
    if t.startswith("("):
        return sum(type_bytes(p) for p in _split_tuple(t[1:-1]))
    if t.startswith("token"):
        return 0
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", t)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def type_elems(t: str) -> int:
    m = re.match(r"[a-z0-9]+\[([\d,]*)\]", t.strip())
    if not m:
        return 0
    n = 1
    for d in m.group(1).split(","):
        if d:
            n *= int(d)
    return n


def type_dims(t: str) -> List[int]:
    m = re.match(r"[a-z0-9]+\[([\d,]*)\]", t.strip())
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def _split_tuple(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for c in s:
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        if c == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(c)
    if cur:
        out.append("".join(cur))
    return [x for x in (p.strip() for p in out) if x]


@dataclasses.dataclass
class Instr:
    name: str
    type: str
    opcode: str
    operands: List[str]
    attrs: str
    args_raw: str = ""


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.mem_bytes += other.mem_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        self.unknown_trip_counts += other.unknown_trip_counts

    def as_dict(self):
        return {"flops": self.flops, "mem_bytes": self.mem_bytes,
                "coll_bytes": self.coll_bytes,
                "coll_by_kind": dict(self.coll_by_kind),
                "unknown_trip_counts": self.unknown_trip_counts}


class HloAnalyzer:

    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._fusion_called: set = set()
        self._parse(hlo_text)
        self._memo: Dict[str, Stats] = {}
        self._flops_memo: Dict[str, float] = {}

    # -- parsing --------------------------------------------------------------

    def _parse(self, text: str):
        cur_name, cur = None, []
        for line in text.splitlines():
            m = re.match(r"^(ENTRY )?%([\w.\-]+) .*\{", line)
            if m:
                cur_name = m.group(2)
                cur = []
                if m.group(1):
                    self.entry = cur_name
                continue
            if line.startswith("}"):
                if cur_name:
                    self.computations[cur_name] = cur
                cur_name = None
                continue
            if cur_name is None:
                continue
            ins = self._parse_instr(line)
            if ins is not None:
                cur.append(ins)
                if ins.opcode in ("fusion", "reduce", "sort", "map",
                                  "scatter", "reduce-window", "call",
                                  "select-and-scatter"):
                    for m2 in re.finditer(
                            r"(?:calls|to_apply)=%([\w.\-]+)", ins.attrs):
                        self._fusion_called.add(m2.group(1))

    def _parse_instr(self, line: str) -> Optional[Instr]:
        line = line.strip()
        m = re.match(r"^(?:ROOT )?%([\w.\-]+) = ", line)
        if not m:
            return None
        name = m.group(1)
        rhs = line[m.end():]
        # type: balanced tuple or single token
        if rhs.startswith("("):
            depth = 0
            i = 0
            for i, c in enumerate(rhs):
                depth += c == "("
                depth -= c == ")"
                if depth == 0:
                    break
            type_str, rest = rhs[:i + 1], rhs[i + 1:].strip()
        else:
            sp = rhs.find(" ")
            type_str, rest = rhs[:sp], rhs[sp + 1:].strip()
        m2 = re.match(r"([a-z][\w\-]*)\(", rest)
        if not m2:
            return None
        opcode = m2.group(1)
        # operands: balanced slice
        start = rest.find("(")
        depth, end = 0, start
        for j in range(start, len(rest)):
            depth += rest[j] == "("
            depth -= rest[j] == ")"
            if depth == 0:
                end = j
                break
        args = rest[start + 1:end]
        attrs = rest[end + 1:]
        operands = re.findall(r"%([\w.\-]+)", args)
        return Instr(name, type_str, opcode, operands, attrs, args)

    # -- analysis ---------------------------------------------------------------

    def analyze(self) -> Stats:
        return self._stats(self.entry)

    def _symtab(self, comp: str) -> Dict[str, str]:
        return {i.name: i.type for i in self.computations[comp]}

    def _flops_of(self, ins: Instr, symtab: Dict[str, str]) -> float:
        if ins.opcode == "dot":
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
            k = 1
            if m and ins.operands:
                lhs_dims = type_dims(symtab.get(ins.operands[0], ""))
                for d in (int(x) for x in m.group(1).split(",") if x):
                    if d < len(lhs_dims):
                        k *= lhs_dims[d]
            return 2.0 * k * type_elems(ins.type)
        if ins.opcode == "convolution":
            return 2.0 * type_elems(ins.type)  # underestimate; unused here
        if ins.opcode in _ELEMENTWISE:
            return float(type_elems(ins.type))
        if ins.opcode in ("reduce", "reduce-window"):
            return float(sum(type_elems(symtab.get(o, ""))
                             for o in ins.operands[:max(
                                 1, len(ins.operands) // 2)]))
        return 0.0

    def _flops_only(self, comp: str) -> float:
        if comp in self._flops_memo:
            return self._flops_memo[comp]
        total = 0.0
        symtab = self._symtab(comp)
        for ins in self.computations.get(comp, []):
            if ins.opcode == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if m:
                    total += self._flops_only(m.group(1))
            else:
                total += self._flops_of(ins, symtab)
        self._flops_memo[comp] = total
        return total

    def _trip_count(self, ins: Instr) -> Tuple[float, bool]:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.attrs)
        if m:
            return float(m.group(1)), True
        # fallback: s32 constant in the condition computation
        mc = re.search(r"condition=%([\w.\-]+)", ins.attrs)
        if mc and mc.group(1) in self.computations:
            consts = [int(x) for i2 in self.computations[mc.group(1)]
                      if i2.opcode == "constant"
                      for x in re.findall(r"^\s*(\d+)\s*$", i2.args_raw)]
            if consts:
                return float(max(consts)), True
        return 1.0, False

    def _stats(self, comp: str) -> Stats:
        if comp in self._memo:
            return self._memo[comp]
        st = Stats()
        symtab = self._symtab(comp)
        for ins in self.computations.get(comp, []):
            op = ins.opcode
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue  # counted at -start
                if base == "all-gather":
                    nb = type_bytes(ins.type)
                else:
                    nb = sum(type_bytes(symtab.get(o, ""))
                             for o in ins.operands)
                st.coll_bytes += nb
                st.coll_by_kind[base] = st.coll_by_kind.get(base, 0) + nb
                continue
            if op == "while":
                m = re.search(r"body=%([\w.\-]+)", ins.attrs)
                if m:
                    trip, known = self._trip_count(ins)
                    st.add(self._stats(m.group(1)), trip)
                    if not known:
                        st.unknown_trip_counts += 1
                continue
            if op == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%([\w.\-]+))", ins.attrs)
                names = []
                for a, b in branches:
                    if a:
                        names += re.findall(r"%([\w.\-]+)", a)
                    if b:
                        names.append(b)
                if names:
                    sub = [self._stats(n) for n in names if
                           n in self.computations]
                    if sub:
                        best = max(sub, key=lambda s: s.flops + s.mem_bytes)
                        st.add(best)
                continue
            if op == "call":
                m = re.search(r"to_apply=%([\w.\-]+)", ins.attrs)
                if m and m.group(1) in self.computations:
                    st.add(self._stats(m.group(1)))
                continue
            if op == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if m:
                    st.flops += self._flops_only(m.group(1))
                    st.mem_bytes += self._fusion_bytes(ins, m.group(1),
                                                       symtab)
                else:
                    st.mem_bytes += type_bytes(ins.type) + sum(
                        type_bytes(symtab.get(o, "")) for o in ins.operands)
                continue
            st.flops += self._flops_of(ins, symtab)
            if op not in _SKIP_BYTES:
                st.mem_bytes += self._instr_bytes(ins, symtab)
        self._memo[comp] = st
        return st

    # -- HBM-traffic models ------------------------------------------------------

    def _instr_bytes(self, ins: Instr, symtab: Dict[str, str]) -> float:
        """Traffic for a top-level instruction, aliasing-aware.

        Slice-like ops read/write only the slice, not the whole buffer;
        dynamic-update-slice aliases its target in place. Counting full
        operand buffers there inflates scan-heavy programs ~100x.
        """
        op = ins.opcode
        res = type_bytes(ins.type)
        if op in ("dynamic-slice", "slice", "gather", "pad", "broadcast",
                  "iota", "reverse", "copy", "transpose", "concatenate"):
            return 2.0 * res
        if op == "reshape":
            return 0.0
        if op == "dynamic-update-slice":
            upd = type_bytes(symtab.get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else res
            return 2.0 * upd
        if op == "scatter":
            upd = type_bytes(symtab.get(ins.operands[2], "")) \
                if len(ins.operands) > 2 else res
            return 2.0 * upd + res * 0  # read-modify-write of touched rows
        return res + sum(type_bytes(symtab.get(o, ""))
                         for o in ins.operands)

    def _param_index(self, called: str, pname: str) -> Optional[int]:
        for i2 in self.computations.get(called, []):
            if i2.name == pname and i2.opcode == "parameter":
                m = re.match(r"\s*(\d+)", i2.args_raw)
                if m:
                    return int(m.group(1))
        return None

    def _fusion_bytes(self, ins: Instr, called: str,
                      symtab: Dict[str, str]) -> float:
        """Fusion-boundary traffic with slice/DUS aliasing awareness.

        For each fusion parameter: if every use inside the fusion is a
        dynamic-slice/slice, only the slice results are read; if it is the
        in-place target of the root dynamic-update-slice, only the update
        region is written. Everything else counts at full size.
        """
        body = self.computations.get(called, [])
        if not body:
            return type_bytes(ins.type) + sum(
                type_bytes(symtab.get(o, "")) for o in ins.operands)
        symc = {i2.name: i2.type for i2 in body}
        root = body[-1]
        # uses of each parameter name
        uses: Dict[str, List[Instr]] = {}
        for i2 in body:
            for o in i2.operands:
                uses.setdefault(o, []).append(i2)
        # map param index -> param name
        pname_by_idx: Dict[int, str] = {}
        for i2 in body:
            if i2.opcode == "parameter":
                m = re.match(r"\s*(\d+)", i2.args_raw)
                if m:
                    pname_by_idx[int(m.group(1))] = i2.name
        dus_target = root.operands[0] \
            if root.opcode == "dynamic-update-slice" and root.operands \
            else None
        total = 0.0
        for idx, opnd in enumerate(ins.operands):
            pname = pname_by_idx.get(idx)
            full = type_bytes(symtab.get(opnd, ""))
            if pname is None:
                total += full
                continue
            if pname == dus_target:
                continue                      # aliased in place, not read
            puses = uses.get(pname, [])
            if puses and all(u.opcode in ("dynamic-slice", "slice")
                             for u in puses):
                total += sum(2.0 * type_bytes(u.type) for u in puses)
            else:
                total += full
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            total += 2.0 * type_bytes(symc.get(root.operands[1], ""))
        else:
            total += type_bytes(ins.type)
        return total


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link


def roofline_terms(stats: Stats) -> Dict[str, float]:
    """Seconds per step, per device (HLO is the per-device module)."""
    tc = stats.flops / PEAK_FLOPS
    tm = stats.mem_bytes / HBM_BW
    tn = stats.coll_bytes / LINK_BW
    dom = max((tc, "compute"), (tm, "memory"), (tn, "collective"))[1]
    return {"compute_s": tc, "memory_s": tm, "collective_s": tn,
            "dominant": dom,
            "step_s_lower_bound": max(tc, tm, tn)}


def analyze_text(hlo_text: str) -> Dict:
    a = HloAnalyzer(hlo_text)
    st = a.analyze()
    out = st.as_dict()
    out.update(roofline_terms(st))
    return out


# ---------------------------------------------------------------------------
# Hillclimb tooling: attribute the roofline terms to individual ops
# ---------------------------------------------------------------------------

def top_contributors(hlo_text: str, n: int = 25) -> Dict[str, list]:
    """Top-n (op, bytes/flops, trip-multiplied) per roofline term.

    Walks the entry with the same trip-count multipliers as analyze();
    returns {'memory': [...], 'collective': [...], 'flops': [...]} with
    entries (computation, op name, opcode, amount, multiplier).
    """
    a = HloAnalyzer(hlo_text)
    mem: list = []
    coll: list = []
    flops: list = []

    def walk(comp: str, mult: float):
        symtab = a._symtab(comp)
        for ins in a.computations.get(comp, []):
            op = ins.opcode
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                if base == "all-gather":
                    nb = type_bytes(ins.type)
                else:
                    nb = sum(type_bytes(symtab.get(o, ""))
                             for o in ins.operands)
                coll.append((comp, ins.name, base, nb * mult, mult,
                             ins.type))
                continue
            if op == "while":
                m = re.search(r"body=%([\w.\-]+)", ins.attrs)
                if m:
                    trip, _ = a._trip_count(ins)
                    walk(m.group(1), mult * trip)
                continue
            if op == "call":
                m = re.search(r"to_apply=%([\w.\-]+)", ins.attrs)
                if m and m.group(1) in a.computations:
                    walk(m.group(1), mult)
                continue
            if op == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if m:
                    fb = a._fusion_bytes(ins, m.group(1), symtab)
                    ff = a._flops_only(m.group(1))
                    mem.append((comp, ins.name, "fusion", fb * mult, mult,
                                ins.type))
                    if ff:
                        flops.append((comp, ins.name, "fusion", ff * mult,
                                      mult, ins.type))
                continue
            f = a._flops_of(ins, symtab)
            if f:
                flops.append((comp, ins.name, op, f * mult, mult, ins.type))
            if op not in _SKIP_BYTES:
                mem.append((comp, ins.name, op,
                            a._instr_bytes(ins, symtab) * mult, mult,
                            ins.type))

    walk(a.entry, 1.0)
    key = lambda t: -t[3]
    return {"memory": sorted(mem, key=key)[:n],
            "collective": sorted(coll, key=key)[:n],
            "flops": sorted(flops, key=key)[:n]}


def print_top(hlo_text: str, n: int = 20):
    top = top_contributors(hlo_text, n)
    for term in ("memory", "collective", "flops"):
        unit = "GiB" if term != "flops" else "GFLOP"
        div = 2 ** 30 if term != "flops" else 1e9
        print(f"--- top {term} ---")
        for comp, name, op, amt, mult, ty in top[term]:
            print(f"  {amt / div:10.2f} {unit}  x{mult:<6.0f} {op:<12} "
                  f"{ty[:44]:<44} {name[:48]} [{comp[:40]}]")
