"""Mesh construction for single-pod and multi-pod deployments.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required so smoke tests see a
single CPU device while the dry-run process sees 512 placeholder devices.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro import compat
from repro.compat import AxisType
from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(cfg: MeshConfig) -> Mesh:
    return compat.make_mesh(
        cfg.shape, cfg.axes, axis_types=(AxisType.Auto,) * len(cfg.axes)
    )


def make_test_mesh(shape: Sequence[int] = (1, 1),
                   axes: Sequence[str] = ("data", "model")) -> Mesh:
    """A mesh sized for whatever devices exist (CPU tests)."""
    return compat.make_mesh(
        tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes)
    )


def make_cache_mesh(stripes: int, *, axis: str = "cache") -> Mesh:
    """1-D mesh for the striped HPS L1 payload: as many devices as can
    tile ``stripes`` evenly (so stripe ``i`` lands on device
    ``i * size / stripes``), degrading to a 1-device mesh when the
    stripe count and the device count don't divide."""
    import numpy as np

    n_dev = len(jax.devices())
    size = min(stripes, n_dev)
    while size > 1 and stripes % size:
        size -= 1
    return Mesh(np.asarray(jax.devices()[:size]), (axis,))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def mesh_config_for(mesh: Mesh) -> MeshConfig:
    return MeshConfig(tuple(mesh.devices.shape), tuple(mesh.axis_names))
