"""Portable model export (paper §2: the HugeCTR→ONNX converter).

No ONNX runtime is available offline, so the converter targets the same
*goal* — a self-describing, framework-neutral artifact another stack can
load without this codebase: a directory with

    graph.json    — node list (op, inputs, attrs) + model/table metadata
    weights.npz   — all parameters by stable name (embedding tables in
                    LOGICAL layout: mesh-size independent)

``export_recsys`` writes it; ``load_exported`` + ``run_exported`` execute
the graph with nothing but numpy — the cross-framework check the ONNX
converter provides (and our tests assert parity with the JAX forward).

Emission is a WALK of the model's compiled :class:`DenseGraphProgram`
(``models/recsys/dense_graph.py``): there is no per-architecture code
here, so any graph the compiler accepts — the four canonical recipes and
novel layer DAGs alike — exports and replays under the numpy executor.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

OPSET = {"gather_sum", "concat", "relu", "linear", "dot_interaction",
         "cross", "sigmoid", "fm_second_order", "add", "reduce_sum",
         "ewise_add", "ewise_mul", "slice"}


def _subtree(params: Dict, path) -> Dict:
    """The param sub-tree a program node's path points at."""
    p = params
    for k in path:
        p = p[k]
    return p


def _param(params: Dict, path) -> np.ndarray:
    return np.asarray(_subtree(params, path))


def _emit_mlp(node, params, weights, nodes):
    """One program mlp node -> (optional concat +) a linear chain."""
    prefix = "/".join(node.params["p"])
    pdict = _subtree(params, node.params["p"])
    inp = node.inputs[0]
    if len(node.inputs) > 1:
        nodes.append({"op": "concat", "inputs": list(node.inputs),
                      "output": f"{node.output}__in", "attrs": {}})
        inp = f"{node.output}__in"
    n = len(pdict) // 2
    cur = inp
    final = node.attrs["final_activation"]
    for i in range(n):
        weights[f"{prefix}/w{i}"] = np.asarray(pdict[f"w{i}"])
        weights[f"{prefix}/b{i}"] = np.asarray(pdict[f"b{i}"])
        dst = node.output if i == n - 1 else f"{prefix}_h{i}"
        nodes.append({"op": "linear", "inputs": [cur], "output": dst,
                      "attrs": {"w": f"{prefix}/w{i}",
                                "b": f"{prefix}/b{i}",
                                "relu": i < n - 1 or final}})
        cur = dst


def _emit_first_order(out, dense_in, wide_in, w_name, b_name, w, b,
                      weights, nodes):
    """wide.sum + dense @ w + b as portable reduce_sum/linear/add."""
    weights[w_name] = np.asarray(w)[:, None]
    weights[b_name] = np.asarray(b)[None]
    nodes.append({"op": "reduce_sum", "inputs": [wide_in],
                  "output": f"{out}__ws", "attrs": {}})
    nodes.append({"op": "linear", "inputs": [dense_in],
                  "output": f"{out}__lin",
                  "attrs": {"w": w_name, "b": b_name, "relu": False}})
    return [f"{out}__ws", f"{out}__lin"]


def export_recsys(model, params: Dict, directory: str,
                  model_name: str = "model") -> str:
    """Serialize a RecsysModel + trained params to the portable format
    by walking its compiled dense program."""
    from repro.models.recsys.model import logical_tables

    os.makedirs(directory, exist_ok=True)
    cfg = model.cfg
    program = model.program
    weights: Dict[str, np.ndarray] = {}
    nodes: List[Dict] = []

    # -- embeddings: logical (unpadded, de-striped) per-table arrays -------
    emb_out = program.inputs["emb"]
    for name, full in logical_tables(model.embedding,
                                     params["embedding"]).items():
        weights[f"table/{name}"] = full
    nodes.append({"op": "gather_sum", "inputs": ["cat"],
                  "output": emb_out,
                  "attrs": {"tables": [t.name for t in cfg.tables],
                            "combiners": [t.combiner
                                          for t in cfg.tables]}})
    wide_table_names: List[str] = []
    if model.wide is not None:
        for name, full in logical_tables(
                model.wide, params["wide_embedding"]).items():
            weights[f"table/{name}"] = full
            wide_table_names.append(name)
        nodes.append({"op": "gather_sum", "inputs": ["cat"],
                      "output": program.inputs["wide"] or "wide",
                      "attrs": {"tables": wide_table_names,
                                "combiners": ["sum"] * len(
                                    wide_table_names)}})
    # N-group models: one gather per extra group, reading its own cat
    # column span (col_start; absent/0 on legacy single-group graphs)
    cols = model.group_columns()
    for gname, coll in model.extra.items():
        key = f"embedding@{gname}"
        for name, full in logical_tables(coll, params[key]).items():
            weights[f"table/{name}"] = full
        nodes.append({"op": "gather_sum", "inputs": ["cat"],
                      "output": gname,
                      "attrs": {"tables": [t.name for t in coll.tables],
                                "combiners": [t.combiner
                                              for t in coll.tables],
                                "col_start": cols[key][0]}})

    # -- dense graph: one walk of the compiled program ---------------------
    for node in program.nodes:
        if node.op == "mlp":
            _emit_mlp(node, params, weights, nodes)
        elif node.op == "cross":
            prefix = "/".join(node.params["p"])
            p = _subtree(params, node.params["p"])
            n_cross = len(p) // 2
            for i in range(n_cross):
                weights[f"{prefix}/w{i}"] = np.asarray(p[f"w{i}"])
                weights[f"{prefix}/b{i}"] = np.asarray(p[f"b{i}"])
            nodes.append({"op": "cross", "inputs": [node.inputs[0]],
                          "output": node.output,
                          "attrs": {"layers": n_cross,
                                    "prefix": prefix}})
        elif node.op == "dot_interaction":
            nodes.append({"op": "dot_interaction",
                          "inputs": list(node.inputs),
                          "output": node.output, "attrs": {}})
        elif node.op == "concat":
            nodes.append({"op": "concat", "inputs": list(node.inputs),
                          "output": node.output, "attrs": {}})
        elif node.op == "first_order":
            terms = _emit_first_order(
                node.output, node.inputs[0], node.inputs[1],
                "/".join(node.params["w"]), "/".join(node.params["b"]),
                _param(params, node.params["w"]),
                _param(params, node.params["b"]), weights, nodes)
            nodes.append({"op": "add", "inputs": terms,
                          "output": node.output, "attrs": {}})
        elif node.op == "fm_second":
            nodes.append({"op": "fm_second_order",
                          "inputs": [node.inputs[0]],
                          "output": node.output, "attrs": {}})
        elif node.op == "fm":
            p = _subtree(params, node.params["p"])
            prefix = "/".join(node.params["p"])
            terms = _emit_first_order(
                node.output, node.inputs[0], node.inputs[1],
                f"{prefix}/w", f"{prefix}/b", p["w"], p["b"],
                weights, nodes)
            nodes.append({"op": "fm_second_order",
                          "inputs": [node.inputs[2]],
                          "output": f"{node.output}__fm2", "attrs": {}})
            nodes.append({"op": "add",
                          "inputs": terms + [f"{node.output}__fm2"],
                          "output": node.output, "attrs": {}})
        elif node.op == "add":
            nodes.append({"op": "ewise_add", "inputs": list(node.inputs),
                          "output": node.output, "attrs": {}})
        elif node.op == "multiply":
            nodes.append({"op": "ewise_mul", "inputs": list(node.inputs),
                          "output": node.output, "attrs": {}})
        elif node.op == "relu":
            nodes.append({"op": "relu", "inputs": [node.inputs[0]],
                          "output": node.output, "attrs": {}})
        elif node.op == "slice":
            nodes.append({"op": "slice", "inputs": [node.inputs[0]],
                          "output": node.output,
                          "attrs": {"start": node.attrs["start"],
                                    "stop": node.attrs["stop"]}})
        elif node.op == "reduce_sum":
            nodes.append({"op": "reduce_sum", "inputs": [node.inputs[0]],
                          "output": node.output, "attrs": {}})
        else:                                # pragma: no cover
            raise NotImplementedError(f"export for op {node.op}")

    # -- terminal: sum the logit bottoms, then the probability -------------
    if len(program.logit_bottoms) == 1:
        logit_name = program.logit_bottoms[0]
    else:
        logit_name = "logit" if "logit" not in program.shapes \
            else "__logit"
        nodes.append({"op": "add", "inputs": list(program.logit_bottoms),
                      "output": logit_name, "attrs": {}})
    nodes.append({"op": "sigmoid", "inputs": [logit_name],
                  "output": "prob", "attrs": {}})

    from repro.configs.base import recsys_config_hash
    from repro.models.recsys.model import wide_tables
    all_tables = cfg.tables + (wide_tables(cfg)
                               if model.wide is not None else ())
    for g in getattr(cfg, "extra_groups", ()):
        all_tables = all_tables + tuple(g.tables)
    graph = {
        "format": "repro-portable-v1",
        "model": model_name,
        "kind": cfg.model,
        "config_hash": recsys_config_hash(cfg),
        "num_dense_features": cfg.num_dense_features,
        "embedding_dim": cfg.embedding_dim,
        "dense_input": program.inputs["dense"],
        "tables": [{"name": t.name, "vocab": t.vocab_size,
                    "dim": t.dim, "hotness": t.hotness,
                    "combiner": t.combiner} for t in all_tables],
        "nodes": nodes,
    }
    with open(os.path.join(directory, "graph.json"), "w") as f:
        json.dump(graph, f, indent=1)
    np.savez(os.path.join(directory, "weights.npz"), **weights)
    return directory


def load_exported(directory: str):
    with open(os.path.join(directory, "graph.json")) as f:
        graph = json.load(f)
    data = np.load(os.path.join(directory, "weights.npz"))
    weights = {k: data[k] for k in data.files}
    return graph, weights


def run_exported(graph: Dict, weights: Dict[str, np.ndarray],
                 batch: Dict[str, np.ndarray]) -> np.ndarray:
    """Pure-numpy executor — the cross-framework parity check."""
    env: Dict[str, np.ndarray] = {
        graph.get("dense_input", "dense"):
            np.asarray(batch["dense"], np.float32)}
    cat = np.asarray(batch["cat"])

    def _col(x: np.ndarray) -> np.ndarray:
        """Any logit-shaped tensor -> [B] (flattens a trailing 1-dim)."""
        return x.reshape(len(cat), -1).sum(axis=1)

    def _2d(x: np.ndarray) -> np.ndarray:
        """Any tensor -> [B, n] (3-D embedding blocks flatten)."""
        return x.reshape(x.shape[0], -1)

    for node in graph["nodes"]:
        op, out = node["op"], node["output"]
        a = node["attrs"]
        if op == "gather_sum":
            combiners = a.get("combiners") or [
                graph["tables"][ti]["combiner"]
                for ti in range(len(a["tables"]))]
            outs = []
            col0 = a.get("col_start", 0)
            for ti, tname in enumerate(a["tables"]):
                tab = weights[f"table/{tname}"]
                ids = cat[:, col0 + ti, :]
                valid = ids >= 0
                rows = tab[np.clip(ids, 0, None)]
                rows = rows * valid[..., None]
                pooled = rows.sum(axis=1)
                if combiners[ti] == "mean":
                    pooled = pooled / np.maximum(
                        valid.sum(1, keepdims=True), 1)
                outs.append(pooled)
            env[out] = np.stack(outs, axis=1)
            env[f"{out}_flat"] = env[out].reshape(len(cat), -1)
        elif op == "linear":
            x = _2d(env[node["inputs"][0]])
            h = x @ weights[a["w"]] + weights[a["b"]]
            env[out] = np.maximum(h, 0) if a["relu"] else h
        elif op == "concat":
            env[out] = np.concatenate(
                [_2d(env[i]) for i in node["inputs"]], axis=1)
        elif op == "dot_interaction":
            bot, emb = env[node["inputs"][0]], env[node["inputs"][1]]
            feats = np.concatenate([bot[:, None, :], emb], axis=1)
            gram = np.einsum("bfd,bgd->bfg", feats, feats)
            i, j = np.tril_indices(feats.shape[1], -1)
            env[out] = gram[:, i, j]
        elif op == "cross":
            prefix = a.get("prefix", "cross")
            x0 = env[node["inputs"][0]]
            x = x0
            for i in range(a["layers"]):
                xw = x @ weights[f"{prefix}/w{i}"]
                x = x0 * xw[:, None] + weights[f"{prefix}/b{i}"] + x
            env[out] = x
        elif op == "reduce_sum":
            env[out] = _col(env[node["inputs"][0]])
        elif op == "fm_second_order":
            e = env[node["inputs"][0]]       # [B, T, D]
            s = e.sum(axis=1)
            sq = (e * e).sum(axis=1)
            env[out] = (0.5 * (s * s - sq)).sum(axis=1)
        elif op == "add":
            env[out] = np.sum([_col(env[i]) for i in node["inputs"]],
                              axis=0)
        elif op == "ewise_add":
            acc = env[node["inputs"][0]]
            for i in node["inputs"][1:]:
                acc = acc + env[i]
            env[out] = acc
        elif op == "ewise_mul":
            acc = env[node["inputs"][0]]
            for i in node["inputs"][1:]:
                acc = acc * env[i]
            env[out] = acc
        elif op == "relu":
            env[out] = np.maximum(env[node["inputs"][0]], 0)
        elif op == "slice":
            env[out] = _2d(env[node["inputs"][0]])[:,
                                                   a["start"]:a["stop"]]
        elif op == "sigmoid":
            env[out] = 1.0 / (1.0 + np.exp(-env[node["inputs"][0]]))
        else:
            raise ValueError(f"unknown op {op}")
    return env["prob"][:, 0] if env["prob"].ndim == 2 else env["prob"]
