"""Portable model export (paper §2: the HugeCTR→ONNX converter).

No ONNX runtime is available offline, so the converter targets the same
*goal* — a self-describing, framework-neutral artifact another stack can
load without this codebase: a directory with

    graph.json    — node list (op, inputs, attrs) + model/table metadata
    weights.npz   — all parameters by stable name (embedding tables in
                    LOGICAL layout: mesh-size independent)

``export_recsys`` writes it; ``load_exported`` + ``run_exported`` execute
the graph with nothing but numpy — the cross-framework check the ONNX
converter provides (and our tests assert parity with the JAX forward).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

OPSET = {"gather_sum", "concat", "relu", "linear", "dot_interaction",
         "cross", "sigmoid", "fm_second_order", "add", "reduce_sum"}


def export_recsys(model, params: Dict, directory: str,
                  model_name: str = "model") -> str:
    """Serialize a RecsysModel + trained params to the portable format."""
    from repro.models.recsys.model import logical_tables

    os.makedirs(directory, exist_ok=True)
    cfg = model.cfg
    weights: Dict[str, np.ndarray] = {}
    nodes: List[Dict] = []

    # -- embeddings: logical (unpadded, de-striped) per-table arrays -------
    for name, full in logical_tables(model.embedding,
                                     params["embedding"]).items():
        weights[f"table/{name}"] = full
    nodes.append({"op": "gather_sum", "inputs": ["cat"],
                  "output": "emb",
                  "attrs": {"tables": [t.name for t in cfg.tables],
                            "combiners": [t.combiner
                                          for t in cfg.tables]}})
    wide_table_names: List[str] = []
    if model.wide is not None:
        for name, full in logical_tables(
                model.wide, params["wide_embedding"]).items():
            weights[f"table/{name}"] = full
            wide_table_names.append(name)
        nodes.append({"op": "gather_sum", "inputs": ["cat"],
                      "output": "wide",
                      "attrs": {"tables": wide_table_names,
                                "combiners": ["sum"] * len(
                                    wide_table_names)}})

    # -- dense graph per model type ----------------------------------------
    def mlp(prefix, pdict, inp, out, final_relu=False):
        n = len(pdict) // 2
        cur = inp
        for i in range(n):
            weights[f"{prefix}/w{i}"] = np.asarray(pdict[f"w{i}"])
            weights[f"{prefix}/b{i}"] = np.asarray(pdict[f"b{i}"])
            dst = out if i == n - 1 else f"{prefix}_h{i}"
            nodes.append({"op": "linear", "inputs": [cur],
                          "output": dst,
                          "attrs": {"w": f"{prefix}/w{i}",
                                    "b": f"{prefix}/b{i}",
                                    "relu": i < n - 1 or final_relu}})
            cur = dst

    if cfg.model == "dlrm":
        mlp("bottom", params["bottom"], "dense", "bot", final_relu=True)
        nodes.append({"op": "dot_interaction", "inputs": ["bot", "emb"],
                      "output": "tri", "attrs": {}})
        nodes.append({"op": "concat", "inputs": ["bot", "tri"],
                      "output": "top_in", "attrs": {}})
        mlp("top", params["top"], "top_in", "logit")
    elif cfg.model == "dcn":
        nodes.append({"op": "concat", "inputs": ["dense", "emb_flat"],
                      "output": "flat", "attrs": {}})
        n_cross = len(params["cross"]) // 2
        for i in range(n_cross):
            weights[f"cross/w{i}"] = np.asarray(params["cross"][f"w{i}"])
            weights[f"cross/b{i}"] = np.asarray(params["cross"][f"b{i}"])
        nodes.append({"op": "cross", "inputs": ["flat"],
                      "output": "crossed",
                      "attrs": {"layers": n_cross}})
        mlp("deep", params["deep"], "flat", "deep_out")
        nodes.append({"op": "concat", "inputs": ["crossed", "deep_out"],
                      "output": "both", "attrs": {}})
        mlp("combine", params["combine"], "both", "logit")
    elif cfg.model in ("deepfm", "wdl"):
        # shared first-order term: sum(wide rows) + dense @ w + bias
        weights["dense_w"] = np.asarray(params["dense_w"])[:, None]
        weights["bias"] = np.asarray(params["bias"])[None]
        nodes.append({"op": "reduce_sum", "inputs": ["wide"],
                      "output": "wide_sum", "attrs": {}})
        nodes.append({"op": "linear", "inputs": ["dense"],
                      "output": "dense_lin",
                      "attrs": {"w": "dense_w", "b": "bias",
                                "relu": False}})
        nodes.append({"op": "concat", "inputs": ["dense", "emb_flat"],
                      "output": "flat", "attrs": {}})
        mlp("deep", params["deep"], "flat", "deep_out")
        logit_terms = ["wide_sum", "dense_lin", "deep_out"]
        if cfg.model == "deepfm":
            nodes.append({"op": "fm_second_order", "inputs": ["emb"],
                          "output": "fm2", "attrs": {}})
            logit_terms.insert(2, "fm2")
        nodes.append({"op": "add", "inputs": logit_terms,
                      "output": "logit", "attrs": {}})
    else:
        raise NotImplementedError(f"export for {cfg.model}")
    nodes.append({"op": "sigmoid", "inputs": ["logit"],
                  "output": "prob", "attrs": {}})

    from repro.configs.base import recsys_config_hash
    from repro.models.recsys.model import wide_tables
    all_tables = cfg.tables + (wide_tables(cfg)
                               if model.wide is not None else ())
    graph = {
        "format": "repro-portable-v1",
        "model": model_name,
        "kind": cfg.model,
        "config_hash": recsys_config_hash(cfg),
        "num_dense_features": cfg.num_dense_features,
        "embedding_dim": cfg.embedding_dim,
        "tables": [{"name": t.name, "vocab": t.vocab_size,
                    "dim": t.dim, "hotness": t.hotness,
                    "combiner": t.combiner} for t in all_tables],
        "nodes": nodes,
    }
    with open(os.path.join(directory, "graph.json"), "w") as f:
        json.dump(graph, f, indent=1)
    np.savez(os.path.join(directory, "weights.npz"), **weights)
    return directory


def load_exported(directory: str):
    with open(os.path.join(directory, "graph.json")) as f:
        graph = json.load(f)
    data = np.load(os.path.join(directory, "weights.npz"))
    weights = {k: data[k] for k in data.files}
    return graph, weights


def run_exported(graph: Dict, weights: Dict[str, np.ndarray],
                 batch: Dict[str, np.ndarray]) -> np.ndarray:
    """Pure-numpy executor — the cross-framework parity check."""
    env: Dict[str, np.ndarray] = {
        "dense": np.asarray(batch["dense"], np.float32)}
    cat = np.asarray(batch["cat"])

    def _col(x: np.ndarray) -> np.ndarray:
        """Any logit-shaped tensor -> [B] (flattens a trailing 1-dim)."""
        return x.reshape(len(cat), -1).sum(axis=1)

    for node in graph["nodes"]:
        op, out = node["op"], node["output"]
        a = node["attrs"]
        if op == "gather_sum":
            combiners = a.get("combiners") or [
                graph["tables"][ti]["combiner"]
                for ti in range(len(a["tables"]))]
            outs = []
            for ti, tname in enumerate(a["tables"]):
                tab = weights[f"table/{tname}"]
                ids = cat[:, ti, :]
                valid = ids >= 0
                rows = tab[np.clip(ids, 0, None)]
                rows = rows * valid[..., None]
                pooled = rows.sum(axis=1)
                if combiners[ti] == "mean":
                    pooled = pooled / np.maximum(
                        valid.sum(1, keepdims=True), 1)
                outs.append(pooled)
            env[out] = np.stack(outs, axis=1)
            env[f"{out}_flat"] = env[out].reshape(len(cat), -1)
        elif op == "linear":
            x = env[node["inputs"][0]]
            h = x @ weights[a["w"]] + weights[a["b"]]
            env[out] = np.maximum(h, 0) if a["relu"] else h
        elif op == "concat":
            env[out] = np.concatenate(
                [env[i] for i in node["inputs"]], axis=1)
        elif op == "dot_interaction":
            bot, emb = env[node["inputs"][0]], env[node["inputs"][1]]
            feats = np.concatenate([bot[:, None, :], emb], axis=1)
            gram = np.einsum("bfd,bgd->bfg", feats, feats)
            i, j = np.tril_indices(feats.shape[1], -1)
            env[out] = gram[:, i, j]
        elif op == "cross":
            x0 = env[node["inputs"][0]]
            x = x0
            for i in range(a["layers"]):
                xw = x @ weights[f"cross/w{i}"]
                x = x0 * xw[:, None] + weights[f"cross/b{i}"] + x
            env[out] = x
        elif op == "reduce_sum":
            env[out] = _col(env[node["inputs"][0]])
        elif op == "fm_second_order":
            e = env[node["inputs"][0]]       # [B, T, D]
            s = e.sum(axis=1)
            sq = (e * e).sum(axis=1)
            env[out] = (0.5 * (s * s - sq)).sum(axis=1)
        elif op == "add":
            env[out] = np.sum([_col(env[i]) for i in node["inputs"]],
                              axis=0)
        elif op == "sigmoid":
            env[out] = 1.0 / (1.0 + np.exp(-env[node["inputs"][0]]))
        else:
            raise ValueError(f"unknown op {op}")
    return env["prob"][:, 0] if env["prob"].ndim == 2 else env["prob"]
