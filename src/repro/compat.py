"""JAX version compatibility shims.

Compat policy (see ROADMAP.md): the repo targets the *installed* JAX first
and newer APIs opportunistically. Anything that moved between JAX 0.4.x
and 0.5+/0.6+ goes through this module — call sites never feature-test
``jax`` themselves:

* ``shard_map``    — ``jax.shard_map`` (new) vs
                     ``jax.experimental.shard_map.shard_map`` (0.4.x).
                     The new ``check_vma`` kwarg maps onto the old
                     ``check_rep``.
* ``AxisType``     — ``jax.sharding.AxisType`` is absent before 0.5;
                     a placeholder enum keeps annotations importable.
* ``make_mesh``    — the ``axis_types=`` kwarg is absent before 0.5;
                     dropped when unsupported (all axes default to Auto,
                     which is what every call site passes anyway).
* ``shard_map_mesh`` — JAX >= 0.5 wants an ``AbstractMesh`` when a
                     ``shard_map`` is staged under ``jit`` (a concrete
                     Mesh bakes device ids into the jaxpr and is
                     deprecated there); 0.4.x has no AbstractMesh and
                     takes the concrete Mesh. Call sites that build a
                     shard_map inside a jitted function route the mesh
                     through this helper.
"""
from __future__ import annotations

import inspect
from typing import Callable, Optional, Sequence

import jax
from jax.sharding import Mesh

__all__ = ["AxisType", "HAS_AXIS_TYPE", "make_mesh", "shard_map",
           "shard_map_mesh"]


# -- AxisType ----------------------------------------------------------------

try:  # JAX >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPE = True
except ImportError:  # JAX 0.4.x: everything is implicitly Auto
    class AxisType:  # type: ignore[no-redef]
        """Placeholder for ``jax.sharding.AxisType`` on old JAX."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    HAS_AXIS_TYPE = False


# -- make_mesh ---------------------------------------------------------------

if hasattr(jax, "make_mesh"):
    _MAKE_MESH_AXIS_TYPES = (
        "axis_types" in inspect.signature(jax.make_mesh).parameters)

    def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
                  axis_types: Optional[Sequence] = None) -> Mesh:
        if _MAKE_MESH_AXIS_TYPES and axis_types is not None:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=tuple(axis_types))
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
else:  # very old JAX: assemble the Mesh by hand
    from jax.experimental import mesh_utils

    def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
                  axis_types: Optional[Sequence] = None) -> Mesh:
        devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
        return Mesh(devices, tuple(axis_names))


# -- shard_map ---------------------------------------------------------------

if hasattr(jax, "shard_map"):  # JAX >= 0.6
    def shard_map(f: Callable, *, mesh: Mesh, in_specs, out_specs,
                  check_vma: bool = True) -> Callable:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # JAX 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f: Callable, *, mesh: Mesh, in_specs, out_specs,
                  check_vma: bool = True) -> Callable:
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)


# -- shard_map_mesh ----------------------------------------------------------

def shard_map_mesh(mesh: Mesh):
    """The mesh object to hand ``shard_map``: on JAX >= 0.6, staging a
    concrete ``Mesh`` under ``jit`` is deprecated (it bakes device ids
    into the jaxpr), so return the ``AbstractMesh`` while tracing; on
    0.4.x (no ``jax.shard_map``, no AbstractMesh support) and for eager
    calls, the concrete ``Mesh`` is both required and sufficient."""
    if hasattr(jax, "shard_map"):
        try:
            tracing = not jax.core.trace_state_clean()
        except AttributeError:  # jax.core reshuffles across versions
            tracing = False
        if tracing:
            return getattr(mesh, "abstract_mesh", mesh)
    return mesh
