"""The paper's model zoo: DLRM, DCN, DeepFM, Wide&Deep.

One functional ``RecsysModel`` facade owns:
  * the sparse part — an :class:`EmbeddingCollection` (the paper's MP
    embedding engine), plus a dim-1 "wide" collection for WDL/DeepFM
    first-order terms, and
  * the dense part — model-specific MLP/cross/interaction layers, which are
    replicated (DP) exactly as the paper prescribes.

``apply(params, batch)`` returns logits ``[B]``; ``loss_fn`` adds BCE.
batch = {"dense": [B, Nd] f32, "cat": [B, T, H] int32 (-1 pad), "label": [B]}
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RecsysConfig, EmbeddingTableConfig
from repro.core.embedding import EmbeddingCollection, resolve_strategies
from repro.launch.mesh import mesh_config_for
from repro.models.recsys import dense_graph, layers
from repro.kernels import ops as kops


def wide_tables(cfg: RecsysConfig):
    """The dim-1 first-order ("wide") twin of every table — WDL/DeepFM
    derive their wide branch from the deep tables, so the serving side
    (object- or config-driven deploy) can reconstruct it from the
    RecsysConfig alone."""
    return tuple(
        dataclasses.replace(t, name=f"{t.name}_wide", dim=1,
                            strategy="data_parallel")
        for t in cfg.tables)


_wide_tables = wide_tables  # legacy alias


def export_logical_params(model, params: Dict) -> Dict:
    """Param tree with embedding groups in LOGICAL (mesh-independent)
    layout — the checkpoint format shared by Trainer and api.Model."""
    out = dict(params)
    for key, coll in model.collections().items():
        if key in out:
            out[key] = coll.export_logical(out[key])
    return out


def import_logical_params(model, params: Dict) -> Dict:
    """Inverse of :func:`export_logical_params` for ``model``'s mesh."""
    out = dict(params)
    for key, coll in model.collections().items():
        if key in out:
            out[key] = coll.import_logical(out[key])
    return out


def logical_tables(collection, emb_params) -> Dict[str, np.ndarray]:
    """Per-table LOGICAL weights (unpadded, de-striped, hot+cold merged)
    keyed by table name — the export shape the PDB and the portable
    converter both consume."""
    logical = collection.export_logical(emb_params)
    out: Dict[str, np.ndarray] = {}
    for gname, group in collection.groups.items():
        if gname == "cold":
            continue               # merged into "hot" below
        for i, (t, off) in enumerate(zip(group.tables, group.offsets)):
            end = group.offsets[i + 1] if i + 1 < group.num_tables \
                else group.total_rows
            if gname == "hot":
                cg = collection.groups["cold"]
                coff = cg.offsets[i]
                cend = cg.offsets[i + 1] if i + 1 < cg.num_tables \
                    else cg.total_rows
                full = np.concatenate(
                    [np.asarray(logical["hot"])[off:end],
                     np.asarray(logical["cold"])[coff:cend]], axis=0)
            elif gname == "loc":
                full = np.asarray(logical["loc"][i])[:t.vocab_size]
            else:
                full = np.asarray(logical[gname])[off:end]
            out[t.name] = full
    return out


def import_logical_tables(collection, emb_params,
                          tables: Dict[str, np.ndarray]) -> Dict:
    """Inverse of :func:`logical_tables`: write per-table FULL weight
    arrays back into the collection's logical layout and import for this
    mesh. ``emb_params`` supplies the layout template (and the values of
    any table absent from ``tables``) — the ETC trainer uses this to
    fold parameter-server contents back into a servable param tree."""
    logical = {}
    for k, v in collection.export_logical(emb_params).items():
        if isinstance(v, list):
            logical[k] = [np.array(x) for x in v]
        else:
            logical[k] = np.array(v)
    for gname, group in collection.groups.items():
        if gname == "cold":
            continue               # written through "hot" below
        for i, (t, off) in enumerate(zip(group.tables, group.offsets)):
            if t.name not in tables:
                continue
            full = np.asarray(tables[t.name], np.float32)
            if full.shape != (t.vocab_size, t.dim):
                raise ValueError(
                    f"table {t.name}: got {full.shape}, want "
                    f"({t.vocab_size}, {t.dim})")
            end = group.offsets[i + 1] if i + 1 < group.num_tables \
                else group.total_rows
            if gname == "hot":
                cg = collection.groups["cold"]
                coff = cg.offsets[i]
                cend = cg.offsets[i + 1] if i + 1 < cg.num_tables \
                    else cg.total_rows
                nhot = end - off
                logical["hot"][off:end] = full[:nhot]
                logical["cold"][coff:cend] = full[nhot:]
            elif gname == "loc":
                logical["loc"][i][:t.vocab_size] = full
            else:
                logical[gname][off:end] = full
    return collection.import_logical(
        {k: ([jnp.asarray(x) for x in v] if isinstance(v, list)
             else jnp.asarray(v)) for k, v in logical.items()})


class RecsysModel:

    def __init__(self, cfg: RecsysConfig, mesh: Mesh, *,
                 global_batch: int,
                 comm: str = "allgather_rs",
                 a2a_threshold: int = 65536,
                 embed_shard_axes: str = "all",
                 use_kernels: bool = False,
                 dense_executor: str = "graph"):
        self.cfg = cfg
        self.mesh = mesh
        if cfg.model == "dlrm" and cfg.bottom_mlp[-1] != cfg.embedding_dim:
            raise ValueError(
                "DLRM needs bottom_mlp[-1] == embedding_dim for the "
                f"interaction, got {cfg.bottom_mlp[-1]} != "
                f"{cfg.embedding_dim}")
        if dense_executor not in ("graph", "reference"):
            raise ValueError(
                f"dense_executor must be 'graph' (the compiled program) "
                f"or 'reference' (the fixed pipeline), got "
                f"{dense_executor!r}")
        if dense_executor == "reference" and cfg.model == "graph":
            raise ValueError(
                "the reference executor only covers the four canonical "
                "recipes; model='graph' always runs the compiled program")
        mesh_cfg = mesh_config_for(mesh)
        tables = resolve_strategies(cfg.tables, mesh_cfg, global_batch)
        cd = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
        pool = kops.kernel_pool if use_kernels else None

        def pick_comm(group_tables):
            # "auto" resolves PER COLLECTION: each independently-
            # dimensioned group gets the comm pattern its table sizes
            # want (hybrid recipe — all_to_all only for large one-hot).
            if comm != "auto":
                return comm
            from repro.core.embedding.planner import choose_comm
            return choose_comm(group_tables, threshold=a2a_threshold)

        self.embedding = EmbeddingCollection(
            tables, mesh, comm=pick_comm(tables), compute_dtype=cd,
            shard_axes=embed_shard_axes, pool_fn=pool)
        self.compute_dtype = cd
        self.use_kernels = use_kernels
        self.dense_executor = dense_executor
        #: the compiled dense program — ONE executor for every model
        #: kind: canonical recipes bind their historical params,
        #: model="graph" compiles the embedded DAG
        self.program = dense_graph.program_for(cfg,
                                               use_kernels=use_kernels)
        self.wide: Optional[EmbeddingCollection] = None
        if cfg.model in ("wdl", "deepfm") or \
                (cfg.model == "graph" and cfg.wide_branch):
            wt = wide_tables(cfg)
            self.wide = EmbeddingCollection(wt, mesh, comm=pick_comm(wt),
                                            compute_dtype=cd)
        #: extra N-group collections, param-tree key "embedding@<name>"
        self.extra: Dict[str, EmbeddingCollection] = {}
        for g in getattr(cfg, "extra_groups", ()):
            gt = resolve_strategies(g.tables, mesh_cfg, global_batch)
            self.extra[g.name] = EmbeddingCollection(
                gt, mesh, comm=pick_comm(gt), compute_dtype=cd,
                shard_axes=embed_shard_axes, pool_fn=pool)
        #: cat column span per collection key, in declared order —
        #: batches lay out cat as [primary tables | group1 | group2 ...]
        cols: Dict[str, tuple] = {"embedding": (0, len(cfg.tables))}
        off = len(cfg.tables)
        for g in getattr(cfg, "extra_groups", ()):
            cols[f"embedding@{g.name}"] = (off, off + len(g.tables))
            off += len(g.tables)
        self._group_cols = cols

    def collections(self) -> Dict[str, EmbeddingCollection]:
        """Every embedding collection keyed by its param-tree key."""
        out: Dict[str, EmbeddingCollection] = {"embedding": self.embedding}
        if self.wide is not None:
            out["wide_embedding"] = self.wide
        for name, coll in self.extra.items():
            out[f"embedding@{name}"] = coll
        return out

    def group_columns(self) -> Dict[str, tuple]:
        """``cat`` column ``(start, stop)`` per lookup key (the wide
        twin reads the primary columns, so it is not listed)."""
        return dict(self._group_cols)

    # -- init ----------------------------------------------------------------

    def init(self, key: jax.Array) -> Dict:
        cfg = self.cfg
        k_emb, k_wide, k1, k2, k3, k4 = jax.random.split(key, 6)
        params: Dict = {"embedding": self.embedding.init(k_emb)}
        if self.wide is not None:
            params["wide_embedding"] = self.wide.init(k_wide)
        for i, (name, coll) in enumerate(sorted(self.extra.items())):
            params[f"embedding@{name}"] = coll.init(
                jax.random.fold_in(k_emb, i + 1))
        d, t = cfg.embedding_dim, cfg.num_tables
        nd = cfg.num_dense_features
        if cfg.model == "graph":
            # per-layer params from the compiled program, keyed by each
            # layer's output tensor (the trainer's dense/sparse split is
            # by the reserved embedding keys, so any layer name works)
            params.update(self.program.init(k1))
        elif cfg.model == "dlrm":
            params["bottom"] = layers.mlp_init(k1, nd, cfg.bottom_mlp)
            f = t + 1
            top_in = cfg.bottom_mlp[-1] + f * (f - 1) // 2
            params["top"] = layers.mlp_init(k2, top_in, cfg.top_mlp)
        elif cfg.model == "dcn":
            in_dim = nd + t * d
            params["cross"] = layers.cross_init(k1, in_dim,
                                                cfg.num_cross_layers)
            params["deep"] = layers.mlp_init(k2, in_dim, cfg.top_mlp)
            params["combine"] = layers.mlp_init(
                k3, in_dim + cfg.top_mlp[-1], (1,))
        elif cfg.model == "deepfm":
            in_dim = nd + t * d
            params["deep"] = layers.mlp_init(k1, in_dim, cfg.top_mlp + (1,))
            params["dense_w"] = jax.random.normal(k2, (nd,)) * 0.01
            params["bias"] = jnp.zeros(())
        elif cfg.model == "wdl":
            in_dim = nd + t * d
            params["deep"] = layers.mlp_init(k1, in_dim, cfg.top_mlp + (1,))
            params["dense_w"] = jax.random.normal(k2, (nd,)) * 0.01
            params["bias"] = jnp.zeros(())
        else:
            raise ValueError(cfg.model)
        return params

    # -- shardings -------------------------------------------------------------

    def param_shardings(self) -> Dict:
        """NamedShardings: embeddings per strategy, dense replicated (DP)."""
        rep = NamedSharding(self.mesh, P())
        shardings: Dict = {key: coll.param_shardings()
                           for key, coll in self.collections().items()}
        # structure only — eval_shape, NEVER a real init (tables can be
        # tens of GB; allocating them here stalled the dry-run for 20 min)
        dummy = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

        def fill(tree):
            return jax.tree.map(lambda _: rep, tree)

        for k, v in dummy.items():
            if k in shardings:
                continue
            shardings[k] = fill(v)
        return shardings

    # -- forward ---------------------------------------------------------------

    def apply(self, params: Dict, batch: Dict, *,
              manual: bool = False) -> jax.Array:
        cat = batch["cat"]
        # single-group models keep the whole-cat trace they always had;
        # N-group models slice each collection's column span
        cat_p = cat if not self.extra \
            else cat[:, slice(*self._group_cols["embedding"]), :]
        emb = self.embedding.lookup(params["embedding"], cat_p,
                                    manual=manual)
        wide = None
        if self.wide is not None:
            wide = self.wide.lookup(params["wide_embedding"], cat_p,
                                    manual=manual)       # [B, T, 1]
        extras = None
        if self.extra:
            extras = {}
            for name, coll in self.extra.items():
                key = f"embedding@{name}"
                s = slice(*self._group_cols[key])
                extras[name] = coll.lookup(params[key], cat[:, s, :],
                                           manual=manual)
        return self.apply_dense(params, batch["dense"], emb, wide,
                                extras=extras)

    def apply_dense(self, params: Dict, dense: jax.Array, emb: jax.Array,
                    wide: Optional[jax.Array] = None, *,
                    extras: Optional[Dict[str, jax.Array]] = None
                    ) -> jax.Array:
        """Dense-only forward from precomputed pooled embeddings.

        This is the inference entry point: the HPS resolves ``emb`` (and
        ``wide``) on the host, the replicated dense net runs on device.

        Execution is the compiled :class:`DenseGraphProgram` — the same
        node loop for the canonical recipes and for novel graphs
        (bit-exact with the historical fixed pipeline, which survives as
        :meth:`apply_dense_reference` for the parity tests and the
        compile-overhead benchmark).
        """
        if self.dense_executor == "reference":
            return self.apply_dense_reference(params, dense, emb, wide)
        env = self.program.make_env(dense, emb, wide, self.compute_dtype,
                                    extras=extras)
        return self.program.apply(params, env, self.compute_dtype)

    def apply_dense_reference(self, params: Dict, dense: jax.Array,
                              emb: jax.Array,
                              wide: Optional[jax.Array] = None
                              ) -> jax.Array:
        """The pre-compiler fixed pipeline (canonical recipes only) —
        kept as the bit-exactness reference for the generic executor."""
        cfg = self.cfg
        cd = self.compute_dtype
        emb = emb.astype(cd)                       # [B, T, D]
        dense = dense.astype(jnp.float32)
        b = dense.shape[0]
        if cfg.model == "dlrm":
            bot = layers.mlp_apply(params["bottom"], dense,
                                   final_activation=True, compute_dtype=cd)
            feats = jnp.concatenate([bot[:, None, :], emb], axis=1)
            if self.use_kernels:
                tri = kops.dot_interaction(feats)
            else:
                from repro.kernels.ref import dot_interaction_ref
                tri = dot_interaction_ref(feats)
            top_in = jnp.concatenate([bot.astype(jnp.float32), tri], axis=1)
            logit = layers.mlp_apply(params["top"], top_in, compute_dtype=cd)
            return logit[:, 0]
        flat = jnp.concatenate(
            [dense, emb.reshape(b, -1).astype(jnp.float32)], axis=1)
        if cfg.model == "dcn":
            crossed = layers.cross_apply(params["cross"], flat,
                                         compute_dtype=cd)
            deep = layers.mlp_apply(params["deep"], flat, compute_dtype=cd)
            both = jnp.concatenate([crossed, deep], axis=1)
            return layers.mlp_apply(params["combine"], both,
                                    compute_dtype=cd)[:, 0]
        if cfg.model == "deepfm":
            first = wide.sum(axis=(1, 2)) \
                + dense @ params["dense_w"] + params["bias"]
            second = layers.fm_second_order(emb).sum(axis=1)
            deep = layers.mlp_apply(params["deep"], flat,
                                    compute_dtype=cd)[:, 0]
            return first + second + deep
        if cfg.model == "wdl":
            wide_logit = wide.sum(axis=(1, 2)) \
                + dense @ params["dense_w"] + params["bias"]
            deep = layers.mlp_apply(params["deep"], flat,
                                    compute_dtype=cd)[:, 0]
            return wide_logit + deep
        raise ValueError(cfg.model)

    def loss_fn(self, params: Dict, batch: Dict, *,
                manual: bool = False) -> jax.Array:
        logits = self.apply(params, batch, manual=manual)
        return layers.bce_with_logits(logits, batch["label"])
