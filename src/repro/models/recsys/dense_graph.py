"""Generic dense-graph compiler: layer DAG -> :class:`DenseGraphProgram`.

This is the execution half of the graph API redesign (HugeCTR's front
door is a declarative layer graph the framework compiles for ANY
architecture, not a menu of recipes). ``compile_layers`` takes the named
``DenseLayer`` wiring, validates it (unknown tensors, duplicate names,
cycles, arity, shape agreement, single terminal, no unused layers),
topologically sorts it, infers every tensor's per-sample shape, and
emits a ``DenseGraphProgram``: a node list the model executes as ONE
jitted apply, plus per-layer parameter init. ``RecsysModel.apply_dense``
runs the program for every model — the four canonical recipes execute
through it bit-exactly (their programs are derived from the canonical
``RecsysConfig`` by :func:`canonical_program`, binding the historical
parameter names), and novel graphs execute through the same node loop
with per-layer parameters keyed by their output tensor.

Tensor shapes are tracked per sample (the batch axis is implicit):
``(n,)`` is a 2-D ``[B, n]`` feature block, ``(T, D)`` is a 3-D pooled
embedding block, and ``()`` is a logit-shaped ``[B]`` column. The op
vocabulary and its shape rules live in ``OP_RULES`` below; ``api.py``
documents the user-facing subset.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.recsys import layers as dlayers

#: params that can never be shadowed by a layer output (the embedding
#: collections own these keys in the param tree)
RESERVED_NAMES = ("embedding", "wide_embedding")


class GraphError(ValueError):
    """A model graph that cannot be compiled into a dense program."""


# ---------------------------------------------------------------------------
# Specs and nodes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerSpec:
    """One dense layer before compilation (validated, not yet typed)."""
    type: str
    bottoms: Tuple[str, ...]
    top: str
    units: Tuple[int, ...] = ()
    num_layers: int = 0
    final_activation: bool = False
    start: int = 0
    stop: int = 0
    #: parameter-tree path override (canonical programs bind historical
    #: names like ("bottom",); default is (top,))
    param: Optional[Tuple[str, ...]] = None


@dataclasses.dataclass
class Node:
    """One compiled op: inputs resolved, shapes known, params bound."""
    op: str
    inputs: Tuple[str, ...]
    output: str
    attrs: Dict
    #: local param name -> path into the model param tree
    params: Dict[str, Tuple[str, ...]]


def spec_from_layer(layer) -> LayerSpec:
    """An ``api.DenseLayer``-shaped object -> :class:`LayerSpec`."""
    return LayerSpec(
        type=layer.type, bottoms=tuple(layer.bottom_names),
        top=layer.top_names[0], units=tuple(layer.units),
        num_layers=int(layer.num_layers),
        final_activation=bool(layer.final_activation),
        start=int(getattr(layer, "start", 0)),
        stop=int(getattr(layer, "stop", 0)))


# -- serializable spec (RecsysConfig.dense_graph) ---------------------------

def graph_spec(dense_name: str, emb_name: str, wide_name: Optional[str],
               specs: Sequence[LayerSpec],
               extras: Sequence[str] = ()) -> Tuple:
    """The hashable tuple form embedded in ``RecsysConfig.dense_graph``:
    one ``("inputs", dense, emb, wide)`` header + one
    ``(type, bottoms, top, attrs)`` tuple per layer. N-group models
    append a 5th header element naming the extra embedding inputs —
    omitted when there are none, so legacy specs (and their config
    hashes) are unchanged."""
    head: Tuple = ("inputs", dense_name, emb_name, wide_name or "")
    if extras:
        head = head + (tuple(extras),)
    out: List[Tuple] = [head]
    for s in specs:
        attrs: List[Tuple] = []
        if s.type == "mlp":
            attrs = [("final_activation", s.final_activation),
                     ("units", tuple(s.units))]
        elif s.type == "cross":
            attrs = [("num_layers", s.num_layers)]
        elif s.type == "slice":
            attrs = [("start", s.start), ("stop", s.stop)]
        out.append((s.type, tuple(s.bottoms), s.top, tuple(attrs)))
    return tuple(out)


def spec_layers(dense_graph: Tuple) -> Tuple[str, str, Optional[str],
                                             List[LayerSpec],
                                             Tuple[str, ...]]:
    """Inverse of :func:`graph_spec`. The last return value is the
    tuple of extra embedding input names (() for legacy 4-field
    headers)."""
    if not dense_graph or dense_graph[0][0] != "inputs":
        raise GraphError("dense_graph spec is missing its inputs header")
    head = dense_graph[0]
    _, dense_name, emb_name, wide_name = head[:4]
    extras = tuple(head[4]) if len(head) > 4 else ()
    specs = []
    for typ, bottoms, top, attrs in dense_graph[1:]:
        kw = dict(attrs)
        specs.append(LayerSpec(
            type=typ, bottoms=tuple(bottoms), top=top,
            units=tuple(kw.get("units", ())),
            num_layers=int(kw.get("num_layers", 0)),
            final_activation=bool(kw.get("final_activation", False)),
            start=int(kw.get("start", 0)), stop=int(kw.get("stop", 0))))
    return dense_name, emb_name, (wide_name or None), specs, extras


def dense_graph_from_jsonable(g) -> Tuple:
    """Rebuild the tuple spec from its JSON (lists) form."""
    if not g:
        return ()
    head = list(g[0])
    if len(head) > 4:              # N-group header carries extras names
        head[4] = tuple(head[4])
    out: List[Tuple] = [tuple(head)]
    for typ, bottoms, top, attrs in g[1:]:
        out.append((typ, tuple(bottoms), top,
                    tuple((k, tuple(v) if isinstance(v, (list, tuple))
                           else v) for k, v in attrs)))
    return tuple(out)


# ---------------------------------------------------------------------------
# Shape inference
# ---------------------------------------------------------------------------

def _flat_dim(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def _fmt(name: str, shape: Tuple[int, ...]) -> str:
    return f"{name!r} [B{''.join(f', {s}' for s in shape)}]"


def _arity(s: LayerSpec, lo: int, hi: Optional[int] = None) -> None:
    n = len(s.bottoms)
    if n < lo or (hi is not None and n > hi):
        want = f"exactly {lo}" if hi == lo else (
            f"at least {lo}" if hi is None else f"{lo}..{hi}")
        raise GraphError(
            f"DenseLayer({s.type}) -> {s.top!r} takes {want} bottom "
            f"tensor(s), got {list(s.bottoms)}")


def _infer_shape(s: LayerSpec, shp: Dict[str, Tuple[int, ...]]
                 ) -> Tuple[int, ...]:
    """Per-sample output shape of layer ``s`` given its bottoms' shapes;
    raises :class:`GraphError` naming the offending tensor on mismatch."""
    bs = [shp[b] for b in s.bottoms]
    if s.type == "mlp":
        _arity(s, 1)
        if not s.units:
            raise GraphError(f"DenseLayer(mlp) -> {s.top!r} needs units")
        return (s.units[-1],)
    if s.type == "cross":
        _arity(s, 1, 1)
        if len(bs[0]) != 1:
            raise GraphError(
                f"cross -> {s.top!r} runs over a 2-D feature block, but "
                f"{_fmt(s.bottoms[0], bs[0])} is not [B, n]")
        return bs[0]
    if s.type == "dot_interaction":
        _arity(s, 2, 2)
        vec, emb = bs
        if len(vec) != 1 or len(emb) != 2:
            raise GraphError(
                f"dot_interaction -> {s.top!r} takes [bottom_mlp_out "
                f"[B, D], embeddings [B, T, D]], got "
                f"{_fmt(s.bottoms[0], vec)} and {_fmt(s.bottoms[1], emb)}")
        if vec[0] != emb[1]:
            raise GraphError(
                f"dot_interaction -> {s.top!r}: bottom mlp must end at "
                f"the embedding dim for the interaction: "
                f"{s.bottoms[0]!r} has {vec[0]} features != embedding "
                f"dim {emb[1]} of {s.bottoms[1]!r}")
        f = emb[0] + 1
        return (f * (f - 1) // 2,)
    if s.type == "fm":
        _arity(s, 3, 3)
        return ()
    if s.type == "concat":
        _arity(s, 1)
        return (sum(_flat_dim(b) for b in bs),)
    if s.type in ("add", "multiply"):
        _arity(s, 2)
        for b, bshape in zip(s.bottoms[1:], bs[1:]):
            if bshape != bs[0]:
                raise GraphError(
                    f"{s.type} -> {s.top!r} needs equal shapes, but "
                    f"{_fmt(b, bshape)} != {_fmt(s.bottoms[0], bs[0])}")
        return bs[0]
    if s.type == "relu":
        _arity(s, 1, 1)
        return bs[0]
    if s.type == "slice":
        _arity(s, 1, 1)
        if len(bs[0]) != 1:
            raise GraphError(
                f"slice -> {s.top!r} cuts a 2-D feature block, but "
                f"{_fmt(s.bottoms[0], bs[0])} is not [B, n]")
        if not (0 <= s.start < s.stop <= bs[0][0]):
            raise GraphError(
                f"slice -> {s.top!r}: [{s.start}:{s.stop}] out of range "
                f"for {_fmt(s.bottoms[0], bs[0])}")
        return (s.stop - s.start,)
    if s.type == "reduce_sum":
        _arity(s, 1, 1)
        return ()
    if s.type == "sigmoid":
        _arity(s, 1)
        for b, bshape in zip(s.bottoms, bs):
            if bshape not in ((), (1,)):
                raise GraphError(
                    f"sigmoid sums logit-shaped bottoms ([B] or [B, 1]), "
                    f"but {_fmt(b, bshape)} is wider — end the branch "
                    "with a 1-unit head or a reduce_sum")
        return ()
    if s.type == "first_order":        # internal (canonical wdl/deepfm)
        return ()
    if s.type == "fm_second":          # internal (canonical deepfm)
        return ()
    raise GraphError(f"unknown DenseLayer type {s.type!r}")


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

def _toposort(specs: List[LayerSpec],
              available: set) -> List[LayerSpec]:
    """Kahn's algorithm over the layer DAG (stable w.r.t. declaration
    order). Unknown tensors and cycles raise with the offending names."""
    producible = set(available) | {s.top for s in specs}
    for s in specs:
        for b in s.bottoms:
            if b not in producible:
                raise GraphError(
                    f"DenseLayer({s.type}) -> {s.top!r} reads unknown "
                    f"tensor {b!r} (known tensors: "
                    f"{sorted(producible)})")
    done = set(available)
    order: List[LayerSpec] = []
    remaining = list(specs)
    while remaining:
        ready = [s for s in remaining if all(b in done for b in s.bottoms)]
        if not ready:
            cyc = sorted(s.top for s in remaining)
            raise GraphError(
                f"dependency cycle among DenseLayers producing {cyc}: "
                "each reads a tensor that (transitively) depends on its "
                "own output")
        for s in ready:
            order.append(s)
            done.add(s.top)
        remaining = [s for s in remaining if s not in ready]
    return order


class DenseGraphProgram:
    """A compiled dense graph: topo-ordered nodes, per-tensor shapes,
    one ``apply`` (jit-traceable) and per-layer ``init``."""

    def __init__(self, nodes: List[Node], shapes: Dict[str, Tuple],
                 inputs: Dict[str, Optional[str]],
                 logit_bottoms: Tuple[str, ...], *,
                 use_kernels: bool = False):
        self.nodes = nodes
        self.shapes = shapes
        self.inputs = inputs                 # {"dense","emb","wide"} -> name
        self.logit_bottoms = logit_bottoms
        self.use_kernels = use_kernels

    # -- params ---------------------------------------------------------------

    def init(self, key: jax.Array) -> Dict:
        """Init every param-bearing node (novel graphs; canonical models
        keep their historical init in ``RecsysModel.init``)."""
        bearing = [n for n in self.nodes
                   if n.op in ("mlp", "cross", "fm")]
        params: Dict = {}
        if not bearing:
            return params
        keys = jax.random.split(key, len(bearing))
        for n, k in zip(bearing, keys):
            if n.op == "mlp":
                p = dlayers.mlp_init(k, n.attrs["in_dim"],
                                     n.attrs["units"])
            elif n.op == "cross":
                p = dlayers.cross_init(k, n.attrs["in_dim"],
                                       n.attrs["num_layers"])
            else:                            # fm first-order weights
                p = {"w": jax.random.normal(
                        k, (n.attrs["in_dim"],)) * 0.01,
                     "b": jnp.zeros(())}
            params[n.params["p"][0]] = p
        return params

    # -- execution -------------------------------------------------------------

    def make_env(self, dense, emb, wide, compute_dtype,
                 extras: Optional[Dict] = None) -> Dict:
        """Input environment with the canonical entry casts: dense f32,
        the deep embedding block in compute dtype, the wide block as
        delivered (the first-order term pools it in its own dtype).
        ``extras`` maps extra embedding group names to their pooled
        blocks (N-group models); they get the deep cast."""
        env = {self.inputs["dense"]: dense.astype(jnp.float32),
               self.inputs["emb"]: emb.astype(compute_dtype)}
        if self.inputs.get("wide") and wide is not None:
            env[self.inputs["wide"]] = wide
        for name in self.inputs.get("extras", ()):
            env[name] = extras[name].astype(compute_dtype)
        return env

    def apply(self, params: Dict, env: Dict, compute_dtype) -> jax.Array:
        """Execute the node list; returns the logit column ``[B]``."""

        def fetch(node: Node, local: str):
            p = params
            for k in node.params[local]:
                p = p[k]
            return p

        def x2d(v):
            return v if v.ndim == 2 else v.reshape(v.shape[0], -1)

        def col(v):
            return v if v.ndim == 1 else \
                v.reshape(v.shape[0], -1).sum(axis=1)

        for n in self.nodes:
            xs = [env[i] for i in n.inputs]
            if n.op == "mlp":
                vs = [x2d(v) for v in xs]
                x = vs[0] if len(vs) == 1 else jnp.concatenate(vs, axis=1)
                env[n.output] = dlayers.mlp_apply(
                    fetch(n, "p"), x,
                    final_activation=n.attrs["final_activation"],
                    compute_dtype=compute_dtype)
            elif n.op == "cross":
                env[n.output] = dlayers.cross_apply(
                    fetch(n, "p"), xs[0], compute_dtype=compute_dtype)
            elif n.op == "dot_interaction":
                feats = jnp.concatenate([xs[0][:, None, :], xs[1]], axis=1)
                if self.use_kernels:
                    from repro.kernels import ops as kops
                    env[n.output] = kops.dot_interaction(feats)
                else:
                    from repro.kernels.ref import dot_interaction_ref
                    env[n.output] = dot_interaction_ref(feats)
            elif n.op == "concat":
                env[n.output] = jnp.concatenate([x2d(v) for v in xs],
                                                axis=1)
            elif n.op == "add":
                out = xs[0]
                for v in xs[1:]:
                    out = out + v
                env[n.output] = out
            elif n.op == "multiply":
                out = xs[0]
                for v in xs[1:]:
                    out = out * v
                env[n.output] = out
            elif n.op == "relu":
                env[n.output] = jax.nn.relu(xs[0])
            elif n.op == "slice":
                env[n.output] = xs[0][:, n.attrs["start"]:n.attrs["stop"]]
            elif n.op == "reduce_sum":
                env[n.output] = col(xs[0])
            elif n.op == "first_order":
                dense_v, wide_v = xs
                env[n.output] = wide_v.sum(axis=(1, 2)) \
                    + dense_v @ fetch(n, "w") + fetch(n, "b")
            elif n.op == "fm_second":
                env[n.output] = dlayers.fm_second_order(xs[0]).sum(axis=1)
            elif n.op == "fm":
                dense_v, wide_v, emb_v = xs
                p = fetch(n, "p")
                first = wide_v.sum(axis=(1, 2)) \
                    + dense_v @ p["w"] + p["b"]
                env[n.output] = first \
                    + dlayers.fm_second_order(emb_v).sum(axis=1)
            else:                            # pragma: no cover
                raise ValueError(f"uncompiled op {n.op!r}")

        out = None
        for name in self.logit_bottoms:
            v = col(env[name])
            out = v if out is None else out + v
        return out


def compile_layers(specs: Sequence[LayerSpec], *, dense_name: str,
                   num_dense: int, emb_name: str, num_tables: int,
                   emb_dim: int, wide_name: Optional[str] = None,
                   extra_embs: Optional[Dict[str, Tuple[int, int]]] = None,
                   use_kernels: bool = False) -> DenseGraphProgram:
    """Validate + toposort + shape-infer the layer DAG and emit the
    program. Every failure is a :class:`GraphError` naming the offending
    layer or tensor. ``extra_embs`` maps extra embedding group names to
    their per-sample ``(num_tables, dim)`` shapes (N-group models)."""
    specs = list(specs)
    extra_embs = dict(extra_embs or {})
    inputs: Dict[str, Tuple[int, ...]] = {dense_name: (num_dense,),
                                          emb_name: (num_tables, emb_dim)}
    if wide_name:
        inputs[wide_name] = (num_tables, 1)
    for name, (t_n, d_n) in extra_embs.items():
        if name in inputs:
            raise GraphError(
                f"extra SparseEmbedding group name {name!r} collides "
                "with another graph input")
        inputs[name] = (t_n, d_n)

    produced = set(inputs)
    for s in specs:
        if s.top in produced:
            raise GraphError(f"duplicate tensor name {s.top!r}")
        if s.top in RESERVED_NAMES or s.top.startswith("embedding@"):
            raise GraphError(
                f"tensor name {s.top!r} is reserved for the embedding "
                "parameter groups")
        produced.add(s.top)

    order = _toposort(specs, set(inputs))

    # shapes (in topo order, so every bottom is known)
    shapes: Dict[str, Tuple[int, ...]] = dict(inputs)
    for s in order:
        shapes[s.top] = _infer_shape(s, shapes)

    # terminal discipline: exactly one unconsumed tensor, every
    # embedding branch read, sigmoid only at the end
    consumed = {b for s in specs for b in s.bottoms}
    for s in specs:
        if s.type == "sigmoid" and s.top in consumed:
            raise GraphError(
                f"sigmoid -> {s.top!r} is a terminal layer; "
                f"{s.top!r} cannot feed another layer")
    terminals = [s for s in specs if s.top not in consumed]
    if not terminals:
        raise GraphError("the graph has no terminal: every layer output "
                         "is consumed by another layer")
    if len(terminals) > 1:
        names = sorted(s.top for s in terminals)
        raise GraphError(
            f"the graph must end in exactly one terminal tensor, got "
            f"{len(terminals)}: {names} are all unconsumed — unused "
            "layers must be removed or wired in")
    must_read = (emb_name,) + ((wide_name,) if wide_name else ()) \
        + tuple(extra_embs)
    for name in must_read:
        if name not in consumed:
            raise GraphError(
                f"SparseEmbedding output {name!r} is never read by any "
                "DenseLayer")

    term = terminals[0]
    if term.type == "sigmoid":
        logit_bottoms = tuple(term.bottoms)
    else:
        if shapes[term.top] not in ((), (1,)):
            raise GraphError(
                f"terminal tensor {_fmt(term.top, shapes[term.top])} is "
                "not logit-shaped; end the graph with a 1-unit head, a "
                "reduce_sum, or a sigmoid layer")
        logit_bottoms = (term.top,)

    # emit nodes (the sigmoid terminal compiles into the logit sum)
    nodes: List[Node] = []
    for s in order:
        if s.type == "sigmoid":
            continue
        attrs: Dict = {}
        params: Dict[str, Tuple[str, ...]] = {}
        path = s.param or (s.top,)
        if s.type == "mlp":
            attrs = {"units": tuple(s.units),
                     "final_activation": s.final_activation,
                     "in_dim": sum(_flat_dim(shapes[b])
                                   for b in s.bottoms)}
            params = {"p": path}
        elif s.type == "cross":
            attrs = {"num_layers": s.num_layers,
                     "in_dim": shapes[s.bottoms[0]][0]}
            params = {"p": path}
        elif s.type == "slice":
            attrs = {"start": s.start, "stop": s.stop}
        elif s.type == "first_order":
            # internal op; canonical_program rebinds these paths to the
            # historical top-level ("dense_w", "bias") entries
            params = {"w": (s.top, "w"), "b": (s.top, "b")}
        elif s.type == "fm":
            # roles by shape: the 2-D block, the dim-1 3-D block, the
            # embedding 3-D block
            vec = [b for b in s.bottoms if len(shapes[b]) == 1]
            wid = [b for b in s.bottoms
                   if len(shapes[b]) == 2 and shapes[b][1] == 1]
            emb = [b for b in s.bottoms
                   if len(shapes[b]) == 2 and shapes[b][1] != 1]
            if len(vec) != 1 or len(wid) != 1 or len(emb) != 1:
                raise GraphError(
                    f"fm -> {s.top!r} reads [dense features [B, n], "
                    "wide embeddings [B, T, 1], deep embeddings "
                    f"[B, T, D>1]], got shapes "
                    f"{[shapes[b] for b in s.bottoms]} for "
                    f"{list(s.bottoms)}")
            s = dataclasses.replace(s, bottoms=(vec[0], wid[0], emb[0]))
            attrs = {"in_dim": shapes[vec[0]][0]}
            params = {"p": path}
        nodes.append(Node(op=s.type, inputs=tuple(s.bottoms), output=s.top,
                          attrs=attrs, params=params))

    return DenseGraphProgram(
        nodes, shapes,
        {"dense": dense_name, "emb": emb_name, "wide": wide_name,
         "extras": tuple(extra_embs)},
        logit_bottoms, use_kernels=use_kernels)


# ---------------------------------------------------------------------------
# Canonical programs (the four paper recipes, historical param names)
# ---------------------------------------------------------------------------

def canonical_program(cfg, *, use_kernels: bool = False
                      ) -> DenseGraphProgram:
    """The fixed-recipe graphs expressed as programs — node for node the
    computation ``RecsysModel.apply_dense`` always ran, so execution
    through the generic program is bit-exact with the legacy path."""
    t, d, nd = len(cfg.tables), cfg.embedding_dim, cfg.num_dense_features

    def mlp(bottoms, top, units, param, final=False):
        return LayerSpec("mlp", tuple(bottoms), top, units=tuple(units),
                         final_activation=final, param=(param,))

    if cfg.model == "dlrm":
        specs = [
            mlp(("dense",), "bot", cfg.bottom_mlp, "bottom", final=True),
            LayerSpec("dot_interaction", ("bot", "emb"), "tri"),
            LayerSpec("concat", ("bot", "tri"), "top_in"),
            mlp(("top_in",), "logit", cfg.top_mlp, "top"),
            LayerSpec("sigmoid", ("logit",), "prob"),
        ]
        wide = None
    elif cfg.model == "dcn":
        specs = [
            LayerSpec("concat", ("dense", "emb"), "flat"),
            LayerSpec("cross", ("flat",), "crossed",
                      num_layers=cfg.num_cross_layers, param=("cross",)),
            mlp(("flat",), "deep_out", cfg.top_mlp, "deep"),
            LayerSpec("concat", ("crossed", "deep_out"), "both"),
            mlp(("both",), "logit", (1,), "combine"),
            LayerSpec("sigmoid", ("logit",), "prob"),
        ]
        wide = None
    elif cfg.model == "deepfm":
        specs = [
            LayerSpec("concat", ("dense", "emb"), "flat"),
            mlp(("flat",), "deep_out", cfg.top_mlp + (1,), "deep"),
            LayerSpec("first_order", ("dense", "wide"), "first"),
            LayerSpec("fm_second", ("emb",), "fm2"),
            LayerSpec("sigmoid", ("first", "fm2", "deep_out"), "prob"),
        ]
        wide = "wide"
    elif cfg.model == "wdl":
        specs = [
            LayerSpec("concat", ("dense", "emb"), "flat"),
            mlp(("flat",), "deep_out", cfg.top_mlp + (1,), "deep"),
            LayerSpec("first_order", ("dense", "wide"), "wide_out"),
            LayerSpec("sigmoid", ("wide_out", "deep_out"), "prob"),
        ]
        wide = "wide"
    else:
        raise ValueError(f"no canonical program for model {cfg.model!r}")

    prog = compile_layers(
        specs, dense_name="dense", num_dense=nd, emb_name="emb",
        num_tables=t, emb_dim=d, wide_name=wide,
        use_kernels=use_kernels)
    # bind the historical first-order params (compile defaults them
    # under the layer name; the canonical tree keeps them at the top)
    for n in prog.nodes:
        if n.op == "first_order":
            n.params = {"w": ("dense_w",), "b": ("bias",)}
    return prog


def program_for(cfg, *, use_kernels: bool = False) -> DenseGraphProgram:
    """The program for ANY RecsysConfig: canonical recipes bind their
    historical params; ``model == "graph"`` compiles ``cfg.dense_graph``."""
    if cfg.model != "graph":
        return canonical_program(cfg, use_kernels=use_kernels)
    dense_name, emb_name, wide_name, specs, extras = \
        spec_layers(cfg.dense_graph)
    by_name = {g.name: g for g in getattr(cfg, "extra_groups", ())}
    missing = [n for n in extras if n not in by_name]
    if missing:
        raise GraphError(
            f"dense_graph header names extra embedding inputs {missing} "
            "with no matching extra_groups entry in the config")
    extra_embs = {n: (len(by_name[n].tables), by_name[n].dim)
                  for n in extras}
    return compile_layers(
        specs, dense_name=dense_name, num_dense=cfg.num_dense_features,
        emb_name=emb_name, num_tables=len(cfg.tables),
        emb_dim=cfg.embedding_dim, wide_name=wide_name,
        extra_embs=extra_embs, use_kernels=use_kernels)
