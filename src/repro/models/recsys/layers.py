"""Shared dense layers for the recsys model zoo (functional, no framework)."""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return {"bf16": jnp.bfloat16, "f32": jnp.float32}[name]


def mlp_init(key: jax.Array, in_dim: int, sizes: Sequence[int]) -> Dict:
    params = {}
    dims = [in_dim] + list(sizes)
    keys = jax.random.split(key, len(sizes))
    for i, k in enumerate(keys):
        fan_in, fan_out = dims[i], dims[i + 1]
        w = jax.random.normal(k, (fan_in, fan_out), jnp.float32)
        w = w * np.sqrt(2.0 / fan_in)
        params[f"w{i}"] = w
        params[f"b{i}"] = jnp.zeros((fan_out,), jnp.float32)
    return params


def mlp_apply(params: Dict, x: jax.Array, *, final_activation: bool = False,
              compute_dtype=jnp.bfloat16) -> jax.Array:
    n = len(params) // 2
    h = x.astype(compute_dtype)
    for i in range(n):
        w = params[f"w{i}"].astype(compute_dtype)
        h = jax.lax.dot(h, w, preferred_element_type=jnp.float32)
        h = h + params[f"b{i}"]
        if i < n - 1 or final_activation:
            h = jax.nn.relu(h)
        h = h.astype(compute_dtype)
    return h.astype(jnp.float32)


def cross_init(key: jax.Array, dim: int, n_layers: int) -> Dict:
    params = {}
    keys = jax.random.split(key, n_layers)
    for i, k in enumerate(keys):
        params[f"w{i}"] = jax.random.normal(k, (dim,), jnp.float32) \
            / np.sqrt(dim)
        params[f"b{i}"] = jnp.zeros((dim,), jnp.float32)
    return params


def cross_apply(params: Dict, x0: jax.Array,
                compute_dtype=jnp.bfloat16) -> jax.Array:
    """DCN cross network: x_{l+1} = x0 * (x_l . w_l) + b_l + x_l."""
    n = len(params) // 2
    x0c = x0.astype(compute_dtype)
    x = x0c
    for i in range(n):
        w = params[f"w{i}"].astype(compute_dtype)
        xw = jnp.einsum("bd,d->b", x, w,
                        preferred_element_type=jnp.float32)
        x = (x0c * xw[:, None].astype(compute_dtype)
             + params[f"b{i}"].astype(compute_dtype) + x)
    return x.astype(jnp.float32)


def fm_second_order(emb: jax.Array) -> jax.Array:
    """FM pairwise term: ``emb [B, T, D]`` -> ``[B, D]``.

    0.5 * ((sum_t v_t)^2 - sum_t v_t^2) — equivalent to summing all pairwise
    hadamard products.
    """
    e = emb.astype(jnp.float32)
    s = e.sum(axis=1)
    sq = (e * e).sum(axis=1)
    return 0.5 * (s * s - sq)


def bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Numerically-stable mean binary cross entropy."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def auc(logits: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUC (host-side eval metric, the paper's model metric)."""
    order = np.argsort(logits)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    pos = labels > 0.5
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))
