"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Placement mirrors the paper's *localized slot embedding*: each device owns
whole experts (slots) and tokens route to owners — the same machinery as
the embedding engine's bucketed dispatch (capacity factor, overflow drops).

Because the batch is replicated over the ``model`` axis (it is sharded
over DP axes only), every model-rank routes the SAME local tokens to its
OWN experts and a single ``psum`` over ``model`` combines the top-k expert
outputs — token traffic equals one TP all-reduce of activations, with no
all-to-all needed (DESIGN.md §4).

Runs inside ``shard_map`` (the backbone wraps it); experts whose count
does not divide the model-axis size are padded and masked out of routing.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.lm.transformer import norm_apply, norm_init


def padded_experts(cfg: LMConfig, model_axis_size: int) -> int:
    e = cfg.moe.num_experts
    return (e + model_axis_size - 1) // model_axis_size * model_axis_size


def moe_init(key: jax.Array, cfg: LMConfig, model_axis_size: int) -> Dict:
    d, f = cfg.d_model, cfg.moe.expert_d_ff
    e_pad = padded_experts(cfg, model_axis_size)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s, so = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    return {
        "router": jax.random.normal(k1, (d, e_pad), jnp.float32) * s,
        "w1": jax.random.normal(k2, (e_pad, d, f), jnp.float32) * s,
        "w3": jax.random.normal(k3, (e_pad, d, f), jnp.float32) * s,
        "w2": jax.random.normal(k4, (e_pad, f, d), jnp.float32) * so,
        "norm": norm_init(cfg),
    }


def _bucket(owner: jax.Array, n_buckets: int, capacity: int):
    """owner [N] in [0, n_buckets] (n_buckets = drop) -> slot assignment."""
    m = owner.shape[0]
    order = jnp.argsort(owner, stable=True)
    sorted_owner = owner[order]
    start = jnp.searchsorted(sorted_owner, jnp.arange(n_buckets + 1))
    pos = jnp.arange(m) - start[sorted_owner]
    ok = (pos < capacity) & (sorted_owner < n_buckets)
    slot_sorted = jnp.where(ok, sorted_owner * capacity + pos,
                            n_buckets * capacity)
    slot = jnp.zeros((m,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    return slot


def moe_apply_local(params: Dict, x: jax.Array, cfg: LMConfig, *,
                    model_axis: str, model_axis_size: int) -> jax.Array:
    """Per-device MoE body (call inside shard_map over the full mesh).

    ``x [B_loc, S, D]`` (replicated over ``model``); expert weights arrive
    sharded on their leading E axis: ``[E_loc, D, F]``.
    """
    moe = cfg.moe
    b, s, d = x.shape
    e_pad = padded_experts(cfg, model_axis_size)
    e_loc = params["w1"].shape[0]
    cd = x.dtype
    h = norm_apply(params["norm"], x, cfg)
    logits = (h @ params["router"].astype(cd)).astype(jnp.float32)
    if e_pad > moe.num_experts:          # mask padding experts
        pad_mask = jnp.arange(e_pad) >= moe.num_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    gate_vals, sel = jax.lax.top_k(logits, moe.top_k)   # [B, S, k]
    gate = jax.nn.softmax(gate_vals, axis=-1)

    n = b * s
    flat = h.reshape(n, d)
    sel_flat = sel.reshape(n * moe.top_k)
    gate_flat = gate.reshape(n * moe.top_k).astype(jnp.float32)
    tok_of = jnp.repeat(jnp.arange(n), moe.top_k)

    midx = jax.lax.axis_index(model_axis)
    e0 = midx * e_loc
    rel = sel_flat - e0
    local = (rel >= 0) & (rel < e_loc)
    owner = jnp.where(local, rel, e_loc)
    capacity = max(1, int(n * moe.top_k / moe.num_experts
                          * moe.capacity_factor))
    slot = _bucket(owner, e_loc, capacity)              # [n*k]
    valid = slot < e_loc * capacity

    # gather tokens into [E_loc, C, D]
    buf_tok = jnp.full((e_loc * capacity,), n, jnp.int32) \
        .at[slot].set(tok_of.astype(jnp.int32), mode="drop")
    flat_pad = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], 0)
    buf = flat_pad[buf_tok].reshape(e_loc, capacity, d)

    u = jnp.einsum("ecd,edf->ecf", buf, params["w1"].astype(cd))
    u = jax.nn.silu(u) * jnp.einsum("ecd,edf->ecf", buf,
                                    params["w3"].astype(cd))
    y_buf = jnp.einsum("ecf,efd->ecd", u, params["w2"].astype(cd))
    y_buf = y_buf.reshape(e_loc * capacity, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)], 0)

    # scatter back with gate weights
    contrib = y_buf[jnp.where(valid, slot, e_loc * capacity)] \
        * (gate_flat * valid).astype(y_buf.dtype)[:, None]
    y = jnp.zeros((n, d), jnp.float32).at[tok_of].add(
        contrib.astype(jnp.float32))
    y = jax.lax.psum(y, model_axis)
    return x + y.reshape(b, s, d).astype(cd)


def aux_load_balance_loss(logits: jax.Array, sel: jax.Array,
                          num_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary (fraction × router prob)."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(sel[..., 0], logits.shape[-1]),
                    axis=(0, 1))
    return num_experts * jnp.sum(frac * probs.mean(axis=(0, 1)))
