"""LMModel — assembly of the assigned architectures on the paper's substrate.

One class covers all six families (dense / moe / ssm / hybrid / audio /
vlm) via the config's ``block_pattern``; layers are stacked and driven by
``lax.scan`` so the HLO stays one-group-sized.

The paper's technique shows up here as the **vocab embedding modes**
(DESIGN.md §5): LM token tables are Zipf-accessed like CTR features, so
the hybrid hot/cold split applies directly:

  * ``replicated`` — whole table on every device (small vocabs),
  * ``sharded``    — rows striped over ``embed_shard_axes`` (Megatron-style
    MP; fwd psum of [B, S, D]),
  * ``hybrid``     — hot rows replicated (local lookup, no comm in fwd;
    tiny grad all-reduce) + cold rows striped over *all* mesh axes
    (HugeCTR's hybrid sparse embedding, which also FSDP-shards the
    dominant memory consumer for 256k-vocab archs).

Cross-entropy runs in sequence chunks against the (vocab-sharded) head so
[B, S, V] logits never materialize.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

from repro.configs.base import LMConfig, ShapeConfig
from repro.models.lm import moe as moe_lib
from repro.models.lm import rglru as rglru_lib
from repro.models.lm import xlstm as xlstm_lib
from repro.models.lm import transformer as tf


def _stack_init(init_fn, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args))(keys)


class LMModel:

    def __init__(self, cfg: LMConfig, mesh: Mesh, *,
                 embed_mode: str = "auto",
                 embed_shard_axes: Optional[Tuple[str, ...]] = None,
                 hot_fraction: float = 0.05,
                 q_chunk: int = 1024, k_chunk: int = 1024,
                 loss_chunk: int = 512,
                 remat: str = "none",
                 attn_partition: str = "auto",
                 compute_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.mesh = mesh
        self.cd = compute_dtype if cfg.dtype == "bf16" else jnp.float32
        self.q_chunk, self.k_chunk = q_chunk, k_chunk
        self.loss_chunk = loss_chunk
        self.remat = remat
        axes = tuple(mesh.axis_names)
        self.model_axis = "model"
        self.model_size = int(mesh.shape["model"]) \
            if "model" in axes else 1
        self.n_dev = int(np.prod(mesh.devices.shape))
        if embed_mode == "auto":
            embed_mode = "hybrid" if cfg.vocab_size >= 100_000 else \
                "sharded" if cfg.vocab_size * cfg.d_model > 2 ** 26 else \
                "replicated"
        self.embed_mode = embed_mode
        # default: shard the cold/sharded table over "model" only, so the
        # (tied) output head is naturally vocab-parallel with no resharding;
        # all-axes sharding is available as a memory-scaling knob.
        self.embed_axes = embed_shard_axes or ("model",)
        # FSDP-style extra sharding of block params over "data" when TP-only
        # sharding would blow past HBM (command-r-plus: 104B params).
        self.fsdp = cfg.dense_param_count * 12 / max(
            int(mesh.shape["model"]) if "model" in axes else 1, 1) > 10e9
        self.hot_rows = max(self.n_dev, int(cfg.vocab_size * hot_fraction)) \
            if embed_mode == "hybrid" else 0
        # pad cold/sharded rows to the sharding product
        shard_n = 1
        for a in self.embed_axes:
            shard_n *= int(mesh.shape[a])
        self._embed_shard_n = shard_n
        cold = cfg.vocab_size - self.hot_rows
        self.cold_rows = (cold + shard_n - 1) // shard_n * shard_n
        vpad = (cfg.vocab_size + self.model_size - 1) \
            // self.model_size * self.model_size
        self.vocab_pad = vpad
        # attention partitioning for train/prefill: head-sharding is clean
        # iff the model axis factors as (a | hkv) x (b | group) — GSPMD
        # then shards kv-heads by a and query-groups by b with no sharded
        # contraction. Otherwise it splits head_dim and all-reduces every
        # score block (456 GiB/device on minitron prefill — §Perf iter 2);
        # those archs shard the query SEQUENCE instead (seqpar_attention).
        # Measured: seq wins only for the dirty cases (minitron g=3,
        # granite-3b g=3); clean archs regress under seq (causal-half FLOP
        # loss) — hence the exact divisibility rule, not a blanket one.
        if attn_partition == "auto":
            if self.model_size > 1 and cfg.num_kv_heads > 0:
                import math
                a = math.gcd(cfg.num_kv_heads, self.model_size)
                b = self.model_size // a
                group = cfg.num_heads // cfg.num_kv_heads
                dirty = group % b != 0
            else:
                dirty = False
            # FSDP archs also take seq — but only when TRAINING (remat
            # set): the win is the seq-over-model sharding of the scan-
            # carry saves (§Perf iter 6), which measured 72.2 s (heads)
            # vs 40.6 s (seq) on command-r train_4k; for fwd-only prefill
            # heads measured better (26.4 vs 48.6 s).
            training = remat != "none"
            attn_partition = "seq" if (dirty or (self.fsdp and training)) \
                else "heads"
        self.attn_partition = attn_partition
        self._seq_par_mesh = mesh if attn_partition == "seq" else None
        # layer grouping for the scan
        self.pattern = cfg.block_pattern
        total = cfg.num_layers
        per = len(self.pattern)
        self.n_groups = total // per
        self.n_tail = total - self.n_groups * per    # leftover layers
        self.tail_pattern = cfg.block_pattern[:self.n_tail]

    # ------------------------------------------------------------------ init

    def _block_init(self, key, kind: str):
        cfg = self.cfg
        if kind == "attn":
            return {"attn": tf.attn_init(key, cfg),
                    "ffn": self._ffn_or_moe_init(
                        jax.random.fold_in(key, 1))}
        if kind == "local_attn":
            return {"attn": tf.attn_init(key, cfg),
                    "ffn": tf.ffn_init(jax.random.fold_in(key, 1), cfg)}
        if kind == "rglru":
            return {"rglru": rglru_lib.rglru_init(key, cfg),
                    "ffn": tf.ffn_init(jax.random.fold_in(key, 1), cfg)}
        if kind == "mlstm":
            return {"mlstm": xlstm_lib.mlstm_init(key, cfg)}
        if kind == "slstm":
            return {"slstm": xlstm_lib.slstm_init(key, cfg)}
        raise ValueError(kind)

    def _ffn_or_moe_init(self, key):
        if self.cfg.moe is not None:
            return moe_lib.moe_init(key, self.cfg, self.model_size)
        return tf.ffn_init(key, self.cfg)

    def init(self, key: jax.Array) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        d = cfg.d_model
        params: Dict = {}
        # embeddings
        scale = 1.0 / np.sqrt(d)
        if self.embed_mode == "hybrid":
            params["embed_hot"] = jax.random.normal(
                keys[0], (self.hot_rows, d), jnp.float32) * scale
            params["embed_cold"] = jax.random.normal(
                keys[1], (self.cold_rows, d), jnp.float32) * scale
        else:
            rows = self.vocab_pad if self.embed_mode == "sharded" \
                else cfg.vocab_size
            params["embed"] = jax.random.normal(
                keys[0], (rows, d), jnp.float32) * scale
        if not cfg.tie_embeddings:
            params["head"] = jax.random.normal(
                keys[2], (d, self.vocab_pad), jnp.float32) * scale
        params["final_norm"] = tf.norm_init(cfg)
        # blocks: one stacked params-tree per pattern position
        params["groups"] = {}
        for pi, kind in enumerate(self.pattern):
            params["groups"][f"{pi}_{kind}"] = _stack_init(
                lambda k: self._block_init(k, kind),
                jax.random.fold_in(keys[3], pi), self.n_groups)
        for pi, kind in enumerate(self.tail_pattern):
            params["groups"][f"tail{pi}_{kind}"] = _stack_init(
                lambda k: self._block_init(k, kind),
                jax.random.fold_in(keys[4], pi), 1)
        # encoder (enc-dec archs)
        if cfg.encoder_layers:
            params["enc_groups"] = _stack_init(
                lambda k: {"attn": tf.attn_init(k, cfg),
                           "ffn": tf.ffn_init(jax.random.fold_in(k, 1),
                                              cfg)},
                keys[5], cfg.encoder_layers)
            params["cross"] = _stack_init(
                lambda k: tf.attn_init(k, cfg), keys[6], cfg.num_layers)
        return params

    # ----------------------------------------------------------- shardings

    def param_specs(self) -> Dict:
        cfg = self.cfg
        m = self.model_axis
        # FSDP: also stripe the non-TP dim of big projections over "data";
        # GSPMD then all-gathers each scan step's weights (ZeRO-3).
        data_axes = tuple(a for a in self.mesh.axis_names
                          if a not in ("model", "pod"))
        fs = data_axes[0] if (self.fsdp and data_axes) else None
        dsz = int(self.mesh.shape[fs]) if fs else 1

        def fsd(n):
            return fs if (fs and n % dsz == 0) else None

        def attn_spec():
            hd = cfg.resolved_head_dim
            div = lambda n: (m if n % self.model_size == 0 else None)
            return {"wq": P(None, fsd(cfg.d_model),
                            div(cfg.num_heads * hd)),
                    "wk": P(None, fsd(cfg.d_model),
                            div(cfg.num_kv_heads * hd)),
                    "wv": P(None, fsd(cfg.d_model),
                            div(cfg.num_kv_heads * hd)),
                    "wo": P(None, div(cfg.num_heads * hd),
                            fsd(cfg.d_model)),
                    "norm": _norm_spec(cfg)}

        def ffn_spec(f=None):
            f = f or cfg.d_ff
            div = m if f % self.model_size == 0 else None
            sp = {"w1": P(None, fsd(cfg.d_model), div),
                  "w2": P(None, div, fsd(cfg.d_model)),
                  "norm": _norm_spec(cfg)}
            if cfg.activation in ("swiglu", "geglu"):
                sp["w3"] = P(None, fsd(cfg.d_model), div)
            return sp

        def _norm_spec(cfg):
            return {} if cfg.norm == "nonparam_ln" \
                else {"scale": P(None, None)}

        def moe_spec():
            return {"router": P(None, None, None),
                    "w1": P(None, m, None, None),
                    "w3": P(None, m, None, None),
                    "w2": P(None, m, None, None),
                    "norm": _norm_spec(cfg)}

        def dense_d_spec(shape_key):
            # big [L, D, D] square projections: shard output dim
            div = m if cfg.d_model % self.model_size == 0 else None
            return P(None, None, div)

        def block_spec(kind):
            if kind in ("attn", "local_attn"):
                ffn = moe_spec() if (cfg.moe is not None and kind == "attn") \
                    else ffn_spec()
                return {"attn": attn_spec(), "ffn": ffn}
            if kind == "rglru":
                div = m if cfg.d_model % self.model_size == 0 else None
                return {"rglru": {
                    "w_gelu": P(None, None, div),
                    "w_rnn": P(None, None, div),
                    "conv": P(None, None, div),
                    "wa": P(None, None, div), "wx": P(None, None, div),
                    "lam": P(None, div),
                    "w_out": P(None, div, None),
                    "norm": _norm_spec(cfg)}, "ffn": ffn_spec()}
            if kind == "mlstm":
                div = m if cfg.d_model % self.model_size == 0 else None
                return {"mlstm": {
                    "wq": P(None, None, div), "wk": P(None, None, div),
                    "wv": P(None, None, div),
                    "wi": P(None, None, None), "wf": P(None, None, None),
                    "bf": P(None, None), "bi": P(None, None),
                    "wo": P(None, div, None), "wog": P(None, None, div),
                    "norm": _norm_spec(cfg), "gn": P(None, div)}}
            if kind == "slstm":
                div = m if cfg.d_model % self.model_size == 0 else None
                return {"slstm": {
                    "wz": P(None, None, div), "wi": P(None, None, div),
                    "wf": P(None, None, div), "wo": P(None, None, div),
                    "rz": P(None, None, None, None),
                    "ri": P(None, None, None, None),
                    "rf": P(None, None, None, None),
                    "ro": P(None, None, None, None),
                    "bf": P(None, None), "bi": P(None, None),
                    "down": P(None, div, None), "norm": _norm_spec(cfg)}}
            raise ValueError(kind)

        specs: Dict = {"final_norm": ({} if cfg.norm == "nonparam_ln"
                                      else {"scale": P(None)}),
                       "groups": {}}
        if self.embed_mode == "hybrid":
            specs["embed_hot"] = P(None, None)
            specs["embed_cold"] = P(self.embed_axes, None)
        elif self.embed_mode == "sharded":
            specs["embed"] = P(self.embed_axes, None)
        else:
            specs["embed"] = P(None, None)
        if not cfg.tie_embeddings:
            specs["head"] = P(None,
                              m if self.vocab_pad % self.model_size == 0
                              else None)
        for pi, kind in enumerate(self.pattern):
            specs["groups"][f"{pi}_{kind}"] = block_spec(kind)
        for pi, kind in enumerate(self.tail_pattern):
            specs["groups"][f"tail{pi}_{kind}"] = block_spec(kind)
        if cfg.encoder_layers:
            specs["enc_groups"] = {"attn": attn_spec(), "ffn": ffn_spec()}
            specs["cross"] = attn_spec()
        return specs

    def param_shardings(self):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.param_specs(),
            is_leaf=lambda x: isinstance(x, P))

    # -------------------------------------------------------------- embed

    def _sharded_lookup(self, table: jax.Array, ids: jax.Array,
                        valid: jax.Array) -> jax.Array:
        """Row-sharded table lookup via shard_map (masked take + psum).

        Plain ``jnp.take`` on a row-sharded table makes GSPMD all-gather
        the WHOLE table (11 GiB f32 for command-r's cold split, ×several
        live buffers — §Perf iter 9). The HugeCTR-style pattern instead:
        every shard resolves the ids that fall in its row range and one
        psum of the [B, S, D] activations combines them — the same
        masked_range_lookup the recsys engine uses.
        """
        axes = self.embed_axes
        b = ids.shape[0]
        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        dp_n = 1
        for a in dp:
            dp_n *= int(self.mesh.shape[a])
        dspec = dp if b % dp_n == 0 else None
        shard_rows = table.shape[0] // self._embed_shard_n

        def local(tab, ids_, valid_):
            idx = jax.lax.axis_index(axes)
            rel = ids_ - idx * shard_rows
            ok = valid_ & (rel >= 0) & (rel < shard_rows)
            part = jnp.take(tab, jnp.where(ok, rel, 0), axis=0)
            part = jnp.where(ok[..., None], part.astype(self.cd), 0)
            return jax.lax.psum(part, axes)

        fn = compat.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(axes, None), P(dspec, None), P(dspec, None)),
            out_specs=P(dspec, None, None), check_vma=False)
        return fn(table, ids, valid)

    def embed(self, params: Dict, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        if self.embed_mode == "hybrid":
            hot = params["embed_hot"]
            is_hot = tokens < self.hot_rows
            hot_part = jnp.take(hot, jnp.where(is_hot, tokens, 0),
                                axis=0).astype(self.cd)
            hot_part = jnp.where(is_hot[..., None], hot_part, 0)
            cold_part = self._sharded_lookup(
                params["embed_cold"], tokens - self.hot_rows, ~is_hot)
            x = hot_part + cold_part
        elif self.embed_mode == "sharded":
            x = self._sharded_lookup(
                params["embed"], tokens, jnp.ones(tokens.shape, bool))
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        return x.astype(self.cd)

    def _head_parts(self, params: Dict):
        """Output head as a list of [D, V_part] matrices.

        The tied-hybrid case stays in two parts (hot.T replicated,
        cold.T vocab-parallel) so the full table is never materialized —
        logits are the concat along vocab and, because cold ids follow hot
        ids contiguously, ``concat_logits[token_id]`` is the right logit.
        """
        if self.cfg.tie_embeddings:
            if self.embed_mode == "hybrid":
                return [params["embed_hot"].T, params["embed_cold"].T]
            emb = params["embed"]
            if emb.shape[0] < self.vocab_pad:
                emb = jnp.pad(
                    emb, ((0, self.vocab_pad - emb.shape[0]), (0, 0)))
            return [emb.T]
        return [params["head"]]

    @property
    def logits_size(self) -> int:
        if self.cfg.tie_embeddings and self.embed_mode == "hybrid":
            return self.hot_rows + self.cold_rows
        return self.vocab_pad

    # -------------------------------------------------------------- blocks

    def _apply_block(self, kind: str, bp: Dict, x, *, positions,
                     cache=None, cache_pos=None):
        cfg = self.cfg
        new_cache = None
        if kind in ("attn", "local_attn"):
            window = cfg.local_attn_window if kind == "local_attn" else None
            x, new_cache = tf.attn_apply(
                bp["attn"], x, cfg, positions=positions, causal=True,
                window=window, cache=cache, cache_pos=cache_pos,
                q_chunk=self.q_chunk, k_chunk=self.k_chunk,
                seq_par_mesh=self._seq_par_mesh)
            if cfg.moe is not None and kind == "attn":
                x = self._moe(bp["ffn"], x)
            else:
                x = tf.ffn_apply(bp["ffn"], x, cfg)
        elif kind == "rglru":
            x, new_cache = rglru_lib.rglru_apply(bp["rglru"], x, cfg,
                                                 state=cache)
            x = tf.ffn_apply(bp["ffn"], x, cfg)
        elif kind == "mlstm":
            x, new_cache = xlstm_lib.mlstm_apply(bp["mlstm"], x, cfg,
                                                 state=cache)
        elif kind == "slstm":
            x, new_cache = xlstm_lib.slstm_apply(bp["slstm"], x, cfg,
                                                 state=cache)
        else:
            raise ValueError(kind)
        return x, new_cache

    def _moe(self, mp: Dict, x: jax.Array) -> jax.Array:
        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        wspec = {"router": P(None, None),
                 "w1": P("model", None, None),
                 "w3": P("model", None, None),
                 "w2": P("model", None, None),
                 "norm": jax.tree.map(lambda _: P(None), mp["norm"])}
        fn = compat.shard_map(
            functools.partial(moe_lib.moe_apply_local, cfg=self.cfg,
                              model_axis="model",
                              model_axis_size=self.model_size),
            mesh=self.mesh,
            in_specs=(wspec, P(dp, None, None)),
            out_specs=P(dp, None, None),
            check_vma=False)
        return fn(mp, x)

    # --------------------------------------------------------------- train

    def _pin_batch(self, h):
        """Pin activations to batch-over-DP sharding inside scan bodies.

        With FSDP the weights carry the ``data`` axis on their contraction
        dims; without this constraint GSPMD resolves the conflict by
        RESHARDING ACTIVATIONS to replicated-batch/split-d (observed on
        command-r train_4k: [256, 4096, 768] per-device activations,
        442 GiB peak). Pinning batch forces the cheap resolution — the
        ZeRO-3 per-layer weight all-gather. §Perf iter 5.

        When attention is sequence-partitioned anyway, the seq dim is
        additionally pinned over ``model`` — this shards the per-layer
        scan-carry saves (the residual-stream activations reverse-mode
        keeps) 16x, which is what brings the 104B train cell under HBM
        (§Perf iter 6). Elementwise/rowwise ops (norms, FFN matmuls over
        d) are indifferent to seq sharding.
        """
        if not self.fsdp:
            return h
        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        seq = "model" if (self.attn_partition == "seq"
                          and h.shape[1] % self.model_size == 0) else None
        return jax.lax.with_sharding_constraint(
            h, NamedSharding(self.mesh, P(dp, seq, None)))

    def _run_stack(self, params, x, positions, *, enc_out=None):
        """Scan every pattern group; returns final hidden states."""
        if self.cfg.encoder_layers:
            return self._run_encdec_decoder(params, x, positions, enc_out)
        for pi, kind in enumerate(self.pattern):
            gp = params["groups"][f"{pi}_{kind}"]

            def body(h, layer_p, _kind=kind):
                h = self._pin_batch(h)
                h2, _ = self._apply_block(_kind, layer_p, h,
                                          positions=positions)
                return h2, ()

            fn = body
            if self.remat == "group":
                # sqrt(L) nested-scan remat: reverse-mode keeps only the
                # n_outer group-boundary carries instead of all L (the
                # [L, B, S, D] carry stack was the peak-HBM driver for
                # command-r train_4k); each group's layers are recomputed
                # during its backward. §Perf iter 10.
                n = self.n_groups
                outer = max(1, int(np.sqrt(n)))
                while n % outer:
                    outer -= 1
                inner = n // outer

                def group_body(h, group_p, _fn=jax.checkpoint(body)):
                    # inner layers are ALSO checkpointed: during a group's
                    # bwd recompute the inner scan would otherwise stack
                    # every layer's interior activations at once
                    # (measured: peak 82 GiB vs 27 GiB nested).
                    h2, _ = jax.lax.scan(_fn, h, group_p)
                    return h2, ()

                gp = jax.tree.map(
                    lambda a: a.reshape((outer, inner) + a.shape[1:]), gp)
                x, _ = jax.lax.scan(jax.checkpoint(group_body), x, gp)
                continue
            if self.remat != "none":
                fn = jax.checkpoint(
                    body, policy=None if self.remat == "full"
                    else jax.checkpoint_policies.checkpoint_dots)
            x, _ = jax.lax.scan(fn, x, gp)
        for pi, kind in enumerate(self.tail_pattern):
            gp = params["groups"][f"tail{pi}_{kind}"]

            def tbody(h, layer_p, _kind=kind):
                h2, _ = self._apply_block(_kind, layer_p, h,
                                          positions=positions)
                return h2, ()

            x, _ = jax.lax.scan(tbody, x, gp)
        return x

    def _run_encdec_decoder(self, params, x, positions, enc_out):
        cfg = self.cfg
        xs = {"blk": params["groups"][f"0_{self.pattern[0]}"],
              "cross": params["cross"]}

        def body(h, layer_p):
            bp = layer_p["blk"]
            h, _ = tf.attn_apply(bp["attn"], h, cfg, positions=positions,
                                 causal=True, q_chunk=self.q_chunk,
                                 k_chunk=self.k_chunk)
            h, _ = tf.attn_apply(layer_p["cross"], h, cfg,
                                 positions=positions, causal=False,
                                 kv_from=enc_out)
            h = tf.ffn_apply(bp["ffn"], h, cfg)
            return h, ()

        fn = body
        if self.remat != "none":
            fn = jax.checkpoint(
                body, policy=None if self.remat == "full"
                else jax.checkpoint_policies.checkpoint_dots)
        x, _ = jax.lax.scan(fn, x, xs)
        return x

    def _encode(self, params, frames: jax.Array) -> jax.Array:
        """Bidirectional encoder over stub frame embeddings."""
        cfg = self.cfg
        x = frames.astype(self.cd)
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], x.shape[:2])

        def body(h, layer_p):
            h, _ = tf.attn_apply(layer_p["attn"], h, cfg,
                                 positions=positions, causal=False,
                                 q_chunk=self.q_chunk, k_chunk=self.k_chunk)
            h = tf.ffn_apply(layer_p["ffn"], h, cfg)
            return h, ()

        x, _ = jax.lax.scan(body, x, params["enc_groups"])
        return x

    def train_loss(self, params: Dict, batch: Dict) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]                    # [B, S_text]
        b = tokens.shape[0]
        x = self.embed(params, tokens)
        prefix = 0
        enc_out = None
        if cfg.frontend == "vision":
            patches = batch["patches"].astype(self.cd)  # [B, S_img, D]
            x = jnp.concatenate([patches, x], axis=1)
            prefix = patches.shape[1]
        if cfg.frontend == "audio":
            enc_out = self._encode(params, batch["frames"])
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = self._run_stack(params, x, positions, enc_out=enc_out)
        x = tf.norm_apply(params["final_norm"], x, cfg)
        # next-token prediction on text positions
        h = x[:, prefix:, :]
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((b, 1), -1, tokens.dtype)], axis=1)
        return self._xent(params, h, labels)

    def _xent(self, params, h: jax.Array, labels: jax.Array) -> jax.Array:
        """Chunked softmax cross-entropy; never materializes [B, S, V]."""
        heads = [p.astype(self.cd) for p in self._head_parts(params)]
        vtotal = self.logits_size
        b, s, d = h.shape
        chunk = min(self.loss_chunk, s)
        nchunks = (s + chunk - 1) // chunk
        pad = nchunks * chunk - s
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)),
                             constant_values=-1)
        hs = h.reshape(b, nchunks, chunk, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(b, nchunks, chunk).transpose(1, 0, 2)

        def body(carry, xs):
            hc, lc = xs
            logits = jnp.concatenate(
                [(hc @ hp).astype(jnp.float32) for hp in heads], axis=-1)
            if vtotal > self.cfg.vocab_size:
                mask = jnp.arange(vtotal) >= self.cfg.vocab_size
                logits = jnp.where(mask, -1e30, logits)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            valid = lc >= 0
            loss = jnp.where(valid, lse - ll, 0.0)
            return (carry[0] + loss.sum(), carry[1] + valid.sum()), ()

        # remat: without this, reverse-mode saves every chunk's [b, c, V]
        # f32 logits (67 GiB for command-r train_4k — §Perf iter 7);
        # recomputing the chunk matmul in bwd is the standard trade.
        body = jax.checkpoint(body)
        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()),
                                            jnp.zeros((), jnp.int32)),
                                     (hs, ls))
        return tot / jnp.maximum(cnt, 1)

    # --------------------------------------------------------------- decode

    def init_cache(self, b: int, max_seq: int) -> Dict:
        cfg = self.cfg
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache: Dict = {"groups": {}}

        def blk_cache(kind, n):
            if kind == "attn":
                s = max_seq
                return (jnp.zeros((n, b, s, hkv, hd), self.cd),
                        jnp.zeros((n, b, s, hkv, hd), self.cd))
            if kind == "local_attn":
                s = min(max_seq, cfg.local_attn_window)
                return (jnp.zeros((n, b, s, hkv, hd), self.cd),
                        jnp.zeros((n, b, s, hkv, hd), self.cd))
            if kind == "rglru":
                return jax.tree.map(
                    lambda z: jnp.broadcast_to(z, (n,) + z.shape).copy(),
                    rglru_lib.rglru_zero_state(cfg, b))
            if kind == "mlstm":
                return jax.tree.map(
                    lambda z: jnp.broadcast_to(z, (n,) + z.shape).copy(),
                    xlstm_lib.mlstm_zero_state(cfg, b))
            if kind == "slstm":
                return jax.tree.map(
                    lambda z: jnp.broadcast_to(z, (n,) + z.shape).copy(),
                    xlstm_lib.slstm_zero_state(cfg, b))
            raise ValueError(kind)

        for pi, kind in enumerate(self.pattern):
            cache["groups"][f"{pi}_{kind}"] = blk_cache(kind, self.n_groups)
        for pi, kind in enumerate(self.tail_pattern):
            cache["groups"][f"tail{pi}_{kind}"] = blk_cache(kind, 1)
        if cfg.encoder_layers:
            senc = cfg.frontend_seq or 512
            cache["cross"] = (
                jnp.zeros((cfg.num_layers, b, senc, hkv, hd), self.cd),
                jnp.zeros((cfg.num_layers, b, senc, hkv, hd), self.cd))
        return cache

    def cache_specs(self, b: int = 0) -> Dict:
        """PartitionSpecs for the cache.

        Attention KV caches: batch over DP; KV heads over "model" when
        divisible, otherwise the SEQUENCE dim shards over "model" (the
        KV cache is the decode memory bound — GQA archs with kv-heads <
        model-size still scale; softmax over the sharded S needs only a
        tiny psum). Recurrent states shard batch only.
        """
        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        dp_n = 1
        for a in dp:
            dp_n *= int(self.mesh.shape[a])
        if b and b % dp_n != 0:
            dp = None          # batch too small to shard (e.g. long_500k)
        hkv = self.cfg.num_kv_heads
        kv_spec = P(None, dp, None, "model", None) \
            if hkv % self.model_size == 0 \
            else P(None, dp, "model", None, None)

        def spec(path, leaf):
            keys = "/".join(str(getattr(p, "key", "")) for p in path)
            is_attn = isinstance(leaf, jax.ShapeDtypeStruct) and \
                leaf.ndim == 5 and ("attn" in keys or "cross" in keys)
            if is_attn:
                return kv_spec
            # recurrent states / misc: batch over DP only
            return P(*( [None, dp] + [None] * (leaf.ndim - 2) ))

        cache = jax.eval_shape(lambda: self.init_cache(8, 16))
        return jax.tree_util.tree_map_with_path(spec, cache)

    def decode_step(self, params: Dict, tokens: jax.Array,
                    cache: Dict, pos: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        """``tokens [B, 1]``, ``pos [B]`` -> (logits [B, Vpad], new cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        x = self.embed(params, tokens)
        positions = pos[:, None]
        new_cache: Dict = {"groups": {}}

        for pi, kind in enumerate(self.pattern):
            gp = params["groups"][f"{pi}_{kind}"]
            gc = cache["groups"][f"{pi}_{kind}"]
            if cfg.encoder_layers:
                def ebody(h, xs):
                    layer_p, (sc, cc) = xs
                    bp = layer_p["blk"]
                    h, nsc = tf.attn_apply(
                        bp["attn"], h, cfg, positions=positions,
                        causal=True, cache=sc, cache_pos=pos)
                    h, _ = tf.attn_apply(
                        layer_p["cross"], h, cfg, positions=positions,
                        causal=False, cache=cc, cache_pos=pos)
                    h = tf.ffn_apply(bp["ffn"], h, cfg)
                    return h, nsc

                xs = ({"blk": gp, "cross": params["cross"]},
                      (gc, cache["cross"]))
                x, nsc = jax.lax.scan(ebody, x, xs)
                new_cache["groups"][f"{pi}_{kind}"] = nsc
                new_cache["cross"] = cache["cross"]
            else:
                def body(h, xs, _kind=kind):
                    layer_p, layer_c = xs
                    h, nc = self._apply_block(
                        _kind, layer_p, h, positions=positions,
                        cache=layer_c, cache_pos=pos)
                    return h, nc

                x, nc = jax.lax.scan(body, x, (gp, gc))
                new_cache["groups"][f"{pi}_{kind}"] = nc
        for pi, kind in enumerate(self.tail_pattern):
            gp = params["groups"][f"tail{pi}_{kind}"]
            gc = cache["groups"][f"tail{pi}_{kind}"]

            def tbody(h, xs, _kind=kind):
                layer_p, layer_c = xs
                h, nc = self._apply_block(
                    _kind, layer_p, h, positions=positions,
                    cache=layer_c, cache_pos=pos)
                return h, nc

            x, nc = jax.lax.scan(tbody, x, (gp, gc))
            new_cache["groups"][f"tail{pi}_{kind}"] = nc
        x = tf.norm_apply(params["final_norm"], x, cfg)
        logits = jnp.concatenate(
            [(x[:, 0] @ hp.astype(self.cd)).astype(jnp.float32)
             for hp in self._head_parts(params)], axis=-1)
        return logits, new_cache

    def prefill(self, params: Dict, batch: Dict) -> jax.Array:
        """Full-sequence forward returning last-position logits.

        (Cache construction during prefill is done by replaying decode for
        serving; the dry-run prefill cell measures the compute-bound
        full-sequence pass, which dominates.)
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = self.embed(params, tokens)
        enc_out = None
        if cfg.frontend == "vision":
            x = jnp.concatenate([batch["patches"].astype(self.cd), x],
                                axis=1)
        if cfg.frontend == "audio":
            enc_out = self._encode(params, batch["frames"])
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = self._run_stack(params, x, positions, enc_out=enc_out)
        x = tf.norm_apply(params["final_norm"], x, cfg)
        return jnp.concatenate(
            [(x[:, -1] @ hp.astype(self.cd)).astype(jnp.float32)
             for hp in self._head_parts(params)], axis=-1)
