"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a x_t)                      (recurrence gate)
    i_t = sigmoid(W_x x_t)                      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)      (elementwise decay, c=8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is elementwise-linear, so training/prefill uses
``jax.lax.associative_scan`` (log-depth — TPU-friendly) and decode keeps
an O(1) state — this is what qualifies the hybrid arch for ``long_500k``.

Block layout (Griffin): x -> {linear -> GeLU} ⊙ {linear -> causal conv1d(4)
-> RG-LRU} -> linear out, with pre-norm and residual.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.lm.transformer import norm_apply, norm_init

_C = 8.0
_CONV_K = 4


def rglru_init(key: jax.Array, cfg: LMConfig) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d)
    # Lambda init so a^(1/c) ~ U[0.9, 0.999] (paper appendix)
    u = jax.random.uniform(ks[0], (d,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))      # softplus^-1(-log u)
    return {
        "w_gelu": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "w_rnn": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "conv": jax.random.normal(ks[3], (_CONV_K, d), jnp.float32)
        * (1.0 / np.sqrt(_CONV_K)),
        "wa": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        "wx": jax.random.normal(ks[5], (d, d), jnp.float32) * s,
        "lam": lam,
        "w_out": jax.random.normal(
            jax.random.fold_in(key, 7), (d, d), jnp.float32) * s,
        "norm": norm_init(cfg),
    }


def rglru_zero_state(cfg: LMConfig, b: int):
    d = cfg.d_model
    return {
        "h": jnp.zeros((b, d), jnp.float32),
        "conv": jnp.zeros((b, _CONV_K - 1, d), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 carry: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d: ``x [B, S, D]``, ``w [K, D]``."""
    k = w.shape[0]
    if carry is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def rglru_apply(params: Dict, x: jax.Array, cfg: LMConfig, *,
                state=None) -> Tuple[jax.Array, Dict]:
    """``x [B, S, D]``; with ``state`` given, S must be 1 (decode)."""
    b, s, d = x.shape
    cd = x.dtype
    xin = norm_apply(params["norm"], x, cfg)
    gate = jax.nn.gelu(xin @ params["w_gelu"].astype(cd))
    u_raw = xin @ params["w_rnn"].astype(cd)     # pre-conv (the carry!)
    conv_carry = None if state is None else state["conv"]
    u = _causal_conv(u_raw, params["conv"].astype(cd), conv_carry)
    new_conv = None
    if state is not None:
        buf = jnp.concatenate([state["conv"].astype(cd), u_raw], axis=1)
        new_conv = buf[:, -(_CONV_K - 1):].astype(jnp.float32)
    uf = u.astype(jnp.float32)
    uf_raw = u_raw.astype(jnp.float32)
    r = jax.nn.sigmoid((xin @ params["wa"].astype(cd)).astype(jnp.float32))
    i = jax.nn.sigmoid((xin @ params["wx"].astype(cd)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r      # [B, S, D]
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    if state is None:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a2 * a1, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
        new_h = h[:, -1]
    else:
        h = a[:, 0] * state["h"] + bx[:, 0]
        new_h = h
        h = h[:, None]
    out = (h.astype(cd) * gate) @ params["w_out"].astype(cd)
    new_state = {"h": new_h, "conv": new_conv} if state is not None else \
        {"h": new_h,
         "conv": uf_raw[:, -(_CONV_K - 1):] if s >= _CONV_K - 1 else
         jnp.pad(uf_raw, ((0, 0), (_CONV_K - 1 - s, 0), (0, 0)))}
    return x + out, new_state
