"""Transformer building blocks for the assigned LM architectures.

Functional style: ``*_init(key, cfg) -> params dict`` and pure apply fns.
Blocks are stacked along a leading layer axis and driven by ``lax.scan``
(keeps HLO size O(1 layer); the roofline analyzer multiplies loop bodies
by trip count).

Attention is **chunked flash-style**: a Python loop over static query
chunks; per chunk, an online-softmax ``fori_loop`` over exactly the key
chunks a causal/local mask allows — so causal attention costs half the
FLOPs of the naive form and peak memory is ``q_chunk × k_chunk`` scores,
which is what makes ``prefill_32k`` fit HBM.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

from repro.configs.base import LMConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: LMConfig, d: Optional[int] = None) -> Dict:
    d = d or cfg.d_model
    if cfg.norm == "nonparam_ln":
        return {}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_apply(params: Dict, x: jax.Array, cfg: LMConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * params["scale"]
    elif cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * params["scale"]
    elif cfg.norm == "nonparam_ln":     # OLMo: no learnable affine
        mu = xf.mean(-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    else:
        raise ValueError(cfg.norm)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """``x [B, S, H, Dh]``, ``positions [B, S]`` -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash-style attention
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, pq0, pk0, *, causal, window, scale):
    """One (q-chunk, k-chunk) raw score block + mask.

    q [B, cq, H, Dh]; k/v [B, ck, Hkv, Dh]. Returns the UNMASKED scores and
    the boolean mask separately so the caller can fold the mask into the
    max-reduce and the exp fusion — masked scores are never materialized
    (one s²-sized write instead of two; §Perf iter 1).
    """
    b, cq, hq, dh = q.shape
    ck, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, cq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    pq = pq0 + jnp.arange(cq)
    pk = pk0 + jnp.arange(ck)
    mask = jnp.ones((cq, ck), bool)
    if causal:
        mask &= pk[None, :] <= pq[:, None]
    if window is not None:
        mask &= pk[None, :] > pq[:, None] - window
    return s, mask[None, None, None]


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      q_chunk: int = 1024, k_chunk: int = 1024,
                      q_pos0=0, p_dtype=None, folded: bool = False
                      ) -> jax.Array:
    """``q [B, Sq, Hq, Dh]``, ``k/v [B, Sk, Hkv, Dh]`` -> ``[B, Sq, Hq, Dh]``.

    For self-attention ``q_pos0 = Sk - Sq`` aligns query positions with the
    tail of the keys (used by cross-chunk prefill). ``q_pos0`` may be a
    traced scalar (sequence-parallel shards pass ``axis_index * shard``);
    the static causal block-range optimization then widens to the full key
    range and masking does the cut — see ``seqpar_attention``.
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq = (sq + q_chunk - 1) // q_chunk
    dyn_pos = isinstance(q_pos0, jax.Array)
    # p_dtype: bf16 halves the dominant [cq, ck] p-write under seqpar
    # (6.26s vs 6.77s f32) but regresses under GSPMD head-sharding, where
    # the XLA:CPU convert materializes an extra s²-tensor (7.15s vs 5.60s)
    # — callers pick per partition; default f32. The Pallas flash kernel
    # (kernels/flash_attention.py) removes the s² HBM traffic entirely on
    # TPU. §Perf iter 1/3.
    p_dtype = p_dtype or jnp.float32
    outs = []
    for qi in range(nq):
        q0 = qi * q_chunk
        cq = min(q_chunk, sq - q0)
        pq0 = q_pos0 + q0
        qc = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=1)
        # static key range for this q chunk (full range if pq0 is traced)
        if dyn_pos:
            lo, hi = 0, sk
        else:
            hi = min(sk, pq0 + cq) if causal else sk
            lo = max(0, pq0 + 1 - window) if window is not None else 0
            lo = (lo // k_chunk) * k_chunk
            hi = min(sk, ((hi + k_chunk - 1) // k_chunk) * k_chunk)
        nk = max(1, (hi - lo + k_chunk - 1) // k_chunk)

        def body(carry, ki):
            m, l, acc = carry
            k0 = lo + ki * k_chunk
            kc = jax.lax.dynamic_slice_in_dim(k, k0, k_chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k0, k_chunk, axis=1)
            s, mask = _attn_block(qc, kc, vc, pq0, k0, causal=causal,
                                  window=window, scale=scale)
            if folded:
                # mask folded into the reduce and the exp — masked scores
                # are never written to HBM; p in ``p_dtype`` (bf16 under
                # seqpar). -1e30 (not -inf) keeps m finite when a whole
                # block is masked (windowed attention): corr =
                # exp(-inf - -inf) would be NaN. The min-clamp stops the
                # exp's VJP from seeing inf on masked entries (raw s can
                # exceed m_new there). §Perf iter 1/3.
                m_new = jnp.maximum(m, jnp.where(mask, s, -1e30).max(-1))
                corr = jnp.exp(m - m_new)
                p = jnp.where(
                    mask,
                    jnp.exp(jnp.minimum(s - m_new[..., None], 0.0)), 0.0)
            else:
                # legacy block: materialize masked scores. Measured BEST
                # under GSPMD head-sharding on the dry-run lowering (the
                # folded form fused worse there: phi3 prefill 4.07→5.43 s)
                # — structure is chosen per partition, by measurement.
                sm = jnp.where(mask, s, -1e30)
                m_new = jnp.maximum(m, sm.max(-1))
                corr = jnp.exp(m - m_new)
                p = jnp.exp(sm - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(p_dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), ()

        m0 = jnp.full((b, hkv, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
        # scan (not fori) + checkpoint: reverse-mode otherwise stacks every
        # k-iteration's [cq, ck] p-block ([nk, B, H, cq, ck] f32 saves —
        # 6 GiB×4 per layer on command-r); with remat only the (m, l, acc)
        # carry chain survives and p is recomputed in bwd. §Perf iter 8.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                      jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, cq, hq, dh)
        outs.append(o.astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def seqpar_attention(q, k, v, mesh, *, causal: bool = True,
                     q_chunk: int = 1024, k_chunk: int = 1024) -> jax.Array:
    """Sequence-parallel attention: q seq-sharded over ``model``, k/v
    gathered (GSPMD inserts the ring all-gather).

    This is the §Perf iter-2 fix for GQA archs whose kv-head count does
    not divide the model axis: head-sharding then forces GSPMD to split
    the head_dim *contraction*, which materializes an all-reduce of every
    [cq, ck] score block (456 GiB/device for minitron prefill_32k).
    Sharding the query sequence instead keeps every score block local —
    the only collective is the k/v all-gather (128 MiB/layer).

    Trade-off: the causal block-range optimization needs static bounds, so
    each shard scans the full key range under the mask — attention FLOPs
    ×2 vs the optimal causal half. Collective term drops ~50×; memory per
    device is unchanged (seq 16-way ≈ head 8-way × causal half).
    """
    from jax.sharding import PartitionSpec as P

    msize = int(mesh.shape["model"])
    b, sq, hq, dh = q.shape
    if msize == 1 or sq % msize != 0:
        return chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                                 k_chunk=k_chunk)
    shard = sq // msize
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def local(qs, kf, vf):
        pq0 = jax.lax.axis_index("model") * shard
        return chunked_attention(qs, kf, vf, causal=causal,
                                 q_chunk=min(q_chunk, shard),
                                 k_chunk=k_chunk, q_pos0=pq0,
                                 p_dtype=vf.dtype, folded=True)

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, "model", None, None), P(dp, None, None, None),
                  P(dp, None, None, None)),
        out_specs=P(dp, "model", None, None), check_vma=False)
    return fn(q, k, v)


def decode_attention(q, k_cache, v_cache, pos, *,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token attention: ``q [B, 1, Hq, Dh]`` vs full cache.

    ``pos [B]`` = current position (cache entries > pos are masked; with
    ``window`` the cache is a rolling buffer and positions wrap).
    """
    b, _, hq, dh = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(b, hkv, g, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    idx = jnp.arange(smax)[None]                       # [1, smax]
    valid = idx <= pos[:, None]
    if window is not None:
        valid &= idx > pos[:, None] - window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (pre-norm, GQA, RoPE)
# ---------------------------------------------------------------------------

def attn_init(key: jax.Array, cfg: LMConfig) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(hq * hd)
    return {
        "wq": jax.random.normal(k1, (d, hq * hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, hkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, hkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (hq * hd, d), jnp.float32) * so,
        "norm": norm_init(cfg),
    }


def attn_apply(params: Dict, x: jax.Array, cfg: LMConfig, *,
               positions: jax.Array,
               causal: bool = True, window: Optional[int] = None,
               cache: Optional[Tuple[jax.Array, jax.Array]] = None,
               cache_pos: Optional[jax.Array] = None,
               kv_from: Optional[jax.Array] = None,
               q_chunk: int = 1024, k_chunk: int = 1024,
               seq_par_mesh=None,
               ) -> Tuple[jax.Array, Optional[Tuple]]:
    """Pre-norm attention with residual.

    * train/prefill: ``cache=None`` -> full-sequence chunked attention.
    * decode: ``cache=(k_cache, v_cache)``, ``x [B, 1, D]``; the new KV is
      written at ``cache_pos`` (rolling for local windows) and attention
      runs against the cache.
    * cross-attention: ``kv_from [B, Senc, D]`` supplies K/V (encoder out);
      no cache mutation, no causal mask.
    """
    b = x.shape[0]
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    cd = x.dtype
    h = norm_apply(params["norm"], x, cfg)
    kv_src = norm_apply(params["norm"], kv_from, cfg) \
        if kv_from is not None else h
    q = (h @ params["wq"].astype(cd)).reshape(b, -1, hq, hd)
    k = (kv_src @ params["wk"].astype(cd)).reshape(b, -1, hkv, hd)
    v = (kv_src @ params["wv"].astype(cd)).reshape(b, -1, hkv, hd)
    if kv_from is None:
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if cache is None else positions  # same timeline
        k = rope(k, kpos, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_from is None:
        k_cache, v_cache = cache
        smax = k_cache.shape[1]
        slot = cache_pos % smax if window is not None else cache_pos
        bidx = jnp.arange(b)
        k_cache = k_cache.at[bidx, slot].set(k[:, 0])
        v_cache = v_cache.at[bidx, slot].set(v[:, 0])
        new_cache = (k_cache, v_cache)
        if window is not None:
            # rolling cache: mask by true positions stored alongside
            o = _rolling_decode(q, k_cache, v_cache, cache_pos, smax)
        else:
            o = decode_attention(q, k_cache, v_cache, cache_pos,
                                 window=None)
    elif cache is not None:   # cross-attn during decode: static kv cache
        k_cache, v_cache = cache
        o = decode_attention(q, k_cache, v_cache,
                             jnp.full((b,), k_cache.shape[1] - 1),
                             window=None)
        new_cache = cache
    elif seq_par_mesh is not None and window is None and causal:
        o = seqpar_attention(q, k, v, seq_par_mesh, causal=True,
                             q_chunk=q_chunk, k_chunk=k_chunk)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              q_chunk=q_chunk, k_chunk=k_chunk)
    out = o.reshape(b, -1, hq * hd) @ params["wo"].astype(cd)
    return x + out, new_cache


def _rolling_decode(q, k_cache, v_cache, pos, smax):
    """Decode vs a rolling (windowed) cache: every entry is valid once the
    window has filled; before that, entries beyond ``pos`` are masked."""
    b = q.shape[0]
    idx = jnp.arange(smax)[None]
    # entry i holds position: i if i <= pos%smax else pos - (pos%smax) - smax + i
    cur = pos[:, None] % smax
    entry_pos = jnp.where(idx <= cur, pos[:, None] - cur + idx,
                          pos[:, None] - cur + idx - smax)
    valid = entry_pos >= 0
    hkv, dh = k_cache.shape[2], k_cache.shape[3]
    hq = q.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / np.sqrt(dh)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_init(key: jax.Array, cfg: LMConfig, d_ff: Optional[int] = None
             ) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s, so = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "w1": jax.random.normal(k1, (d, f), jnp.float32) * s,
        "w2": jax.random.normal(k2, (f, d), jnp.float32) * so,
        "norm": norm_init(cfg),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k3, (d, f), jnp.float32) * s
    return p


def ffn_apply(params: Dict, x: jax.Array, cfg: LMConfig) -> jax.Array:
    cd = x.dtype
    h = norm_apply(params["norm"], x, cfg)
    u = h @ params["w1"].astype(cd)
    if cfg.activation == "swiglu":
        u = jax.nn.silu(u) * (h @ params["w3"].astype(cd))
    elif cfg.activation == "geglu":
        u = jax.nn.gelu(u) * (h @ params["w3"].astype(cd))
    elif cfg.activation == "gelu":
        u = jax.nn.gelu(u)
    elif cfg.activation == "relu":
        u = jax.nn.relu(u)
    elif cfg.activation == "relu_sq":
        u = jnp.square(jax.nn.relu(u))
    else:
        raise ValueError(cfg.activation)
    return x + u @ params["w2"].astype(cd)
