"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential), with exponential gating
and max-stabilizer state.

Both are linear-time in sequence length with O(1) decode state — this is
what makes ``long_500k`` runnable for this architecture. Training uses
``lax.scan`` over time (HLO stays one-step-sized; the roofline analyzer
scales by trip count).

State layout (per layer):
  mLSTM: C [B, H, Dh, Dh], n [B, H, Dh], m [B, H]
  sLSTM: c [B, H, Dh], n [B, H, Dh], h [B, H, Dh], m [B, H]
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.lm.transformer import norm_apply, norm_init


def _heads(cfg: LMConfig) -> Tuple[int, int]:
    h = cfg.num_heads
    return h, cfg.d_model // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key: jax.Array, cfg: LMConfig) -> Dict:
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    return {
        "wq": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "wi": jax.random.normal(ks[3], (d, h), jnp.float32) * s,
        "wf": jax.random.normal(ks[4], (d, h), jnp.float32) * s,
        "bf": jnp.full((h,), 3.0, jnp.float32),   # open forget gate at init
        "bi": jnp.zeros((h,), jnp.float32),
        "wo": jax.random.normal(ks[5], (d, d), jnp.float32) * s,
        "wog": jax.random.normal(ks[6], (d, d), jnp.float32) * s,
        "norm": norm_init(cfg),
        "gn": jnp.ones((d,), jnp.float32),        # post-recurrence groupnorm
    }


def mlstm_zero_state(cfg: LMConfig, b: int):
    h, dh = _heads(cfg)
    return {
        "C": jnp.zeros((b, h, dh, dh), jnp.float32),
        "n": jnp.zeros((b, h, dh), jnp.float32),
        "m": jnp.zeros((b, h), jnp.float32),
    }


def _mlstm_step(state, qkvif):
    q, k, v, i_p, f_p = qkvif      # q/k/v [B, H, Dh]; gates [B, H]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(f_p + m, i_p)
    f_ = jnp.exp(f_p + m - m_new)
    i_ = jnp.exp(i_p - m_new)
    C = f_[..., None, None] * C + i_[..., None, None] \
        * (v[..., :, None] * k[..., None, :])          # [B,H,Dh,Dh]
    n = f_[..., None] * n + i_[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h_out = num / den[..., None]
    return {"C": C, "n": n, "m": m_new}, h_out


def mlstm_apply(params: Dict, x: jax.Array, cfg: LMConfig, *,
                state=None) -> Tuple[jax.Array, Dict]:
    """``x [B, S, D]`` -> ``([B, S, D], state)``. ``state`` enables decode."""
    b, s, d = x.shape
    h, dh = _heads(cfg)
    cd = x.dtype
    xin = norm_apply(params["norm"], x, cfg)
    q = (xin @ params["wq"].astype(cd)).reshape(b, s, h, dh) / np.sqrt(dh)
    k = (xin @ params["wk"].astype(cd)).reshape(b, s, h, dh)
    v = (xin @ params["wv"].astype(cd)).reshape(b, s, h, dh)
    i_p = (xin @ params["wi"].astype(cd) + params["bi"]).astype(jnp.float32)
    f_p = jax.nn.log_sigmoid(
        (xin @ params["wf"].astype(cd) + params["bf"]).astype(jnp.float32))
    if state is None:
        state = mlstm_zero_state(cfg, b)

    def step(st, inp):
        st, h_out = _mlstm_step(st, inp)
        return st, h_out

    seq = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
           k.transpose(1, 0, 2, 3).astype(jnp.float32),
           v.transpose(1, 0, 2, 3).astype(jnp.float32),
           i_p.transpose(1, 0, 2), f_p.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, seq)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, d)     # [B, S, D]
    hs = hs * params["gn"]                              # headwise norm scale
    og = jax.nn.sigmoid(xin @ params["wog"].astype(cd))
    out = (hs.astype(cd) * og) @ params["wo"].astype(cd)
    return x + out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key: jax.Array, cfg: LMConfig) -> Dict:
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = jax.random.split(key, 9)
    s = 1.0 / np.sqrt(d)
    sr = 1.0 / np.sqrt(dh)
    return {
        "wz": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "wi": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "wf": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        # block-diagonal recurrent weights (per head)
        "rz": jax.random.normal(ks[4], (h, dh, dh), jnp.float32) * sr,
        "ri": jax.random.normal(ks[5], (h, dh, dh), jnp.float32) * sr,
        "rf": jax.random.normal(ks[6], (h, dh, dh), jnp.float32) * sr,
        "ro": jax.random.normal(ks[7], (h, dh, dh), jnp.float32) * sr,
        "bf": jnp.full((d,), 3.0, jnp.float32),
        "bi": jnp.zeros((d,), jnp.float32),
        "down": jax.random.normal(ks[8], (d, d), jnp.float32) * s,
        "norm": norm_init(cfg),
    }


def slstm_zero_state(cfg: LMConfig, b: int):
    h, dh = _heads(cfg)
    z = jnp.zeros((b, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((b, h), jnp.float32)}


def slstm_apply(params: Dict, x: jax.Array, cfg: LMConfig, *,
                state=None) -> Tuple[jax.Array, Dict]:
    b, s, d = x.shape
    h, dh = _heads(cfg)
    cd = x.dtype
    xin = norm_apply(params["norm"], x, cfg)
    zx = (xin @ params["wz"].astype(cd)).reshape(b, s, h, dh)
    ix = (xin @ params["wi"].astype(cd) + params["bi"]).reshape(b, s, h, dh)
    fx = (xin @ params["wf"].astype(cd) + params["bf"]).reshape(b, s, h, dh)
    ox = (xin @ params["wo"].astype(cd)).reshape(b, s, h, dh)
    if state is None:
        state = slstm_zero_state(cfg, b)

    rz, ri, rf, ro = (params[k].astype(jnp.float32)
                      for k in ("rz", "ri", "rf", "ro"))

    def step(st, inp):
        zt, it, ft, ot = (t.astype(jnp.float32) for t in inp)
        hp = st["h"]
        rec = lambda r: jnp.einsum("bhj,hjk->bhk", hp, r)
        z = jnp.tanh(zt + rec(rz))
        i_p = it + rec(ri)
        f_p = jax.nn.log_sigmoid(ft + rec(rf))
        o = jax.nn.sigmoid(ot + rec(ro))
        # per-head max stabilizer over gate pre-activations
        i_m = i_p.max(-1)
        m_new = jnp.maximum(f_p.max(-1) + st["m"], i_m)
        f_ = jnp.exp(f_p + (st["m"] - m_new)[..., None])
        i_ = jnp.exp(i_p - m_new[..., None])
        c = f_ * st["c"] + i_ * z
        n = f_ * st["n"] + i_
        h_out = o * c / jnp.maximum(n, 1.0)
        return ({"c": c, "n": n, "h": h_out, "m": m_new}, h_out)

    seq = (zx.transpose(1, 0, 2, 3), ix.transpose(1, 0, 2, 3),
           fx.transpose(1, 0, 2, 3), ox.transpose(1, 0, 2, 3))
    state, hs = jax.lax.scan(step, state, seq)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, d)
    out = hs.astype(cd) @ params["down"].astype(cd)
    return x + out, state
