"""Pallas TPU kernel: fused multi-hot embedding lookup (fwd + bwd).

TPU adaptation of HugeCTR's CUDA gather + warp-reduce lookup (DESIGN.md §2):
random row access is reformulated as a *streaming one-hot matmul* so the
systolic MXU does the work and the table streams HBM -> VMEM tile by tile.

Forward:   pooled[b, :]  = sum_h table[rows[b, h], :]
           = sum_{v-tiles} count(b, v-tile) @ table[v-tile, :]
Backward:  dtable[v, :]  = sum_b count(b, v)^T @ dpooled[b, :]

``count`` is the per-tile one-hot count matrix built in VREGs from an iota
compare — no gather, no atomics (the GPU version needs atomics for bwd;
the matmul transpose form is deterministic, a strict improvement).

Grid layout: reduction dims are trailing (Pallas TPU requirement for
output-block accumulation): fwd grid = (B/bB, V/bV), bwd grid = (V/bV, B/bB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_matrix(rows_blk: jax.Array, v0: jax.Array, bv: int) -> jax.Array:
    """rows_blk [bB, H] -> one-hot count matrix [bB, bv] (f32)."""
    bb, h = rows_blk.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bb, bv), 1)
    count = jnp.zeros((bb, bv), jnp.float32)

    def body(i, acc):
        rel = rows_blk[:, i] - v0
        hit = (rel[:, None] == iota) & (rows_blk[:, i] >= 0)[:, None]
        return acc + hit.astype(jnp.float32)

    return jax.lax.fori_loop(0, h, body, count)


def _fwd_kernel(rows_ref, table_ref, o_ref, *, bv: int):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    count = _count_matrix(rows_ref[...], v * bv, bv)
    o_ref[...] += jnp.dot(count, table_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def _bwd_kernel(rows_ref, dpool_ref, dtab_ref, *, bv: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        dtab_ref[...] = jnp.zeros_like(dtab_ref)

    v = pl.program_id(0)
    count = _count_matrix(rows_ref[...], v * bv, bv)
    dtab_ref[...] += jnp.dot(count.T,
                             dpool_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)


def lookup_fwd(table: jax.Array, rows: jax.Array, *,
               block_b: int = 128, block_v: int = 512,
               interpret: bool = False) -> jax.Array:
    """``table [V, D]`` (V % block_v == 0), ``rows [B, H]`` -> ``[B, D]`` f32."""
    v, d = table.shape
    b, h = rows.shape
    grid = (b // block_b, v // block_v)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, bv=block_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, h), lambda i, j: (i, 0)),
            pl.BlockSpec((block_v, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(rows, table)


def lookup_bwd(table_shape, rows: jax.Array, dpooled: jax.Array, *,
               block_b: int = 128, block_v: int = 512,
               interpret: bool = False) -> jax.Array:
    """Adjoint: ``rows [B, H]``, ``dpooled [B, D]`` -> ``dtable [V, D]`` f32."""
    v, d = table_shape
    b, h = rows.shape
    grid = (v // block_v, b // block_b)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, bv=block_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, h), lambda j, i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, d), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((v, d), jnp.float32),
        interpret=interpret,
    )(rows, dpooled)
