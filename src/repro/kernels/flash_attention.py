"""Pallas TPU flash attention (fwd + bwd) — the §Perf iter-4 kernel.

The dry-run HLO shows the [cq, ck] score/p tensors dominate the memory
roofline term for every full-attention prefill/train cell (~4 s²-sized
HBM touches per layer even after fusion-friendly restructuring). The only
way below that at the XLA level is a fused kernel: scores live in VMEM,
HBM traffic collapses to streaming q, k, v, o (+ the [S] lse vector).

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * the online-softmax accumulator lives in VMEM scratch, carried across
    the *innermost grid dimension* (Pallas TPU executes the grid
    sequentially over the last axis, so the k-axis must be innermost for
    fwd / dq, and the q-axis innermost for dkv);
  * QK^T and PV run on the MXU with f32 accumulation
    (``preferred_element_type``) — block shapes are multiples of 128;
  * GQA is handled in the BlockSpec index maps (kv block index =
    ``h // group``), no head replication in HBM.

Layouts: q/o ``[BH, S, D]`` (BH = B·Hq flattened), k/v ``[BKV, S, D]``.
``lse`` (logsumexp per row) is saved for the backward pass.

Backward follows the standard two-kernel flash-bwd split:
  * dq kernel: grid (BH, nq, nk) — recompute p from (q, k, lse), then
    ``dq += (p ∘ (dp − D)) @ k``;
  * dkv kernel: grid (BH, nk, nq) — ``dv += pᵀ @ do``,
    ``dk += (p ∘ (dp − D))ᵀ @ q``  (D = rowsum(do ∘ o), precomputed).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG = -1e30


def _mask(pq0, pk0, bq, bk, causal: bool, window: Optional[int]):
    pq = pq0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    pk = pk0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        m &= pk <= pq
    if window is not None:
        m &= pk > pq - window
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_sc, l_sc, acc_sc, *,
                bq: int, bk: int, nk: int, causal: bool,
                window: Optional[int], scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    pq0, pk0 = qi * bq, ki * bk
    visible = jnp.bool_(True)
    if causal:
        visible &= pk0 <= pq0 + bq - 1         # block intersects causal
    if window is not None:
        visible &= pk0 + bk - 1 > pq0 - window

    @pl.when(visible)
    def _body():
        q = q_ref[0]                            # [bq, d]
        k = k_ref[0]                            # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = _mask(pq0, pk0, bq, bk, causal, window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_sc[...] + jnp.log(l)


def flash_fwd(q, k, v, *, causal: bool = True,
              window: Optional[int] = None,
              block_q: int = 512, block_k: int = 512,
              interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """``q [BH, S, D]``, ``k/v [BKV, S, D]`` -> (o ``[BH, S, D]``,
    lse ``[BH, S]``). BH must be a multiple of BKV (GQA group)."""
    bh, s, d = q.shape
    bkv = k.shape[0]
    g = bh // bkv
    bq, bk = min(block_q, s), min(block_k, s)
    nq, nk = s // bq, s // bk
    scale = 1.0 / np.sqrt(d)
    grid = (bh, nq, nk)
    kern = functools.partial(_fwd_kernel, bq=bq, bk=bk, nk=nk,
                             causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pl_scratch((bq,), jnp.float32),
            pl_scratch((bq,), jnp.float32),
            pl_scratch((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def pl_scratch(shape, dtype):
    """VMEM scratch allocation (interpret mode maps it to a host buffer)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dcap_ref, dq_ref,
               acc_sc, *, bq: int, bk: int, nk: int, causal: bool,
               window: Optional[int], scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    pq0, pk0 = qi * bq, ki * bk
    visible = jnp.bool_(True)
    if causal:
        visible &= pk0 <= pq0 + bq - 1
    if window is not None:
        visible &= pk0 + bk - 1 > pq0 - window

    @pl.when(visible)
    def _body():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(pq0, pk0, bq, bk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dp = jax.lax.dot_general(
            do_ref[0], v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - dcap_ref[0][:, None]) * scale
        acc_sc[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _final():
        dq_ref[0] = acc_sc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dcap_ref,
                dk_ref, dv_ref, dk_sc, dv_sc, *, bq: int, bk: int,
                nq: int, g: int, causal: bool, window: Optional[int],
                scale: float):
    # grid = (BKV, nk, nq·g): innermost iterates q blocks × group heads
    ki, qg = pl.program_id(1), pl.program_id(2)
    qi = qg // g

    @pl.when(qg == 0)
    def _init():
        dk_sc[...] = jnp.zeros_like(dk_sc)
        dv_sc[...] = jnp.zeros_like(dv_sc)

    pq0, pk0 = qi * bq, ki * bk
    visible = jnp.bool_(True)
    if causal:
        visible &= pk0 <= pq0 + bq - 1
    if window is not None:
        visible &= pk0 + bk - 1 > pq0 - window

    @pl.when(visible)
    def _body():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(pq0, pk0, bq, bk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dv_sc[...] += jax.lax.dot_general(
            p.astype(do_ref.dtype), do_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(
            do_ref[0], v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - dcap_ref[0][:, None]) * scale
        dk_sc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qg == nq * g - 1)
    def _final():
        dk_ref[0] = dk_sc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_sc[...].astype(dv_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, causal: bool = True,
              window: Optional[int] = None,
              block_q: int = 512, block_k: int = 512,
              interpret: bool = False):
    bh, s, d = q.shape
    bkv = k.shape[0]
    g = bh // bkv
    bq, bk = min(block_q, s), min(block_k, s)
    nq, nk = s // bq, s // bk
    scale = 1.0 / np.sqrt(d)
    dcap = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window, scale=scale),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[pl_scratch((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dcap)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, bq=bq, bk=bk, nq=nq, g=g,
                          causal=causal, window=window, scale=scale),
        grid=(bkv, nk, nq * g),
        in_specs=[
            pl.BlockSpec((1, bq, d),
                         lambda h, j, qg, g=g: (h * g + qg % g, qg // g, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, qg: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, qg: (h, j, 0)),
            pl.BlockSpec((1, bq, d),
                         lambda h, j, qg, g=g: (h * g + qg % g, qg // g, 0)),
            pl.BlockSpec((1, bq),
                         lambda h, j, qg, g=g: (h * g + qg % g, qg // g)),
            pl.BlockSpec((1, bq),
                         lambda h, j, qg, g=g: (h * g + qg % g, qg // g)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda h, j, qg: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, qg: (h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, s, d), k.dtype),
            jax.ShapeDtypeStruct((bkv, s, d), v.dtype),
        ],
        scratch_shapes=[pl_scratch((bk, d), jnp.float32),
                        pl_scratch((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dcap)
    return dq, dk, dv
