"""Jit-ready wrappers around the Pallas kernels (padding + custom_vjp).

``interpret`` defaults to True off-TPU so the same call sites validate on
CPU and run the compiled kernel on hardware.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dot_interaction as _di
from repro.kernels import embedding_lookup as _el


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Fused embedding lookup
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fused_embedding_lookup(table: jax.Array, rows: jax.Array,
                           block_b: int = 128, block_v: int = 512
                           ) -> jax.Array:
    """``table [V, D]``, ``rows [B, H]`` (-1 pad) -> sum-pooled ``[B, D]``."""
    return _lookup_impl(table, rows, block_b, block_v)


def _lookup_impl(table, rows, block_b, block_v):
    v, d = table.shape
    b, h = rows.shape
    bb = min(block_b, _round_up(b, 8))
    bv = min(block_v, _round_up(v, 8))
    vp, bp = _round_up(v, bv), _round_up(b, bb)
    tpad = jnp.pad(table, ((0, vp - v), (0, 0)))
    rpad = jnp.pad(rows, ((0, bp - b), (0, 0)), constant_values=-1)
    out = _el.lookup_fwd(tpad, rpad, block_b=bb, block_v=bv,
                         interpret=_interpret())
    return out[:b]


def _lookup_fwd_rule(table, rows, block_b, block_v):
    return _lookup_impl(table, rows, block_b, block_v), (table.shape, rows)


def _lookup_bwd_rule(block_b, block_v, res, dpooled):
    table_shape, rows = res
    v, d = table_shape
    b, h = rows.shape
    bb = min(block_b, _round_up(b, 8))
    bv = min(block_v, _round_up(v, 8))
    vp, bp = _round_up(v, bv), _round_up(b, bb)
    rpad = jnp.pad(rows, ((0, bp - b), (0, 0)), constant_values=-1)
    dpad = jnp.pad(dpooled.astype(jnp.float32), ((0, bp - b), (0, 0)))
    dtab = _el.lookup_bwd((vp, d), rpad, dpad, block_b=bb, block_v=bv,
                          interpret=_interpret())[:v]
    return dtab.astype(jnp.float32), None


fused_embedding_lookup.defvjp(_lookup_fwd_rule, _lookup_bwd_rule)


def kernel_pool(mega: jax.Array, rows: jax.Array, *, combiner: str = "sum",
                compute_dtype=None) -> jax.Array:
    """Drop-in for ``common.pooled_local_lookup`` backed by the kernel.

    ``rows [B, T, H]`` -> ``[B, T, D]`` (mega-table row ids, -1 pad).
    """
    b, t, h = rows.shape
    out = fused_embedding_lookup(mega, rows.reshape(b * t, h))
    out = out.reshape(b, t, -1)
    if combiner == "mean":
        denom = jnp.maximum((rows >= 0).sum(-1, keepdims=True), 1)
        out = out / denom.astype(out.dtype)
    if compute_dtype is not None:
        out = out.astype(compute_dtype)
    return out


# ---------------------------------------------------------------------------
# HPS cache gather (serving hot path: payload[slots] in one dispatch)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_c", "use_kernel"))
def _cache_gather_jit(payload, slots, block_n, block_c, use_kernel):
    if not use_kernel:
        # off-TPU: the test oracle IS the implementation (the one-hot-
        # matmul kernel only pays off on the MXU; interpreting it on CPU
        # would turn the serving hot path into a dense matmul per query)
        from repro.kernels import ref as _ref
        return _ref.cache_gather_ref(payload, slots)
    from repro.kernels import hps_gather as _hg
    c, d = payload.shape
    n = slots.shape[0]
    bn = min(block_n, _round_up(n, 8))
    bc = min(block_c, _round_up(c, 8))
    cp, np_ = _round_up(c, bc), _round_up(n, bn)
    ppad = jnp.pad(payload, ((0, cp - c), (0, 0)))
    spad = jnp.pad(slots.astype(jnp.int32), (0, np_ - n),
                   constant_values=-1)[:, None]
    out = _hg.gather_rows(ppad, spad, block_n=bn, block_c=bc,
                          interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_c", "use_kernel"))
def _dequant_cache_gather_jit(payload, scales, slots, block_n, block_c,
                              use_kernel):
    """Compressed twin of ``_cache_gather_jit``: ``payload [C, D]`` in its
    storage dtype plus a per-row f32 ``scales [C]`` — one fused
    dequantize-gather dispatch (the scale is applied inside the kernel's
    one-hot matmul, never as a second pass over the rows)."""
    if not use_kernel:
        from repro.kernels import ref as _ref
        return _ref.dequant_gather_ref(payload, scales, slots)
    from repro.kernels import hps_gather as _hg
    c, d = payload.shape
    n = slots.shape[0]
    bn = min(block_n, _round_up(n, 8))
    bc = min(block_c, _round_up(c, 8))
    cp, np_ = _round_up(c, bc), _round_up(n, bn)
    ppad = jnp.pad(payload, ((0, cp - c), (0, 0)))
    scpad = jnp.pad(scales.astype(jnp.float32), (0, cp - c))[:, None]
    spad = jnp.pad(slots.astype(jnp.int32), (0, np_ - n),
                   constant_values=-1)[:, None]
    out = _hg.dequant_gather_rows(ppad, scpad, spad, block_n=bn, block_c=bc,
                                  interpret=_interpret())
    return out[:n]


def pooled_cache_lookup(payload: jax.Array, slots: jax.Array,
                        scales=None) -> jax.Array:
    """Serving-path pooled gather: ``payload [C, D]``, ``slots [B, H]``
    (-1 = hole) -> sum-pooled ``[B, D]``.

    Inference-only (no vjp): the MXU one-hot-matmul kernel on TPU, the
    equivalent XLA take+sum elsewhere — same switch as ``cache_gather``.
    With per-row ``scales`` (int8 payloads) the gather is the fused
    dequantize kernel and the pooling sum stays inside the same jit.
    """
    if scales is not None:
        b, h = slots.shape
        rows = _dequant_cache_gather_jit(payload, scales, slots.reshape(-1),
                                         256, 512, not _interpret())
        return rows.reshape(b, h, -1).sum(axis=1)
    if _interpret():
        from repro.kernels import ref as _ref
        return _ref.embedding_lookup_ref(payload, slots)
    return fused_embedding_lookup(payload, slots)


def cache_gather(payload: jax.Array, slots, *, scales=None,
                 block_n: int = 256, block_c: int = 512,
                 use_kernel=None) -> jax.Array:
    """``payload [C, D]``, ``slots [N]`` (-1 = hole -> zero row) -> ``[N, D]``.

    Jitted wrapper: one device dispatch per call after the first trace,
    so ``DeviceEmbeddingCache.query`` costs O(1) dispatches per batch.
    On TPU the read is the ``hps_gather`` Pallas kernel; elsewhere the
    same jit lowers to the equivalent XLA gather (``use_kernel=True``
    forces the kernel in interpret mode — how tests validate it).
    ``scales`` (per-row f32, int8 payloads) switches to the fused
    dequantize-gather kernel — still one dispatch.
    """
    if use_kernel is None:
        use_kernel = not _interpret()
    if scales is not None:
        return _dequant_cache_gather_jit(payload, scales, jnp.asarray(slots),
                                         block_n, block_c, use_kernel)
    return _cache_gather_jit(payload, jnp.asarray(slots), block_n, block_c,
                             use_kernel)


# ---------------------------------------------------------------------------
# Striped (sharded) L1 payload: stripes [N, Cl, D], slot s at [s % N, s // N]
# ---------------------------------------------------------------------------

def flatten_striped_slots(stripes: jax.Array, slots: jax.Array) -> jax.Array:
    """Remap GLOBAL slot ids onto the row-major flattening of ``stripes``
    (``[N, Cl, D] -> [N * Cl, D]``), preserving -1 holes — the
    single-device ("host shard") view of the striped layout."""
    n_stripes, local_rows = stripes.shape[0], stripes.shape[1]
    return jnp.where(slots >= 0,
                     (slots % n_stripes) * local_rows + slots // n_stripes,
                     -1)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _sharded_gather_flat(stripes, slots, use_kernel):
    flat = stripes.reshape(-1, stripes.shape[-1])
    return _cache_gather_jit(flat, flatten_striped_slots(stripes, slots),
                             256, 512, use_kernel)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _dequant_sharded_gather_flat(stripes, scales, slots, use_kernel):
    flat = stripes.reshape(-1, stripes.shape[-1])
    return _dequant_cache_gather_jit(flat, scales.reshape(-1),
                                     flatten_striped_slots(stripes, slots),
                                     256, 512, use_kernel)


def sharded_cache_gather(stripes: jax.Array, slots, *, scales=None,
                         mesh=None, axis: str = "cache",
                         use_kernel=None) -> jax.Array:
    """``stripes [N, Cl, D]``, GLOBAL ``slots [n]`` (-1 = hole) ->
    ``[n, D]`` f32.

    With a ``mesh`` whose ``axis`` the stripes are laid out over, this is
    the ``hps_gather.sharded_gather_rows`` shard_map (per-device gather +
    one psum — the payload never moves). Without one, the same striped
    layout is served from host-shard stripes in a single jitted dispatch
    via the flattened-slot remap, which is bit-identical row-wise.
    ``scales [N, Cl]`` (int8 payloads) rides the same stripe layout —
    the fused dequantize kernel runs per device, same single psum.
    """
    if use_kernel is None:
        use_kernel = not _interpret()
    slots = jnp.asarray(slots)
    if mesh is not None and axis in mesh.shape and mesh.shape[axis] > 1:
        from repro.kernels import hps_gather as _hg
        if scales is not None:
            return _hg.sharded_dequant_gather_rows(
                stripes, scales, slots, mesh=mesh, axis=axis,
                use_kernel=use_kernel, interpret=_interpret())
        return _hg.sharded_gather_rows(stripes, slots, mesh=mesh, axis=axis,
                                       use_kernel=use_kernel,
                                       interpret=_interpret())
    if scales is not None:
        return _dequant_sharded_gather_flat(stripes, scales, slots,
                                            use_kernel)
    return _sharded_gather_flat(stripes, slots, use_kernel)


def sharded_pooled_lookup(stripes: jax.Array, slots: jax.Array, *,
                          scales=None, mesh=None,
                          axis: str = "cache") -> jax.Array:
    """Pooled serving gather off the striped payload: ``stripes
    [N, Cl, D]``, GLOBAL ``slots [B, H]`` (-1 = hole) -> sum-pooled
    ``[B, D]`` — the striped twin of ``pooled_cache_lookup``."""
    if mesh is not None and axis in mesh.shape and mesh.shape[axis] > 1:
        from repro.kernels import hps_gather as _hg
        b, h = slots.shape
        if scales is not None:
            rows = _hg.sharded_dequant_gather_rows(
                stripes, scales, slots.reshape(-1), mesh=mesh, axis=axis,
                use_kernel=not _interpret(), interpret=_interpret())
        else:
            rows = _hg.sharded_gather_rows(stripes, slots.reshape(-1),
                                           mesh=mesh, axis=axis,
                                           use_kernel=not _interpret(),
                                           interpret=_interpret())
        return rows.reshape(b, h, -1).sum(axis=1)
    flat = stripes.reshape(-1, stripes.shape[-1])
    if scales is not None:
        b, h = slots.shape
        rows = _dequant_cache_gather_jit(
            flat, scales.reshape(-1),
            flatten_striped_slots(stripes, slots).reshape(-1),
            256, 512, not _interpret())
        return rows.reshape(b, h, -1).sum(axis=1)
    return pooled_cache_lookup(flat, flatten_striped_slots(stripes, slots))


# ---------------------------------------------------------------------------
# DLRM dot interaction
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def dot_interaction(x: jax.Array, self_interaction: bool = False,
                    block_b: int = 128) -> jax.Array:
    """``x [B, F, D]`` -> pairwise-dot triangle ``[B, P]``."""
    return _interaction_impl(x, self_interaction, block_b)


def _interaction_impl(x, self_interaction, block_b):
    b, f, d = x.shape
    s = jnp.asarray(_di.selection_matrix(f, self_interaction))
    bb = min(block_b, _round_up(b, 8))
    bp = _round_up(b, bb)
    xpad = jnp.pad(x, ((0, bp - b), (0, 0), (0, 0)))
    out = _di.interaction_fwd(xpad, s, block_b=bb, interpret=_interpret())
    return out[:b]


def _interaction_fwd_rule(x, self_interaction, block_b):
    return _interaction_impl(x, self_interaction, block_b), x


def _interaction_bwd_rule(self_interaction, block_b, x, dtri):
    b, f, d = x.shape
    s = jnp.asarray(_di.selection_matrix(f, self_interaction))
    bb = min(block_b, _round_up(b, 8))
    bp = _round_up(b, bb)
    xpad = jnp.pad(x, ((0, bp - b), (0, 0), (0, 0)))
    dpad = jnp.pad(dtri.astype(jnp.float32), ((0, bp - b), (0, 0)))
    # note: the symmetrization inside the bwd kernel doubles the diagonal,
    # which is exactly d(x.x)/dx = 2x — correct for self_interaction too.
    dx = _di.interaction_bwd(xpad, dpad, s, block_b=bb,
                             interpret=_interpret())[:b]
    return (dx.astype(x.dtype),)


dot_interaction.defvjp(_interaction_fwd_rule, _interaction_bwd_rule)


# ---------------------------------------------------------------------------
# Flash attention (fwd + bwd Pallas kernels)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512) -> jax.Array:
    """``q [B, S, Hq, D]``, ``k/v [B, S, Hkv, D]`` -> ``[B, S, Hq, D]``.

    Scores never touch HBM (VMEM-resident online softmax) — the Pallas
    replacement for ``transformer.chunked_attention`` on TPU.
    """
    o, _ = _flash_fwd_impl(q, k, v, causal, window, block_q, block_k)
    return o


def _bhsd(x):
    """[B, S, H, D] -> [B·H, S, D]."""
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _unbhsd(x, b):
    bh, s, d = x.shape
    return x.reshape(b, bh // b, s, d).transpose(0, 2, 1, 3)


def _flash_fwd_impl(q, k, v, causal, window, block_q, block_k):
    from repro.kernels import flash_attention as fa
    b = q.shape[0]
    o, lse = fa.flash_fwd(_bhsd(q), _bhsd(k), _bhsd(v), causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          interpret=_interpret())
    return _unbhsd(o, b), lse


def _flash_fwd_rule(q, k, v, causal, window, block_q, block_k):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, block_q, block_k)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, window, block_q, block_k, res, do):
    from repro.kernels import flash_attention as fa
    q, k, v, o, lse = res
    b = q.shape[0]
    dq, dk, dv = fa.flash_bwd(
        _bhsd(q), _bhsd(k), _bhsd(v), _bhsd(o), lse, _bhsd(do),
        causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_interpret())
    return _unbhsd(dq, b), _unbhsd(dk, b), _unbhsd(dv, b)


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)
