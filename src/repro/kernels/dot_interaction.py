"""Pallas TPU kernel: DLRM dot-interaction (fwd + bwd).

Computes the strict-lower-triangle of the feature Gram matrix per sample:
``x [B, F, D] -> tri [B, P]``, ``P = F(F-1)/2``.

TPU adaptation (DESIGN.md §2): the GPU version extracts the triangle with
per-thread indexed writes. TPUs dislike gathers, so the compaction is a
**selection matmul**: ``tri = flat_gram [B, F^2] @ S [F^2, P]`` where ``S``
is a constant 0/1 matrix — the MXU eats it and everything stays in one
kernel (gram matmul + compaction) per batch tile.

Backward: ``dgram = dtri @ S^T``; ``dx = (dgram + dgram^T) @ x`` — again all
matmuls, same tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def selection_matrix(f: int, self_interaction: bool = False) -> np.ndarray:
    """0/1 matrix ``[F*F, P]`` selecting the (strict) lower triangle."""
    i, j = np.tril_indices(f, 0 if self_interaction else -1)
    p = len(i)
    s = np.zeros((f * f, p), np.float32)
    s[i * f + j, np.arange(p)] = 1.0
    return s


def _fwd_kernel(x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [bB, F, D]
    gram = jax.lax.dot_general(
        x, x, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # [bB, F, F]
    bb, f, _ = gram.shape
    o_ref[...] = jnp.dot(gram.reshape(bb, f * f), s_ref[...],
                         preferred_element_type=jnp.float32)


def _bwd_kernel(x_ref, dtri_ref, s_ref, dx_ref):
    x = x_ref[...].astype(jnp.float32)            # [bB, F, D]
    bb, f, d = x.shape
    dgram = jnp.dot(dtri_ref[...], s_ref[...].T,
                    preferred_element_type=jnp.float32).reshape(bb, f, f)
    dgram = dgram + dgram.transpose(0, 2, 1)
    dx_ref[...] = jax.lax.dot_general(
        dgram, x, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def interaction_fwd(x: jax.Array, s: jax.Array, *, block_b: int = 128,
                    interpret: bool = False) -> jax.Array:
    b, f, d = x.shape
    p = s.shape[1]
    grid = (b // block_b,)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((f * f, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p), jnp.float32),
        interpret=interpret,
    )(x, s)


def interaction_bwd(x: jax.Array, dtri: jax.Array, s: jax.Array, *,
                    block_b: int = 128, interpret: bool = False) -> jax.Array:
    b, f, d = x.shape
    p = s.shape[1]
    grid = (b // block_b,)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, p), lambda i: (i, 0)),
            pl.BlockSpec((f * f, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, d), jnp.float32),
        interpret=interpret,
    )(x, dtri, s)
