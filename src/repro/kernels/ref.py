"""Pure-jnp oracles for every Pallas kernel (the test ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_lookup_ref(table: jax.Array, rows: jax.Array,
                         combiner: str = "sum") -> jax.Array:
    """``table [V, D]``, ``rows [B, H]`` int32 (-1 = pad) -> ``[B, D]``.

    Sum (or mean) of the selected rows; duplicate ids within a sample
    contribute multiply (count semantics).
    """
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    vecs = jnp.take(table, safe, axis=0)
    vecs = jnp.where(valid[..., None], vecs, 0).astype(jnp.float32)
    pooled = vecs.sum(axis=1)
    if combiner == "mean":
        denom = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        pooled = pooled / denom.astype(pooled.dtype)
    return pooled


def embedding_grad_ref(table_shape, rows: jax.Array,
                       dpooled: jax.Array) -> jax.Array:
    """Adjoint of sum-pooled lookup: scatter-add ``dpooled`` rows."""
    v, d = table_shape
    valid = rows >= 0
    safe = jnp.where(valid, rows, v)  # out-of-range -> dropped
    flat_rows = safe.reshape(-1)
    contrib = jnp.broadcast_to(dpooled[:, None, :],
                               rows.shape + (d,)).reshape(-1, d)
    contrib = jnp.where(valid.reshape(-1, 1), contrib, 0)
    out = jnp.zeros((v + 1, d), jnp.float32).at[flat_rows].add(
        contrib.astype(jnp.float32))
    return out[:v]


def cache_gather_ref(payload: jax.Array, slots: jax.Array) -> jax.Array:
    """``payload [C, D]``, ``slots [N]`` int (-1 = hole) -> ``[N, D]`` f32."""
    valid = slots >= 0
    safe = jnp.where(valid, slots, 0)
    rows = jnp.take(payload, safe, axis=0).astype(jnp.float32)
    return jnp.where(valid[:, None], rows, 0.0)


def dequant_gather_ref(payload: jax.Array, scales: jax.Array,
                       slots: jax.Array) -> jax.Array:
    """``payload [C, D]`` (any storage dtype), ``scales [C]`` f32 per-row
    scale, ``slots [N]`` int (-1 = hole) -> ``[N, D]`` f32 dequantized
    rows: ``payload[s].astype(f32) * scales[s]``."""
    valid = slots >= 0
    safe = jnp.where(valid, slots, 0)
    rows = jnp.take(payload, safe, axis=0).astype(jnp.float32)
    rows = rows * jnp.take(scales, safe).astype(jnp.float32)[:, None]
    return jnp.where(valid[:, None], rows, 0.0)


def dequant_sharded_gather_ref(stripes: jax.Array, scales: jax.Array,
                               slots: jax.Array) -> jax.Array:
    """Striped dequantizing gather oracle: ``stripes [N, Cl, D]``,
    ``scales [N, Cl]`` f32, ``slots [n]`` GLOBAL slot ids -> ``[n, D]``
    f32; slot ``s`` lives at ``stripes[s % N, s // N]``."""
    n_stripes = stripes.shape[0]
    valid = slots >= 0
    safe = jnp.where(valid, slots, 0)
    rows = stripes[safe % n_stripes, safe // n_stripes].astype(jnp.float32)
    sc = scales[safe % n_stripes, safe // n_stripes].astype(jnp.float32)
    return jnp.where(valid[:, None], rows * sc[:, None], 0.0)


def sharded_gather_ref(stripes: jax.Array, slots: jax.Array) -> jax.Array:
    """Striped-payload gather oracle: ``stripes [N, Cl, D]``, ``slots
    [n]`` GLOBAL slot ids (-1 = hole) -> ``[n, D]`` f32; global slot
    ``s`` lives at ``stripes[s % N, s // N]``."""
    n_stripes = stripes.shape[0]
    valid = slots >= 0
    safe = jnp.where(valid, slots, 0)
    rows = stripes[safe % n_stripes, safe // n_stripes].astype(jnp.float32)
    return jnp.where(valid[:, None], rows, 0.0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window=None) -> jax.Array:
    """Naive softmax attention oracle: ``q [B, S, Hq, D]``,
    ``k/v [B, S, Hkv, D]`` -> ``[B, S, Hq, D]`` (GQA by head grouping)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    sc = sc / jnp.sqrt(jnp.asarray(d, jnp.float32))
    i = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if window is not None:
        mask &= i[None, :] > i[:, None] - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, d).astype(q.dtype)


def dot_interaction_ref(x: jax.Array, *, self_interaction: bool = False
                        ) -> jax.Array:
    """DLRM pairwise dot interaction.

    ``x [B, F, D]`` -> strict lower triangle of ``x @ x^T``: ``[B, F(F-1)/2]``
    (or with diagonal when ``self_interaction``).
    """
    gram = jnp.einsum("bfd,bgd->bfg", x.astype(jnp.float32),
                      x.astype(jnp.float32))
    f = x.shape[1]
    i, j = jnp.tril_indices(f, 0 if self_interaction else -1)
    return gram[:, i, j]
