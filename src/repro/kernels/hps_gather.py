"""Pallas TPU kernel: batched row gather from the HPS L1 device payload.

The serving hot path reads ``payload[slots]`` for a whole query at once.
Like ``embedding_lookup``, random row access is reformulated as a
streaming one-hot matmul so the MXU does the work and the payload streams
HBM -> VMEM tile by tile — no per-row gather, no host round-trips:

    out[n, :] = sum_{c-tiles} onehot(slots[n], c-tile) @ payload[c-tile, :]

Negative slots (query padding / ids not resident) produce zero rows, which
the cache's overflow path overwrites separately.

Grid layout: the payload-tile reduction dim is trailing (Pallas TPU
requirement for output-block accumulation): grid = (N/bN, C/bC).

``sharded_gather_rows`` is the multi-device entry point for the striped
L1 payload (companion HPS paper, arXiv 2210.08804 §4): slot ``s`` lives
on stripe ``s % n_stripes``, stripes are laid out over a 1-D mesh axis,
and every device runs the same local gather over the stripes it owns —
non-owned slots become holes (zero rows) — so ONE ``psum`` reassembles
the full batch. The payload never leaves its owning device; only the
``[n, D]`` result crosses the interconnect.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.kernels.ops import _round_up


def _gather_kernel(slots_ref, payload_ref, o_ref, *, bc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    slots = slots_ref[...][:, 0]                      # [bN]
    bn = slots.shape[0]
    rel = slots - c * bc
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bc), 1)
    onehot = ((rel[:, None] == iota) & (slots >= 0)[:, None])
    o_ref[...] += jnp.dot(onehot.astype(jnp.float32),
                          payload_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def gather_rows(payload: jax.Array, slots: jax.Array, *,
                block_n: int = 256, block_c: int = 512,
                interpret: bool = False) -> jax.Array:
    """``payload [C, D]`` (C % block_c == 0), ``slots [N, 1]`` int32
    (N % block_n == 0, -1 = hole) -> ``[N, D]`` f32."""
    c, d = payload.shape
    n = slots.shape[0]
    grid = (n // block_n, c // block_c)
    return pl.pallas_call(
        functools.partial(_gather_kernel, bc=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(slots, payload)


def _dq_gather_kernel(slots_ref, payload_ref, scales_ref, o_ref, *, bc: int):
    """Fused dequantize-gather: the per-row scale folds into the one-hot
    BEFORE the matmul, so ``onehot_scaled @ q_tile`` yields already-
    dequantized f32 rows in the same single MXU pass — the compressed
    tile never materializes at f32 width in VMEM."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    slots = slots_ref[...][:, 0]                      # [bN]
    bn = slots.shape[0]
    rel = slots - c * bc
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bc), 1)
    onehot = ((rel[:, None] == iota) & (slots >= 0)[:, None])
    scales = scales_ref[...][:, 0]                    # [bC] f32
    scaled = onehot.astype(jnp.float32) * scales[None, :]
    o_ref[...] += jnp.dot(scaled,
                          payload_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def dequant_gather_rows(payload: jax.Array, scales: jax.Array,
                        slots: jax.Array, *,
                        block_n: int = 256, block_c: int = 512,
                        interpret: bool = False) -> jax.Array:
    """``payload [C, D]`` compressed rows (int8/f16; C % block_c == 0),
    ``scales [C, 1]`` f32 per-row dequant scale, ``slots [N, 1]`` int32
    (N % block_n == 0, -1 = hole) -> ``[N, D]`` dequantized f32.

    One dispatch: scale is applied inside the gather matmul (see
    ``_dq_gather_kernel``), not as a second elementwise pass."""
    c, d = payload.shape
    n = slots.shape[0]
    grid = (n // block_n, c // block_c)
    return pl.pallas_call(
        functools.partial(_dq_gather_kernel, bc=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_c, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(slots, payload, scales)


def _local_stripe_gather(stripes: jax.Array, slots: jax.Array,
                         n_stripes: int, axis: str, *,
                         use_kernel: bool, block_n: int, block_c: int,
                         interpret: bool) -> jax.Array:
    """Per-device body: gather the slots whose stripe this device owns.

    ``stripes [k, Cl, D]`` is the local block of the striped payload
    (``k = n_stripes / mesh_axis_size``); global slot ``s`` maps to
    stripe ``s % n_stripes``, local row ``s // n_stripes``. Slots owned
    elsewhere turn into -1 holes, so the cross-device ``psum`` of the
    per-device gathers is exact (holes contribute zero rows).
    """
    k, cl, d = stripes.shape
    idx = jax.lax.axis_index(axis)
    first = idx * k                                   # first stripe owned
    stripe_of = jnp.where(slots >= 0, slots % n_stripes, -1)
    mine = (stripe_of >= first) & (stripe_of < first + k)
    flat = stripes.reshape(k * cl, d)
    local = (stripe_of - first) * cl + slots // n_stripes
    local = jnp.where(mine, local, -1)
    if not use_kernel:
        valid = local >= 0
        rows = jnp.take(flat, jnp.where(valid, local, 0), axis=0)
        rows = jnp.where(valid[:, None], rows, 0.0).astype(jnp.float32)
    else:
        n = local.shape[0]
        bn = min(block_n, _round_up(n, 8))
        bc = min(block_c, _round_up(k * cl, 8))
        npad, cpad = _round_up(n, bn), _round_up(k * cl, bc)
        fpad = jnp.pad(flat, ((0, cpad - k * cl), (0, 0)))
        lpad = jnp.pad(local.astype(jnp.int32), (0, npad - n),
                       constant_values=-1)[:, None]
        rows = gather_rows(fpad, lpad, block_n=bn, block_c=bc,
                           interpret=interpret)[:n]
    return jax.lax.psum(rows, axis)


def sharded_gather_rows(stripes: jax.Array, slots: jax.Array, *,
                        mesh: Mesh, axis: str = "cache",
                        use_kernel: bool = True, block_n: int = 256,
                        block_c: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Striped-payload gather: ``stripes [N, Cl, D]`` laid out over the
    mesh's ``axis`` (stripe ``i`` on device ``i * size / N``), ``slots
    [n]`` GLOBAL slot ids (-1 = hole) -> ``[n, D]`` f32, replicated.

    Each device gathers only the stripes it holds (one kernel dispatch)
    and one ``psum`` over ``axis`` combines the partial batches.
    """
    n_stripes = stripes.shape[0]
    size = mesh.shape[axis]
    if n_stripes % size:
        raise ValueError(
            f"{n_stripes} stripes do not tile mesh axis '{axis}' "
            f"of size {size}")
    body = functools.partial(
        _local_stripe_gather, n_stripes=n_stripes, axis=axis,
        use_kernel=use_kernel, block_n=block_n, block_c=block_c,
        interpret=interpret)
    spec = P(axis) if size > 1 else P()
    fn = compat.shard_map(body, mesh=compat.shard_map_mesh(mesh),
                          in_specs=(spec, P()), out_specs=P(),
                          check_vma=False)
    return fn(stripes, slots.astype(jnp.int32))


def _local_stripe_dequant_gather(stripes: jax.Array, scales: jax.Array,
                                 slots: jax.Array, n_stripes: int,
                                 axis: str, *, use_kernel: bool,
                                 block_n: int, block_c: int,
                                 interpret: bool) -> jax.Array:
    """Per-device body of the compressed striped gather: identical slot
    routing to ``_local_stripe_gather``, but the local dispatch is the
    fused dequantize-gather kernel (``scales [k, Cl]`` shards with its
    stripes, so dequantization happens before the SAME single ``psum`` —
    no extra collectives)."""
    k, cl, d = stripes.shape
    idx = jax.lax.axis_index(axis)
    first = idx * k
    stripe_of = jnp.where(slots >= 0, slots % n_stripes, -1)
    mine = (stripe_of >= first) & (stripe_of < first + k)
    flat = stripes.reshape(k * cl, d)
    flat_sc = scales.reshape(k * cl).astype(jnp.float32)
    local = (stripe_of - first) * cl + slots // n_stripes
    local = jnp.where(mine, local, -1)
    if not use_kernel:
        valid = local >= 0
        safe = jnp.where(valid, local, 0)
        rows = jnp.take(flat, safe, axis=0).astype(jnp.float32)
        rows = rows * jnp.take(flat_sc, safe)[:, None]
        rows = jnp.where(valid[:, None], rows, 0.0)
    else:
        n = local.shape[0]
        bn = min(block_n, _round_up(n, 8))
        bc = min(block_c, _round_up(k * cl, 8))
        npad, cpad = _round_up(n, bn), _round_up(k * cl, bc)
        fpad = jnp.pad(flat, ((0, cpad - k * cl), (0, 0)))
        spad = jnp.pad(flat_sc, (0, cpad - k * cl))[:, None]
        lpad = jnp.pad(local.astype(jnp.int32), (0, npad - n),
                       constant_values=-1)[:, None]
        rows = dequant_gather_rows(fpad, spad, lpad, block_n=bn,
                                   block_c=bc, interpret=interpret)[:n]
    return jax.lax.psum(rows, axis)


def sharded_dequant_gather_rows(stripes: jax.Array, scales: jax.Array,
                                slots: jax.Array, *,
                                mesh: Mesh, axis: str = "cache",
                                use_kernel: bool = True,
                                block_n: int = 256, block_c: int = 512,
                                interpret: bool = False) -> jax.Array:
    """Compressed striped gather: ``stripes [N, Cl, D]`` (int8/f16) and
    ``scales [N, Cl]`` f32 both laid out over the mesh's ``axis``,
    ``slots [n]`` GLOBAL slot ids (-1 = hole) -> ``[n, D]`` dequantized
    f32, replicated. Same one-psum reassembly as ``sharded_gather_rows``;
    the scale vector rides its stripe shard, so compression adds zero
    collectives."""
    n_stripes = stripes.shape[0]
    size = mesh.shape[axis]
    if n_stripes % size:
        raise ValueError(
            f"{n_stripes} stripes do not tile mesh axis '{axis}' "
            f"of size {size}")
    body = functools.partial(
        _local_stripe_dequant_gather, n_stripes=n_stripes, axis=axis,
        use_kernel=use_kernel, block_n=block_n, block_c=block_c,
        interpret=interpret)
    spec = P(axis) if size > 1 else P()
    fn = compat.shard_map(body, mesh=compat.shard_map_mesh(mesh),
                          in_specs=(spec, spec, P()), out_specs=P(),
                          check_vma=False)
    return fn(stripes, scales, slots.astype(jnp.int32))
