"""Pallas TPU kernel: batched row gather from the HPS L1 device payload.

The serving hot path reads ``payload[slots]`` for a whole query at once.
Like ``embedding_lookup``, random row access is reformulated as a
streaming one-hot matmul so the MXU does the work and the payload streams
HBM -> VMEM tile by tile — no per-row gather, no host round-trips:

    out[n, :] = sum_{c-tiles} onehot(slots[n], c-tile) @ payload[c-tile, :]

Negative slots (query padding / ids not resident) produce zero rows, which
the cache's overflow path overwrites separately.

Grid layout: the payload-tile reduction dim is trailing (Pallas TPU
requirement for output-block accumulation): grid = (N/bN, C/bC).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(slots_ref, payload_ref, o_ref, *, bc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    slots = slots_ref[...][:, 0]                      # [bN]
    bn = slots.shape[0]
    rel = slots - c * bc
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bc), 1)
    onehot = ((rel[:, None] == iota) & (slots >= 0)[:, None])
    o_ref[...] += jnp.dot(onehot.astype(jnp.float32),
                          payload_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def gather_rows(payload: jax.Array, slots: jax.Array, *,
                block_n: int = 256, block_c: int = 512,
                interpret: bool = False) -> jax.Array:
    """``payload [C, D]`` (C % block_c == 0), ``slots [N, 1]`` int32
    (N % block_n == 0, -1 = hole) -> ``[N, D]`` f32."""
    c, d = payload.shape
    n = slots.shape[0]
    grid = (n // block_n, c // block_c)
    return pl.pallas_call(
        functools.partial(_gather_kernel, bc=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(slots, payload)
