"""Configuration dataclasses for the repro framework.

Everything the launcher needs to build a model, its sharding, and its
input specs is declared here. Configs are plain frozen dataclasses so they
hash/compare cleanly and can be embedded in dry-run artifact names.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Embedding tables (the paper's core object)
# ---------------------------------------------------------------------------

#: Communication/placement strategies from the paper (§1).
LOCALIZED = "localized"      # whole table on one device, all-to-all after pool
DISTRIBUTED = "distributed"  # rows striped across all devices (MP)
HYBRID = "hybrid"            # hot rows replicated (DP), cold rows striped (MP)
DATA_PARALLEL = "data_parallel"  # fully replicated (small tables)


@dataclasses.dataclass(frozen=True)
class EmbeddingTableConfig:
    """One categorical feature's embedding table."""
    name: str
    vocab_size: int
    dim: int
    #: number of ids per sample for this feature (1 = one-hot)
    hotness: int = 1
    #: "sum" | "mean" | "concat" (concat only valid for hotness == 1)
    combiner: str = "sum"
    #: placement strategy; "auto" lets the planner decide
    strategy: str = "auto"
    #: fraction of vocab treated as hot for HYBRID (planner may override)
    hot_fraction: float = 0.05

    @property
    def param_count(self) -> int:
        return self.vocab_size * self.dim


@dataclasses.dataclass(frozen=True)
class SparseGroupConfig:
    """One extra N-group embedding collection beyond the primary tables.

    The graph API lowers each independently-dimensioned
    ``SparseEmbedding`` group past the first to one of these; every
    group becomes its own ``EmbeddingCollection`` at build time and its
    own HPS table set at deploy time. ``name`` is the group's graph
    tensor name (its top); all tables in a group share ``dim``.
    """
    name: str
    tables: Tuple[EmbeddingTableConfig, ...]
    dim: int


# ---------------------------------------------------------------------------
# Recsys models (DLRM / DCN / DeepFM / WDL)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                       # "dlrm"|"dcn"|"deepfm"|"wdl"|"graph"
    tables: Tuple[EmbeddingTableConfig, ...]
    num_dense_features: int
    bottom_mlp: Tuple[int, ...]
    top_mlp: Tuple[int, ...]
    embedding_dim: int               # shared D across tables (DLRM-style)
    num_cross_layers: int = 3        # DCN only
    dtype: str = "bf16"              # compute dtype
    #: model == "graph" only: the serialized dense-layer DAG the generic
    #: compiler executes — one ("inputs", dense, emb, wide) header plus
    #: one (type, bottoms, top, attrs) tuple per layer (see
    #: models/recsys/dense_graph.py). Canonical recipes keep ().
    dense_graph: Tuple = ()
    #: model == "graph" only: whether a dim-1 wide twin branch exists
    #: (wdl/deepfm imply it via their model name)
    wide_branch: bool = False
    #: model == "graph" only: extra independently-dimensioned embedding
    #: groups beyond the primary ``tables`` (N-group SparseEmbedding
    #: lowering). Canonical recipes keep ().
    extra_groups: Tuple[SparseGroupConfig, ...] = ()

    @property
    def num_tables(self) -> int:
        return len(self.tables)

    @property
    def all_tables(self) -> Tuple[EmbeddingTableConfig, ...]:
        """Primary tables plus every extra group's tables, in the
        declared order — the full ``cat`` column layout."""
        out = tuple(self.tables)
        for g in self.extra_groups:
            out += tuple(g.tables)
        return out

    @property
    def total_embedding_params(self) -> int:
        return sum(t.param_count for t in self.all_tables)


def recsys_config_to_dict(cfg: RecsysConfig) -> Dict:
    """Plain-JSON form of a RecsysConfig (tuples become lists).

    Default-valued graph fields are omitted so canonical configs keep
    the exact dict (and content hash) they had before the generic
    compiler existed — pre-existing graph.json / ps.json bundles keep
    verifying."""
    d = dataclasses.asdict(cfg)
    if not d["dense_graph"]:
        del d["dense_graph"]
    if not d["wide_branch"]:
        del d["wide_branch"]
    if not d["extra_groups"]:
        del d["extra_groups"]
    return d


def recsys_config_from_dict(d: Dict) -> RecsysConfig:
    tables = tuple(EmbeddingTableConfig(**t) for t in d["tables"])
    rest = {k: v for k, v in d.items() if k != "tables"}
    for k in ("bottom_mlp", "top_mlp"):
        rest[k] = tuple(rest[k])
    if rest.get("dense_graph"):
        from repro.models.recsys.dense_graph import dense_graph_from_jsonable
        rest["dense_graph"] = dense_graph_from_jsonable(rest["dense_graph"])
    if rest.get("extra_groups"):
        rest["extra_groups"] = tuple(
            SparseGroupConfig(
                name=g["name"],
                tables=tuple(EmbeddingTableConfig(**t)
                             for t in g["tables"]),
                dim=g["dim"])
            for g in rest["extra_groups"])
    return RecsysConfig(tables=tables, **rest)


def recsys_config_hash(cfg: RecsysConfig) -> str:
    """Stable content hash, embedded in serialized graphs so a reloaded
    graph can prove it lowers to the exact same model."""
    blob = json.dumps(recsys_config_to_dict(cfg), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# HPS deployment config (the ps.json analogue: everything the serving
# launcher needs to stand up an InferenceServer with no training objects)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HPSConfig:
    """One deployed model's parameter-server spec.

    Paths are relative to the directory holding the ps.json file, so the
    whole deployment bundle (ps.json + graph.json + dense weights + PDB
    files) is relocatable.
    """
    model: str
    pdb_root: str
    graph_path: str
    dense_weights_path: str
    tables: Tuple[EmbeddingTableConfig, ...]
    #: wide models (wdl/deepfm) serve a second, dim-1 HPS
    wide: bool = False
    cache_capacity: int = 4096
    cache_shards: int = 1
    refresh_budget: int = 512
    max_batch: int = 1024
    #: L1 storage precision: "f32" (bit-exact), "f16", or "int8"
    #: (per-row absmax scales; dequantized inside the gather kernel)
    payload_dtype: str = "f32"
    config_hash: str = ""

    def __post_init__(self):
        if self.payload_dtype not in ("f32", "f16", "int8"):
            raise ValueError(
                f"payload_dtype must be one of ('f32', 'f16', 'int8'), "
                f"got {self.payload_dtype!r}")


def hps_config_to_dict(cfg: HPSConfig) -> Dict:
    d = dataclasses.asdict(cfg)
    d["format"] = "repro-ps-v1"
    return d


def hps_config_from_dict(d: Dict) -> HPSConfig:
    if d.get("format", "repro-ps-v1") != "repro-ps-v1":
        raise ValueError(f"unknown ps config format {d.get('format')!r}")
    tables = tuple(EmbeddingTableConfig(**t) for t in d["tables"])
    rest = {k: v for k, v in d.items() if k not in ("tables", "format")}
    return HPSConfig(tables=tables, **rest)


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    """A multi-model deployment bundle: several models' parameter-server
    specs served from ONE storage backend process.

    All member configs share the same ``pdb_root`` (the PDB namespaces
    tables per model) and, at serve time, one VolatileDB and one message
    bus — the GPU-specialized inference parameter server's deployment
    unit (arXiv 2210.08804).
    """
    models: Tuple[HPSConfig, ...]

    def __post_init__(self):
        names = [m.model for m in self.models]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names in ensemble: {names}")
        roots = {m.pdb_root for m in self.models}
        if len(roots) != 1:
            raise ValueError(
                f"ensemble members must share one pdb_root, got {roots}")


def ensemble_config_to_dict(cfg: EnsembleConfig) -> Dict:
    return {"format": "repro-ps-ensemble-v1",
            "models": [hps_config_to_dict(m) for m in cfg.models]}


def ensemble_config_from_dict(d: Dict) -> EnsembleConfig:
    if d.get("format") != "repro-ps-ensemble-v1":
        raise ValueError(f"unknown ensemble format {d.get('format')!r}")
    return EnsembleConfig(models=tuple(hps_config_from_dict(m)
                                       for m in d["models"]))


def ps_config_from_dict(d: Dict):
    """Format-sniffing loader: a ps.json holds either one model's
    :class:`HPSConfig` or a multi-model :class:`EnsembleConfig`."""
    if d.get("format") == "repro-ps-ensemble-v1":
        return ensemble_config_from_dict(d)
    return hps_config_from_dict(d)


# ---------------------------------------------------------------------------
# LM-family architectures (assigned pool)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                      # "dense"|"moe"|"audio"|"vlm"|"ssm"|"hybrid"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm" | "nonparam_ln"
    activation: str = "swiglu"       # "swiglu" | "gelu" | "relu_sq" | "geglu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    # hybrid/ssm block pattern: e.g. ("rglru","rglru","local_attn") repeated
    block_pattern: Tuple[str, ...] = ("attn",)
    local_attn_window: int = 2048    # for "local_attn" blocks
    # enc-dec (seamless): encoder layers, 0 = decoder-only
    encoder_layers: int = 0
    # modality frontend stub: ("audio", frames_dim) / ("vision", patch_dim)
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_seq: int = 0            # stub frontend sequence length
    #: whether full quadratic attention is the only mixer (skips long_500k)
    full_attention_only: bool = True
    dtype: str = "bf16"
    # sub-quadratic decode support (SSM state / bounded-window KV)
    # derived: set in configs where applicable

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def dense_param_count(self) -> int:
        """Rough non-embedding parameter count (for 6ND napkin math)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.moe is not None:
            ffn = self.moe.num_experts * 3 * d * self.moe.expert_d_ff \
                + d * self.moe.num_experts
        elif self.activation in ("swiglu", "geglu"):
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        total_layers = L + self.encoder_layers
        return total_layers * (attn + ffn)

    @property
    def active_param_count(self) -> int:
        """Active (per-token) params — differs from dense for MoE."""
        if self.moe is None:
            return self.dense_param_count
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        ffn = self.moe.top_k * 3 * d * self.moe.expert_d_ff \
            + d * self.moe.num_experts
        return L * (attn + ffn)

    @property
    def embedding_param_count(self) -> int:
        n = self.vocab_size * self.d_model
        return n if self.tie_embeddings else 2 * n


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                 # "train_4k" | "prefill_32k" | ...
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

LM_SHAPE_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(cfg: LMConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (see DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.full_attention_only:
        return False
    return True


# ---------------------------------------------------------------------------
# Embedding Training Cache (online training) knobs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ETCParams:
    """Embedding Training Cache knobs (``Solver(etc=ETCParams(...))``).

    Declares that ``fit()`` should train the embedding tables through the
    ETC — a fixed-capacity device row cache staged against a host/disk
    parameter server — instead of holding every table in device memory
    (the paper's §1 "Online training" / incremental-training mode).

    * ``cache_rows`` — device cache capacity per table (rows).
    * ``ps`` — parameter-server tier: ``"staged"`` (host memory) or
      ``"cached"`` (disk memmaps under ``ps_root``).
    * ``ps_root`` — directory for the cached PS tables (required when
      ``ps="cached"``); reopening the same root resumes training from
      the flushed state.
    * ``ps_shards`` — staged-PS shard count (simulated cluster spread).
    * ``passes`` — keyset-staged passes per ``fit()``: the step budget
      splits into this many passes, each pass pre-stages its keyset
      (the hottest ids of its data window) before stepping and flushes
      the cache back to the PS at the pass boundary — HugeCTR's
      ``wdl_etc`` source-per-pass workflow.

    JSON round-trips through ``Solver`` serialization (graph.json), so a
    deployed graph remembers how it was trained.
    """
    cache_rows: int = 4096
    ps: str = "staged"
    ps_root: Optional[str] = None
    ps_shards: int = 1
    passes: int = 1

    def __post_init__(self):
        if self.ps not in ("staged", "cached"):
            raise ValueError(
                f"ETCParams.ps must be 'staged' or 'cached', got "
                f"{self.ps!r}")
        if self.cache_rows <= 0:
            raise ValueError(
                f"ETCParams.cache_rows must be positive, got "
                f"{self.cache_rows}")
        if self.ps_shards <= 0:
            raise ValueError(
                f"ETCParams.ps_shards must be positive, got "
                f"{self.ps_shards}")
        if self.passes <= 0:
            raise ValueError(
                f"ETCParams.passes must be positive, got {self.passes}")
        if self.ps == "cached" and not self.ps_root:
            raise ValueError(
                "ETCParams(ps='cached') needs ps_root (the memmap "
                "directory)")


# ---------------------------------------------------------------------------
# Training hyper-params
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    dense_optimizer: str = "adamw"    # "sgd" | "adam" | "adamw"
    sparse_optimizer: str = "rowwise_adagrad"  # HugeCTR's default for tables
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    mixed_precision: bool = True      # bf16 compute, f32 master weights
    grad_allreduce_dtype: str = "f32" # "bf16" enables compressed all-reduce
    remat: str = "none"               # "none" | "full" | "dots"
    microbatches: int = 1             # grad accumulation splits


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
