"""Wide&Deep-on-Criteo expressed as a graph-API recipe (paper §2).

The recipe the two-slot facade could never express: TWO embedding
branches (deep dim-16 tables + dim-1 wide twins), a deep tower with its
own logit head, a wide linear head over [dense, wide], and a sigmoid
terminal summing both logits.
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES, RECSYS_ARCHS

ARCH_ID = "wdl-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        top = (32, 16)
    else:
        sizes = list(CRITEO_VOCAB_SIZES)
        top = (1024, 1024)
    name = ARCH_ID + ("-smoke" if smoke else "")
    names = [f"C{i + 1}" for i in range(len(sizes))]
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(vocab_sizes=sizes, dim=16, top_name="emb",
                          table_names=names))
    m.add(SparseEmbedding(vocab_sizes=sizes, dim=1, top_name="wide"))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["deep_out"],
                     units=tuple(top) + (1,)))
    m.add(DenseLayer("mlp", ["dense", "wide"], ["wide_out"],
                     units=(1,)))
    m.add(DenseLayer("sigmoid", ["wide_out", "deep_out"], ["prob"]))
    return m


CONFIG = RECSYS_ARCHS[ARCH_ID]
#: the graph lowers to the same config (parity-tested)
GRAPH_CONFIG = build_model().to_recsys_config()
