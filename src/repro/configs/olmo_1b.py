"""Config module for ``--arch olmo-1b`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "olmo-1b"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
