"""Two-tower residual CTR model — a NOVEL graph, no recipe code.

The scenario the generic dense-graph compiler unlocks: a user tower
(dense features) and an item tower (pooled embeddings) meet in an
elementwise interaction; the dot-product logit and a residual MLP head
are summed by the sigmoid terminal. None of this matches a canonical
recipe — ``to_recsys_config()`` lowers it to ``model="graph"`` with the
DAG embedded, and training, JSON round-trip, deployment, config-driven
serving and numpy export all run through the same compiled program with
zero per-architecture code.

Exercises the extended layer vocabulary: ``multiply``, ``reduce_sum``,
multi-input ``concat``, ``add`` (residual), ``relu``.
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES

ARCH_ID = "twotower-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        dim, tower, head = 16, (32, 16), (16,)
    else:
        sizes = list(CRITEO_VOCAB_SIZES)
        dim, tower, head = 64, (256, 64), (64,)
    name = ARCH_ID + ("-smoke" if smoke else "")
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(
        vocab_sizes=sizes, dim=dim, top_name="emb",
        table_names=[f"C{i + 1}" for i in range(len(sizes))]))
    # the two towers project into a shared space
    m.add(DenseLayer("mlp", ["dense"], ["user"], units=tower,
                     final_activation=True))
    m.add(DenseLayer("mlp", ["emb"], ["item"], units=tower,
                     final_activation=True))
    # tower match: elementwise product, reduced to a dot-product logit
    m.add(DenseLayer("multiply", ["user", "item"], ["inter"]))
    m.add(DenseLayer("reduce_sum", ["inter"], ["dot"]))
    # residual head over [user, item, interaction]
    m.add(DenseLayer("concat", ["user", "item", "inter"], ["feats"]))
    m.add(DenseLayer("mlp", ["feats"], ["h"], units=head,
                     final_activation=True))
    m.add(DenseLayer("mlp", ["h"], ["h2"], units=head))
    m.add(DenseLayer("add", ["h", "h2"], ["res"]))
    m.add(DenseLayer("relu", ["res"], ["res_act"]))
    m.add(DenseLayer("mlp", ["res_act"], ["head"], units=(1,)))
    m.add(DenseLayer("sigmoid", ["dot", "head"], ["prob"]))
    return m
