"""Config module for ``--arch xlstm-125m`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "xlstm-125m"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
