"""Config module for ``--arch minitron-4b`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "minitron-4b"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
