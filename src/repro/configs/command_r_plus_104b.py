"""Config module for ``--arch command-r-plus-104b`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "command-r-plus-104b"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
