"""Config module for ``--arch dcn-criteo`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "dcn-criteo"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
