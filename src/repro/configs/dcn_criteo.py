"""DCN-on-Criteo expressed as a graph-API recipe (paper §2).

Cross network + deep tower over the shared feature concat, combined by
a 1-unit head — declared with ``model.add(...)`` and lowered onto the
registry config (parity-tested).
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES, RECSYS_ARCHS

ARCH_ID = "dcn-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        top = (32, 16)
    else:
        sizes = list(CRITEO_VOCAB_SIZES)
        top = (1024, 1024)
    name = ARCH_ID + ("-smoke" if smoke else "")
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(
        vocab_sizes=sizes, dim=16, top_name="emb",
        table_names=[f"C{i + 1}" for i in range(len(sizes))]))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("cross", ["flat"], ["crossed"], num_layers=6))
    m.add(DenseLayer("mlp", ["flat"], ["deep"], units=top))
    m.add(DenseLayer("concat", ["crossed", "deep"], ["both"]))
    m.add(DenseLayer("mlp", ["both"], ["logit"], units=(1,)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m


CONFIG = RECSYS_ARCHS[ARCH_ID]
#: the graph lowers to the same config (parity-tested)
GRAPH_CONFIG = build_model().to_recsys_config()
