"""Config module for ``--arch seamless-m4t-large-v2`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "seamless-m4t-large-v2"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
