"""Config module for ``--arch granite-moe-3b-a800m`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "granite-moe-3b-a800m"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
