"""NeuMF-style CTR model — THREE embedding groups, three dims.

The architecture the N-group lowering unlocks: a deep (MLP) branch over
dim-16 embeddings, a GMF-style multiplicative interaction driven by a
separate dim-8 embedding group, and a small dim-4 context group feeding
the head directly. No canonical recipe matches — ``to_recsys_config()``
lowers it to ``model="graph"`` with one ``EmbeddingCollection`` (and,
at deploy time, one HPS table set) per group; the cat input carries the
groups' columns back-to-back in declaration order.
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES

ARCH_ID = "neumf-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        deep_sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        gmf_sizes = [min(v, 500) for v in CRITEO_VOCAB_SIZES[6:10]]
        ctx_sizes = [24, 16]
        d_deep, d_gmf, d_ctx = 16, 8, 4
        tower, head = (32, 16), (16,)
    else:
        deep_sizes = list(CRITEO_VOCAB_SIZES[:13])
        gmf_sizes = list(CRITEO_VOCAB_SIZES[13:22])
        ctx_sizes = list(CRITEO_VOCAB_SIZES[22:])
        d_deep, d_gmf, d_ctx = 64, 16, 8
        tower, head = (256, 64), (64,)
    name = ARCH_ID + ("-smoke" if smoke else "")
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    # first group is the primary collection; each further group gets its
    # own collection, param key and cat column span
    m.add(SparseEmbedding(
        vocab_sizes=deep_sizes, dim=d_deep, top_name="deep",
        table_names=[f"C{i + 1}" for i in range(len(deep_sizes))]))
    m.add(SparseEmbedding(
        vocab_sizes=gmf_sizes, dim=d_gmf, top_name="gmf"))
    m.add(SparseEmbedding(
        vocab_sizes=ctx_sizes, dim=d_ctx, top_name="ctx"))
    # deep (MLP) branch over dense + dim-16 embeddings
    m.add(DenseLayer("mlp", ["dense", "deep"], ["deep_h"], units=tower,
                     final_activation=True))
    # GMF-style branch: project both sides into a shared space, multiply
    m.add(DenseLayer("mlp", ["dense"], ["u"], units=(16,),
                     final_activation=True))
    m.add(DenseLayer("mlp", ["gmf"], ["v"], units=(16,),
                     final_activation=True))
    m.add(DenseLayer("multiply", ["u", "v"], ["gmf_int"]))
    # context group feeds the head through one small projection
    m.add(DenseLayer("mlp", ["ctx"], ["ctx_h"], units=(8,),
                     final_activation=True))
    m.add(DenseLayer("concat", ["deep_h", "gmf_int", "ctx_h"], ["feats"]))
    m.add(DenseLayer("mlp", ["feats"], ["h"], units=head,
                     final_activation=True))
    m.add(DenseLayer("mlp", ["h"], ["logit"], units=(1,)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m
