"""Config module for ``--arch phi3-mini-3.8b`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "phi3-mini-3.8b"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
