"""Architecture registry: the 10 assigned LM configs + the paper's own
recsys configs, selectable via ``--arch <id>``.

Sources are the assignment block (DESIGN.md §5 records the two places the
assignment is self-inconsistent and which reading we use).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (
    EmbeddingTableConfig, LMConfig, MoEConfig, RecsysConfig,
)

# ---------------------------------------------------------------------------
# LM architectures
# ---------------------------------------------------------------------------

LM_ARCHS: Dict[str, LMConfig] = {}


def _reg(cfg: LMConfig) -> LMConfig:
    LM_ARCHS[cfg.name] = cfg
    return cfg


granite_moe_1b = _reg(LMConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, norm="rmsnorm", activation="swiglu",
    moe=MoEConfig(num_experts=32, top_k=8, expert_d_ff=512),
    tie_embeddings=True, block_pattern=("attn",),
    full_attention_only=True))

granite_moe_3b = _reg(LMConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, norm="rmsnorm", activation="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
    tie_embeddings=True, block_pattern=("attn",),
    full_attention_only=True))

phi3_mini = _reg(LMConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, norm="rmsnorm", activation="swiglu",
    block_pattern=("attn",), full_attention_only=True))

minitron_4b = _reg(LMConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    head_dim=128, d_ff=9216, vocab_size=256000, norm="layernorm",
    activation="relu_sq", block_pattern=("attn",),
    full_attention_only=True))

command_r_plus = _reg(LMConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000, norm="layernorm", activation="swiglu",
    tie_embeddings=True, block_pattern=("attn",),
    full_attention_only=True))

olmo_1b = _reg(LMConfig(
    name="olmo-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=50304, norm="nonparam_ln", activation="swiglu",
    tie_embeddings=True, block_pattern=("attn",),
    full_attention_only=True))

seamless_m4t = _reg(LMConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206, norm="layernorm", activation="relu",
    tie_embeddings=True, block_pattern=("attn",),
    encoder_layers=24, frontend="audio", frontend_seq=512,
    full_attention_only=True))

pixtral_12b = _reg(LMConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072, norm="rmsnorm",
    activation="swiglu", block_pattern=("attn",),
    frontend="vision", frontend_seq=1024, full_attention_only=True))

xlstm_125m = _reg(LMConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, norm="layernorm", activation="gelu",
    tie_embeddings=True, block_pattern=("mlstm", "slstm"),
    full_attention_only=False))

recurrentgemma_9b = _reg(LMConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000, norm="rmsnorm",
    activation="geglu", tie_embeddings=True,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_attn_window=2048, full_attention_only=False))


def get_lm_config(name: str) -> LMConfig:
    return LM_ARCHS[name]


def reduce_for_smoke(cfg: LMConfig) -> LMConfig:
    """Shrink an arch to CPU-testable size, keeping its structure."""
    per = len(cfg.block_pattern)
    layers = per + (2 if cfg.name == "recurrentgemma-9b" else per)
    kv = min(cfg.num_kv_heads, 2)
    heads = 4 if 4 % kv == 0 else kv
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                        capacity_factor=cfg.moe.capacity_factor)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers, d_model=64, num_heads=heads,
        num_kv_heads=kv, head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 96,
        vocab_size=512, moe=moe,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_seq=16 if cfg.frontend else 0,
        local_attn_window=8)


# ---------------------------------------------------------------------------
# Recsys configs (the paper's own models)
# ---------------------------------------------------------------------------

#: Criteo-Kaggle-like vocab profile (26 tables, heavy-tailed sizes) —
#: shared by the registry configs below and the graph-API recipe modules
#: (configs/dlrm_criteo.py etc.), which must lower to the same tables.
CRITEO_VOCAB_SIZES = (
    1460, 584, 10131227, 2202608, 306, 24, 12518, 634, 4, 93146,
    5684, 8351593, 3195, 28, 14993, 5461306, 11, 5653, 2173, 4,
    7046547, 18, 16, 286181, 105, 142572)


def _criteo_tables(dim: int, scale: float = 1.0):
    return tuple(
        EmbeddingTableConfig(f"C{i+1}", max(4, int(v * scale)), dim,
                             hotness=1, strategy="auto")
        for i, v in enumerate(CRITEO_VOCAB_SIZES))


dlrm_criteo = RecsysConfig(
    name="dlrm-criteo", model="dlrm",
    tables=_criteo_tables(128),
    num_dense_features=13,
    bottom_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
    embedding_dim=128)

dcn_criteo = RecsysConfig(
    name="dcn-criteo", model="dcn",
    tables=_criteo_tables(16),
    num_dense_features=13,
    bottom_mlp=(), top_mlp=(1024, 1024), embedding_dim=16,
    num_cross_layers=6)

deepfm_criteo = RecsysConfig(
    name="deepfm-criteo", model="deepfm",
    tables=_criteo_tables(16),
    num_dense_features=13,
    bottom_mlp=(), top_mlp=(400, 400, 400), embedding_dim=16)

wdl_criteo = RecsysConfig(
    name="wdl-criteo", model="wdl",
    tables=_criteo_tables(16),
    num_dense_features=13,
    bottom_mlp=(), top_mlp=(1024, 1024), embedding_dim=16)

RECSYS_ARCHS: Dict[str, RecsysConfig] = {
    c.name: c for c in (dlrm_criteo, dcn_criteo, deepfm_criteo, wdl_criteo)
}

#: every graph-API recipe module, selectable via ``--arch`` in the
#: launchers: the four canonical paper recipes (which lower onto the
#: registry configs above) PLUS novel architectures that lower to
#: ``model="graph"`` and execute through the generic dense-graph
#: compiler — no registry entry or per-arch code needed.
RECSYS_RECIPES: Dict[str, str] = {
    arch: "repro.configs." + arch.replace("-", "_")
    for arch in ("dlrm-criteo", "dcn-criteo", "deepfm-criteo",
                 "wdl-criteo", "twotower-criteo", "crossdeep-criteo",
                 "neumf-criteo")
}


def reduce_recsys_for_smoke(cfg: RecsysConfig) -> RecsysConfig:
    d = 16
    tables = tuple(
        dataclasses.replace(t, vocab_size=min(t.vocab_size, 1000), dim=d)
        for t in cfg.tables[:6])
    bottom = (32, d) if cfg.model == "dlrm" else ()
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", tables=tables, embedding_dim=d,
        bottom_mlp=bottom, top_mlp=(32, 16, 1) if cfg.model == "dlrm"
        else (32, 16))


ALL_ARCH_IDS = list(LM_ARCHS) + list(RECSYS_ARCHS)
