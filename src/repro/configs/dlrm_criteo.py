"""DLRM-on-Criteo expressed as a graph-API recipe (paper §2).

``build_model`` declares the network with ``model.add(...)``; lowering
it yields the exact registry config (asserted in tests), so the graph is
the single source of model structure for training AND serving.
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES, RECSYS_ARCHS

ARCH_ID = "dlrm-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        dim, bottom, top = 16, (32, 16), (32, 16, 1)
    else:
        sizes = list(CRITEO_VOCAB_SIZES)
        dim = 128
        bottom, top = (512, 256, 128), (1024, 1024, 512, 256, 1)
    name = ARCH_ID + ("-smoke" if smoke else "")
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(
        vocab_sizes=sizes, dim=dim, top_name="emb",
        table_names=[f"C{i + 1}" for i in range(len(sizes))]))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=bottom,
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["interaction"]))
    m.add(DenseLayer("concat", ["bot", "interaction"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=top))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    return m


CONFIG = RECSYS_ARCHS[ARCH_ID]
#: the graph lowers to the same config (parity-tested)
GRAPH_CONFIG = build_model().to_recsys_config()
