"""Config module for ``--arch dlrm-criteo`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "dlrm-criteo"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
