"""Config module for ``--arch recurrentgemma-9b`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "recurrentgemma-9b"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
