"""Config module for ``--arch granite-moe-1b-a400m`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "granite-moe-1b-a400m"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
