"""Parallel cross+deep hybrid (DCN-v2 style) — a NOVEL graph.

Unlike canonical DCN (cross and deep towers concatenated into one
combine head), the branches here run in PARALLEL with their own logit
heads, plus a low-order linear branch over a ``slice`` of the dense
features; the sigmoid terminal sums all three logits. The structure
misses the canonical DCN shape on purpose, so ``to_recsys_config()``
lowers it to ``model="graph"`` and the compiled program executes it —
the DPIFrame-style "dense net as a schedulable operator graph" shape.

Exercises ``slice`` and multi-logit terminals on top of the classic
``cross``/``mlp`` vocabulary.
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES

ARCH_ID = "crossdeep-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        deep, n_cross = (32, 16), 2
    else:
        sizes = list(CRITEO_VOCAB_SIZES)
        deep, n_cross = (1024, 256), 4
    name = ARCH_ID + ("-smoke" if smoke else "")
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(
        vocab_sizes=sizes, dim=16, top_name="emb",
        table_names=[f"C{i + 1}" for i in range(len(sizes))]))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    # parallel branch 1: cross net with its own logit head
    m.add(DenseLayer("cross", ["flat"], ["crossed"],
                     num_layers=n_cross))
    m.add(DenseLayer("mlp", ["crossed"], ["cross_logit"], units=(1,)))
    # parallel branch 2: deep tower with its own logit head
    m.add(DenseLayer("mlp", ["flat"], ["deep_h"], units=deep,
                     final_activation=True))
    m.add(DenseLayer("mlp", ["deep_h"], ["deep_logit"], units=(1,)))
    # parallel branch 3: low-order linear term over the first dense cols
    m.add(DenseLayer("slice", ["dense"], ["dense_lo"], start=0, stop=4))
    m.add(DenseLayer("mlp", ["dense_lo"], ["lin_logit"], units=(1,)))
    m.add(DenseLayer("sigmoid",
                     ["cross_logit", "deep_logit", "lin_logit"],
                     ["prob"]))
    return m
