"""Config module for ``--arch pixtral-12b`` (see registry for the source)."""
from repro.configs.registry import LM_ARCHS, RECSYS_ARCHS

ARCH_ID = "pixtral-12b"
CONFIG = LM_ARCHS.get(ARCH_ID) or RECSYS_ARCHS[ARCH_ID]
