"""DeepFM-on-Criteo expressed as a graph-API recipe (paper §2).

TWO embedding branches: the deep dim-16 tables and their dim-1 wide
twins. The ``fm`` layer carries the first-order (wide + dense linear)
and second-order (pairwise hadamard) terms; the sigmoid terminal sums
the FM and deep-tower logits.
"""
from repro.api import (
    DataReaderParams, DenseLayer, Input, Model, SparseEmbedding, Solver,
)
from repro.configs.registry import CRITEO_VOCAB_SIZES, RECSYS_ARCHS

ARCH_ID = "deepfm-criteo"


def build_model(*, smoke: bool = False, solver: Solver = None,
                reader: DataReaderParams = None, mesh=None) -> Model:
    if smoke:
        sizes = [min(v, 1000) for v in CRITEO_VOCAB_SIZES[:6]]
        top = (32, 16)
    else:
        sizes = list(CRITEO_VOCAB_SIZES)
        top = (400, 400, 400)
    name = ARCH_ID + ("-smoke" if smoke else "")
    names = [f"C{i + 1}" for i in range(len(sizes))]
    m = Model(solver or Solver(),
              reader or DataReaderParams(num_dense_features=13),
              name=name, mesh=mesh)
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(vocab_sizes=sizes, dim=16, top_name="emb",
                          table_names=names))
    m.add(SparseEmbedding(vocab_sizes=sizes, dim=1, top_name="wide"))
    m.add(DenseLayer("concat", ["dense", "emb"], ["flat"]))
    m.add(DenseLayer("mlp", ["flat"], ["deep_out"],
                     units=tuple(top) + (1,)))
    m.add(DenseLayer("fm", ["dense", "wide", "emb"], ["fm_out"]))
    m.add(DenseLayer("sigmoid", ["fm_out", "deep_out"], ["prob"]))
    return m


CONFIG = RECSYS_ARCHS[ARCH_ID]
#: the graph lowers to the same config (parity-tested)
GRAPH_CONFIG = build_model().to_recsys_config()
