"""Persistent database (HPS level 3) — full model copy on disk/SSD.

The paper: *"PDB layers use hard-disks/SSDs to permanently store entire
embedding tables ... backup and ultimate ground truth"*, with per-table
key namespaces. One memmap per (model, table) namespace.

One store-wide lock serializes access: the serve loop upserts online
updates while pipelined-lookup host workers and refresh fetches read the
same rows, and a torn memmap row must never reach the caches.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.configs.base import EmbeddingTableConfig


class PersistentDB:

    # Checked by `python -m repro.analysis`: the memmap handles and
    # their shapes only move under the store-wide lock.
    _GUARDED_BY = {"_maps": "_lock", "_meta": "_lock"}

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._maps: Dict[Tuple[str, str], np.memmap] = {}
        self._meta: Dict[Tuple[str, str], Tuple[int, int]] = {}
        self._lock = threading.RLock()

    def _key(self, model: str, table: str) -> Tuple[str, str]:
        return (model, table)

    def create_table(self, model: str, table: str, vocab: int, dim: int,
                     initial: np.ndarray | None = None) -> None:
        with self._lock:
            path = os.path.join(self.root, f"{model}__{table}.f32")
            mm = np.memmap(path, np.float32, "w+", shape=(vocab, dim))
            if initial is not None:
                mm[:] = initial
            mm.flush()
            self._maps[self._key(model, table)] = mm
            self._meta[self._key(model, table)] = (vocab, dim)
            with open(os.path.join(self.root, f"{model}__{table}.json"),
                      "w") as f:
                json.dump({"vocab": vocab, "dim": dim}, f)

    def open_table(self, model: str, table: str) -> None:
        with self._lock:
            path = os.path.join(self.root, f"{model}__{table}.f32")
            with open(os.path.join(self.root,
                                   f"{model}__{table}.json")) as f:
                meta = json.load(f)
            self._maps[self._key(model, table)] = np.memmap(
                path, np.float32, "r+", shape=(meta["vocab"], meta["dim"]))
            self._meta[self._key(model, table)] = (meta["vocab"],
                                                   meta["dim"])

    def fetch(self, model: str, table: str, ids: np.ndarray) -> np.ndarray:
        with self._lock:
            return np.asarray(self._maps[self._key(model, table)][ids],
                              np.float32)

    def upsert(self, model: str, table: str, ids: np.ndarray,
               rows: np.ndarray) -> None:
        with self._lock:
            mm = self._maps[self._key(model, table)]
            mm[ids] = rows

    def flush(self):
        with self._lock:
            for mm in self._maps.values():
                mm.flush()

    def table_shape(self, model: str, table: str) -> Tuple[int, int]:
        with self._lock:
            return self._meta[self._key(model, table)]
