"""Striped physical storage for the L1 device payload.

``DeviceEmbeddingCache`` resolves ids to *logical slots*; this module owns
where a slot physically lives. The companion HPS paper (arXiv 2210.08804)
stripes the GPU embedding cache across devices so the hot working set
scales past one device's HBM — here slot ``s`` lives on stripe ``s % N``
at local row ``s // N``, and the stripes are laid out over a 1-D mesh
axis (``launch.mesh.make_cache_mesh``) when one is available, or kept as
host shards of a single stacked array otherwise. Because callers only
ever see logical slots, the cache's index/eviction machinery is entirely
layout-agnostic.

``shards=1`` reproduces the original single-payload behavior bit-exactly:
same physical padding, same one-scatter write path, same
``ops.cache_gather`` read path.

Snapshots are immutable jax arrays: ``scatter`` rebinds the payload, so a
reader holding a snapshot is never affected by concurrent writes — the
property the cache's lock-consistent query path relies on.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ops import _round_up


class ShardedPayloadStore:
    """Physical slot storage: single ``[C, D]`` payload (``shards=1``) or
    ``[N, Cl, D]`` stripes (``shards=N``), optionally mesh-placed."""

    def __init__(self, capacity: int, dim: int, *, shards: int = 1,
                 mesh=None, axis: str = "cache"):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards > capacity:
            raise ValueError(
                f"shards={shards} exceeds capacity={capacity}")
        if mesh is not None:
            size = mesh.shape.get(axis, 1)
            if shards % size:
                raise ValueError(
                    f"shards={shards} does not tile mesh axis "
                    f"'{axis}' of size {size}")
        self.capacity = capacity
        self.dim = dim
        self.shards = shards
        self.mesh = mesh
        self.axis = axis
        if shards == 1:
            # physical rows padded to the gather kernel's tile so the
            # jitted gather never copies the payload to pad it
            bc = min(512, _round_up(capacity, 8))
            self.phys_rows = _round_up(capacity, bc)
            self._payload = jnp.zeros((self.phys_rows, dim), jnp.float32)
        else:
            local_cap = -(-capacity // shards)        # rows per stripe
            bc = min(512, _round_up(local_cap, 8))
            self.local_rows = _round_up(local_cap, bc)
            self.phys_rows = shards * self.local_rows
            stripes = jnp.zeros((shards, self.local_rows, dim), jnp.float32)
            if mesh is not None and mesh.shape.get(axis, 1) > 1:
                from jax.sharding import NamedSharding, PartitionSpec
                stripes = jax.device_put(
                    stripes, NamedSharding(mesh, PartitionSpec(axis)))
            self._payload = stripes

    # -- write (the ONE device scatter per cache mutation) -------------------

    def scatter(self, slots: np.ndarray, rows: np.ndarray) -> None:
        """One ``at[...].set`` over the stripes, size-bucketed so XLA
        compiles O(log) scatter shapes instead of one per miss count
        (padding repeats the first slot — idempotent under ``set``)."""
        pad = _round_up(len(slots), 64) - len(slots)
        if pad:
            slots = np.concatenate([slots, np.full(pad, slots[0])])
            rows = np.concatenate(
                [rows, np.broadcast_to(rows[:1], (pad, rows.shape[1]))])
        if self.shards == 1:
            self._payload = self._payload.at[
                jnp.asarray(slots, jnp.int32)].set(jnp.asarray(rows))
        else:
            stripe = jnp.asarray(slots % self.shards, jnp.int32)
            local = jnp.asarray(slots // self.shards, jnp.int32)
            self._payload = self._payload.at[stripe, local].set(
                jnp.asarray(rows))

    # -- read ----------------------------------------------------------------

    def snapshot(self) -> jax.Array:
        """The current immutable payload (``[C, D]`` or ``[N, Cl, D]``).
        Gather from the snapshot you were handed, never from a re-read:
        a later scatter rebinds the store but can never mutate it."""
        return self._payload

    def gather(self, snapshot: jax.Array, slots) -> jax.Array:
        """Logical ``slots [n]`` (-1 = hole) -> ``[n, D]`` rows off a
        snapshot taken from THIS store."""
        if self.shards == 1:
            return ops.cache_gather(snapshot, slots)
        return ops.sharded_cache_gather(snapshot, slots, mesh=self.mesh,
                                        axis=self.axis)
