"""Striped physical storage for the L1 device payload.

``DeviceEmbeddingCache`` resolves ids to *logical slots*; this module owns
where a slot physically lives. The companion HPS paper (arXiv 2210.08804)
stripes the GPU embedding cache across devices so the hot working set
scales past one device's HBM — here slot ``s`` lives on stripe ``s % N``
at local row ``s // N``, and the stripes are laid out over a 1-D mesh
axis (``launch.mesh.make_cache_mesh``) when one is available, or kept as
host shards of a single stacked array otherwise. Because callers only
ever see logical slots, the cache's index/eviction machinery is entirely
layout-agnostic.

``shards=1`` reproduces the original single-payload behavior bit-exactly:
same physical padding, same one-scatter write path, same
``ops.cache_gather`` read path.

Payload precision is a storage knob (``payload_dtype``): ``"f32"`` is the
bit-exact baseline, ``"f16"`` halves the row bytes, ``"int8"`` stores
per-row absmax-quantized rows plus an f32 scale vector striped alongside
the payload — at a fixed HBM byte budget that is 2-4x more resident hot
rows, which is the cheapest L1 hit-rate lever there is (ScaleFreeCTR,
arXiv 2104.08542). Quantization happens host-side on insert/refresh;
reads dequantize inside the fused Pallas gather kernel, so the serving
path stays a single f32 dispatch regardless of storage precision.

Snapshots are immutable jax arrays: ``scatter`` rebinds the payload, so a
reader holding a snapshot is never affected by concurrent writes — the
property the cache's lock-consistent query path relies on. A snapshot is
the pair ``(payload, scales)`` with ``scales is None`` outside int8 mode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ops import _round_up

PAYLOAD_DTYPES = ("f32", "f16", "int8")

_STORAGE = {"f32": jnp.float32, "f16": jnp.float16, "int8": jnp.int8}


def row_bytes(dim: int, payload_dtype: str = "f32") -> int:
    """HBM bytes one resident row costs in a given storage mode (int8
    includes its 4-byte per-row f32 scale)."""
    if payload_dtype == "f32":
        return 4 * dim
    if payload_dtype == "f16":
        return 2 * dim
    if payload_dtype == "int8":
        return dim + 4
    raise ValueError(f"unknown payload_dtype {payload_dtype!r}; "
                     f"expected one of {PAYLOAD_DTYPES}")


def quantize_rows(rows: np.ndarray, payload_dtype: str
                  ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Host-side insert-path quantization: ``rows [n, D]`` f32 ->
    ``(stored_rows, scales_or_None)``.

    int8 uses per-row absmax: ``scale = max|row| / 127`` (1.0 for all-zero
    rows so dequantization is always ``q * scale``), symmetric clip to
    [-127, 127]. f16 is a plain downcast; f32 passes through untouched.
    """
    rows = np.asarray(rows, np.float32)
    if payload_dtype == "f32":
        return rows, None
    if payload_dtype == "f16":
        return rows.astype(np.float16), None
    if payload_dtype == "int8":
        absmax = np.abs(rows).max(axis=1)
        scales = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
        q = np.clip(np.rint(rows / scales[:, None]), -127, 127)
        return q.astype(np.int8), scales
    raise ValueError(f"unknown payload_dtype {payload_dtype!r}; "
                     f"expected one of {PAYLOAD_DTYPES}")


class ShardedPayloadStore:
    """Physical slot storage: single ``[C, D]`` payload (``shards=1``) or
    ``[N, Cl, D]`` stripes (``shards=N``), optionally mesh-placed, in any
    of the ``PAYLOAD_DTYPES`` storage modes."""

    def __init__(self, capacity: int, dim: int, *, shards: int = 1,
                 mesh=None, axis: str = "cache",
                 payload_dtype: str = "f32"):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards > capacity:
            raise ValueError(
                f"shards={shards} exceeds capacity={capacity}")
        if payload_dtype not in _STORAGE:
            raise ValueError(f"unknown payload_dtype {payload_dtype!r}; "
                             f"expected one of {PAYLOAD_DTYPES}")
        if mesh is not None:
            size = mesh.shape.get(axis, 1)
            if shards % size:
                raise ValueError(
                    f"shards={shards} does not tile mesh axis "
                    f"'{axis}' of size {size}")
        self.capacity = capacity
        self.dim = dim
        self.shards = shards
        self.mesh = mesh
        self.axis = axis
        self.payload_dtype = payload_dtype
        store_dt = _STORAGE[payload_dtype]
        scaled = payload_dtype == "int8"
        if shards == 1:
            # physical rows padded to the gather kernel's tile so the
            # jitted gather never copies the payload to pad it
            bc = min(512, _round_up(capacity, 8))
            self.phys_rows = _round_up(capacity, bc)
            self._payload = jnp.zeros((self.phys_rows, dim), store_dt)
            self._scales = (jnp.ones((self.phys_rows,), jnp.float32)
                            if scaled else None)
        else:
            local_cap = -(-capacity // shards)        # rows per stripe
            bc = min(512, _round_up(local_cap, 8))
            self.local_rows = _round_up(local_cap, bc)
            self.phys_rows = shards * self.local_rows
            stripes = jnp.zeros((shards, self.local_rows, dim), store_dt)
            scales = (jnp.ones((shards, self.local_rows), jnp.float32)
                      if scaled else None)
            if mesh is not None and mesh.shape.get(axis, 1) > 1:
                from jax.sharding import NamedSharding, PartitionSpec
                sharding = NamedSharding(mesh, PartitionSpec(axis))
                stripes = jax.device_put(stripes, sharding)
                if scales is not None:
                    # the scale vector stripes WITH its payload rows, so
                    # the fused dequantize-gather never moves it
                    scales = jax.device_put(scales, sharding)
            self._payload = stripes
            self._scales = scales

    # -- write (the ONE device scatter per cache mutation) -------------------

    def scatter(self, slots: np.ndarray, rows: np.ndarray) -> None:
        """One ``at[...].set`` over the stripes, size-bucketed so XLA
        compiles O(log) scatter shapes instead of one per miss count
        (padding repeats the first slot — idempotent under ``set``).
        In compressed modes the f32 rows quantize host-side first; int8
        additionally rebinds the scale vector at the same slots."""
        rows, scales = quantize_rows(np.asarray(rows), self.payload_dtype)
        pad = _round_up(len(slots), 64) - len(slots)
        if pad:
            slots = np.concatenate([slots, np.full(pad, slots[0])])
            rows = np.concatenate(
                [rows, np.broadcast_to(rows[:1], (pad, rows.shape[1]))])
            if scales is not None:
                scales = np.concatenate(
                    [scales, np.broadcast_to(scales[:1], (pad,))])
        if self.shards == 1:
            idx = jnp.asarray(slots, jnp.int32)
            self._payload = self._payload.at[idx].set(jnp.asarray(rows))
            if scales is not None:
                self._scales = self._scales.at[idx].set(jnp.asarray(scales))
        else:
            stripe = jnp.asarray(slots % self.shards, jnp.int32)
            local = jnp.asarray(slots // self.shards, jnp.int32)
            self._payload = self._payload.at[stripe, local].set(
                jnp.asarray(rows))
            if scales is not None:
                self._scales = self._scales.at[stripe, local].set(
                    jnp.asarray(scales))

    # -- read ----------------------------------------------------------------

    def snapshot(self):
        """The current immutable ``(payload, scales)`` pair (``[C, D]`` or
        ``[N, Cl, D]`` payload; ``scales`` is None outside int8 mode).
        Gather from the snapshot you were handed, never from a re-read:
        a later scatter rebinds the store but can never mutate it."""
        return (self._payload, self._scales)

    def gather(self, snapshot, slots) -> jax.Array:
        """Logical ``slots [n]`` (-1 = hole) -> ``[n, D]`` f32 rows off a
        snapshot taken from THIS store (dequantized in-kernel when the
        storage mode is compressed)."""
        payload, scales = snapshot
        if self.shards == 1:
            return ops.cache_gather(payload, slots, scales=scales)
        return ops.sharded_cache_gather(payload, slots, scales=scales,
                                        mesh=self.mesh, axis=self.axis)
