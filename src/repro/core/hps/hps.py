"""Hierarchical Parameter Server orchestration (paper §3).

Lookup path per table: L1 device cache -> L2 volatile DB -> L3 persistent
DB, with promotion on miss at every level. The online-update Consumer
applies trainer messages to L2/L3; the L1 cache's async refresh cycle then
picks them up (poll-based, configurable period — the paper's design).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.message_bus import Consumer, MessageBus
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB


class HPS:

    def __init__(self, model_name: str,
                 tables: Sequence[EmbeddingTableConfig],
                 pdb: PersistentDB, *,
                 vdb: Optional[VolatileDB] = None,
                 cache_capacity: int = 4096,
                 bus: Optional[MessageBus] = None):
        self.model_name = model_name
        self.tables = tuple(tables)
        self.pdb = pdb
        self.vdb = vdb or VolatileDB()
        self.caches: Dict[str, DeviceEmbeddingCache] = {}
        for t in tables:
            self.caches[t.name] = DeviceEmbeddingCache(
                min(cache_capacity, t.vocab_size), t.dim,
                fetch_fn=self._make_fetch(t.name))
        self.consumer = Consumer(bus, model_name) if bus else None

    # -- L2/L3 fall-through ------------------------------------------------------

    def _make_fetch(self, table: str):
        def fetch(ids: np.ndarray) -> np.ndarray:
            mask, rows = self.vdb.query(table, ids)
            if rows is None:
                rows = np.zeros((len(ids), self._dim(table)), np.float32)
            if not mask.all():
                missing = ids[~mask]
                fetched = self.pdb.fetch(self.model_name, table, missing)
                rows[~mask] = fetched
                self.vdb.insert(table, missing, fetched)  # promote
            return rows
        return fetch

    def _dim(self, table: str) -> int:
        return next(t.dim for t in self.tables if t.name == table)

    # -- public lookup ------------------------------------------------------------

    def lookup(self, cat: np.ndarray, hotness: Optional[List[int]] = None
               ) -> jax.Array:
        """``cat [B, T, H]`` (-1 pad) -> pooled ``[B, T, D]`` on device."""
        b, t, h = cat.shape
        outs = []
        for ti, tab in enumerate(self.tables):
            ids = cat[:, ti, :]
            flat = ids.reshape(-1)
            valid = flat >= 0
            vecs = np.zeros((b * h, tab.dim), np.float32)
            if valid.any():
                got = self.caches[tab.name].query(flat[valid])
                vecs[valid] = np.asarray(got)
            pooled = vecs.reshape(b, h, tab.dim).sum(axis=1)
            outs.append(pooled)
        return jnp.asarray(np.stack(outs, axis=1))

    # -- online updates -------------------------------------------------------------

    def apply_updates(self) -> int:
        """Poll the message bus into VDB+PDB (L1 refresh is separate)."""
        if self.consumer is None:
            return 0

        def apply(table, ids, rows):
            self.pdb.upsert(self.model_name, table, ids, rows)
            self.vdb.insert(table, ids, rows)

        return self.consumer.poll(apply)

    def refresh_caches(self) -> int:
        return sum(c.refresh_once() for c in self.caches.values())

    def start_refresh(self, interval_s: float):
        for c in self.caches.values():
            c.start_refresh(interval_s)

    def stop_refresh(self):
        for c in self.caches.values():
            c.stop_refresh()

    # -- metrics ---------------------------------------------------------------------

    def stats(self) -> Dict:
        return {
            "l1_hit_rate": {k: c.hit_rate for k, c in self.caches.items()},
            "l2_hits": self.vdb.hits,
            "l2_misses": self.vdb.misses,
        }
