"""Hierarchical Parameter Server orchestration (paper §3).

Lookup path per table: L1 device cache -> L2 volatile DB -> L3 persistent
DB, with promotion on miss at every level. The online-update Consumer
applies trainer messages to L2/L3 AND marks the touched L1 rows dirty;
the hotness-scheduled refresh (driven by the serving loop, see
``serve.server``) then re-pulls them in bounded chunks, hot rows first.

Batched lookup path: each table resolves through a HOST stage (sorted
index probe + ONE coalesced miss fetch) and a DEVICE stage (the one
payload scatter + slot transfer), and the stacked pooled output
``[B, T, D]`` is computed in a SINGLE jitted device call at the end — the
per-table slot arrays are the only host->device transfer, and the pooled
activations never bounce through host memory. With ``pipelined=True`` the
two stages are double-buffered on a dedicated host worker so table
*t+1*'s index probe overlaps table *t*'s device scatter;
``lookup_stream`` extends the same pipeline across consecutive queries
(query *i+1*'s probes run while the host blocks materializing query *i*'s
result — the serving-loop shape; ``materialize=False`` hands the caller
un-synced device arrays so the serve loop can chain the dense net before
any host sync). ``lookup_stage_sync`` is the no-overlap reference engine
the benchmarks compare against. Pooling honors each table's combiner
(sum or mean); the ``hotness`` argument selects the valid id columns per
table (and is validated against the query shape instead of being silently
ignored).

When the caches are built with ``cache_shards=N`` (optionally over a
``cache_mesh``), the pooled gather reads the striped payload through
``ops.sharded_pooled_lookup`` — same single dispatch, payload distributed
row ``r`` -> stripe ``r % N``.
"""
from __future__ import annotations

import functools
import math
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig
from repro.core.hps.embedding_cache import DeviceEmbeddingCache, LookupPlan
from repro.core.hps.message_bus import Consumer, MessageBus
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("combiners", "apply_mean",
                                             "shards", "mesh", "axis"))
def _pooled_stack(payloads: Tuple[tuple, ...],
                  slots: Tuple[jax.Array, ...],
                  combiners: Tuple[str, ...],
                  apply_mean: bool = True, shards: int = 1,
                  mesh=None, axis: str = "cache") -> jax.Array:
    """One device dispatch: per-table pooled gathers stacked to [B, T, D].

    Each payload is a ``(payload, scales)`` snapshot pair; compressed
    stores dequantize inside the fused gather kernel, so the stacked
    output is f32 regardless of storage precision — still ONE dispatch.
    """
    outs = []
    for (p, sc), s, comb in zip(payloads, slots, combiners):
        if shards == 1:
            pooled = ops.pooled_cache_lookup(p, s, sc)   # [B, D] sum over H
        else:
            pooled = ops.sharded_pooled_lookup(p, s, scales=sc,
                                               mesh=mesh, axis=axis)
        if comb == "mean" and apply_mean:
            denom = jnp.maximum((s >= 0).sum(axis=1, keepdims=True), 1)
            pooled = pooled / denom.astype(pooled.dtype)
        outs.append(pooled)
    return jnp.stack(outs, axis=1)


class HPS:

    # Checked by `python -m repro.analysis`: the L3 fetch counters have
    # their own lock (probe and refresh fetches race), and the lazy host
    # pool is built under _pool_lock.
    _GUARDED_BY = {
        "_l3_fetch_calls": "_l3_stats_lock",
        "_l3_fetch_rows": "_l3_stats_lock",
        "_host_pool": "_pool_lock",
    }

    def __init__(self, model_name: str,
                 tables: Sequence[EmbeddingTableConfig],
                 pdb: PersistentDB, *,
                 vdb: Optional[VolatileDB] = None,
                 cache_capacity: int = 4096,
                 bus: Optional[MessageBus] = None,
                 cache_shards: int = 1, cache_mesh=None,
                 refresh_chunk_rows: int = 1024,
                 payload_dtype: str = "f32"):
        self.model_name = model_name
        self.tables = tuple(tables)
        self.pdb = pdb
        self.vdb = vdb or VolatileDB()
        self.cache_shards = cache_shards
        self.cache_mesh = cache_mesh
        self.cache_capacity = cache_capacity
        self.payload_dtype = payload_dtype
        # O(1) per-table config (the L2/L3 fetch path runs per miss batch)
        self._table_cfg: Dict[str, EmbeddingTableConfig] = {
            t.name: t for t in tables}
        self._l3_fetch_calls: Dict[str, int] = {t.name: 0 for t in tables}
        self._l3_fetch_rows: Dict[str, int] = {t.name: 0 for t in tables}
        # refresh fetches run with the cache lock released, so the L3
        # counters need their own (probe and refresh can fetch at once)
        self._l3_stats_lock = threading.Lock()
        self.caches: Dict[str, DeviceEmbeddingCache] = {}
        for t in tables:
            self.caches[t.name] = DeviceEmbeddingCache(
                min(cache_capacity, t.vocab_size), t.dim,
                fetch_fn=self._make_fetch(t.name),
                shards=cache_shards, mesh=cache_mesh,
                refresh_chunk_rows=refresh_chunk_rows,
                payload_dtype=payload_dtype)
        self.consumer = Consumer(bus, model_name) if bus else None
        self._host_pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()
        #: the lookahead the adaptive ``lookup_stream`` last settled on
        #: (and the deepest it has reached) — observability for the
        #: fetch/compute auto-tuner
        self.stream_depth = 2
        self.stream_depth_peak = 2

    # -- L2/L3 fall-through ------------------------------------------------------

    def _vdb_key(self, table: str) -> str:
        """L2 key namespace: one VolatileDB process can back SEVERAL
        deployed models (the ensemble bundle), so table keys are scoped
        by model — two models' same-named tables never collide, and one
        model's online updates can never touch another's L2 rows."""
        return f"{self.model_name}/{table}"

    def _make_fetch(self, table: str):
        dim = self._table_cfg[table].dim

        def fetch(ids: np.ndarray) -> np.ndarray:
            mask, rows = self.vdb.query(self._vdb_key(table), ids)
            if rows is None:
                rows = np.zeros((len(ids), dim), np.float32)
            if not mask.all():
                missing = ids[~mask]
                fetched = self.pdb.fetch(self.model_name, table, missing)
                with self._l3_stats_lock:
                    self._l3_fetch_calls[table] += 1
                    self._l3_fetch_rows[table] += len(missing)
                rows[~mask] = fetched
                self.vdb.insert(self._vdb_key(table), missing,
                                fetched)  # promote
            return rows
        return fetch

    def _dim(self, table: str) -> int:
        return self._table_cfg[table].dim

    # -- public lookup ------------------------------------------------------------

    def _split_query(self, cat: np.ndarray,
                     hotness: Optional[List[int]]) -> List[np.ndarray]:
        """Validate the query shape and return per-table id blocks [B, H_t]."""
        T = len(self.tables)
        if cat.ndim == 2:
            if hotness is None:
                raise ValueError(
                    "2-D cat requires hotness=[ids per table] to split "
                    f"the {cat.shape[1]} id columns over {T} tables")
            if len(hotness) != T:
                raise ValueError(
                    f"hotness has {len(hotness)} entries for {T} tables")
            if sum(hotness) != cat.shape[1]:
                raise ValueError(
                    f"sum(hotness)={sum(hotness)} != cat.shape[1]="
                    f"{cat.shape[1]}")
            return np.split(cat, np.cumsum(hotness)[:-1], axis=1)
        if cat.ndim != 3:
            raise ValueError(f"cat must be [B, T, H] or [B, sum(hotness)]; "
                             f"got shape {cat.shape}")
        if cat.shape[1] != T:
            raise ValueError(
                f"cat.shape[1]={cat.shape[1]} does not match the "
                f"{T} tables of model '{self.model_name}'")
        blocks = [cat[:, ti, :] for ti in range(T)]
        if hotness is not None:
            if len(hotness) != T:
                raise ValueError(
                    f"hotness has {len(hotness)} entries for {T} tables")
            for ti, h in enumerate(hotness):
                if h > cat.shape[2]:
                    raise ValueError(
                        f"hotness[{ti}]={h} exceeds id columns "
                        f"{cat.shape[2]}")
                if h < cat.shape[2]:  # mask columns beyond the hotness
                    blk = blocks[ti].copy()
                    blk[:, h:] = -1
                    blocks[ti] = blk
        return blocks

    # -- two-stage lookup pipeline -------------------------------------------------

    def _host_worker(self) -> ThreadPoolExecutor:
        """The host-stage workers: index probes + miss fetches run here
        in pipelined mode while the caller's thread owns the device
        stages. Two workers (the double buffer) let table *t+1*'s index
        probe proceed while table *t*'s miss fetch waits on the lower
        levels (remote-L2/SSD IO releases the GIL). Same-table probes
        stay ordered: a probe holds its cache's lock, and with two
        workers at most one successor can be waiting on it. For a
        single-table model one worker suffices — cross-query overlap
        still applies, and FIFO execution keeps deep streams ordered."""
        with self._pool_lock:
            if self._host_pool is None:
                self._host_pool = ThreadPoolExecutor(
                    max_workers=min(2, len(self.tables)),
                    thread_name_prefix="hps-host")
            return self._host_pool

    def close(self) -> None:
        """Release the host-stage workers (idempotent; a later pipelined
        lookup just recreates them)."""
        with self._pool_lock:
            pool, self._host_pool = self._host_pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def _probe(self, ti: int, blocks: List[np.ndarray]) -> LookupPlan:
        """HOST stage for table ``ti``: probe + coalesced miss fetch."""
        flat = np.ascontiguousarray(blocks[ti], np.int64).reshape(-1)
        return self.caches[self.tables[ti].name].probe(flat)

    def _device_stage(self, ti: int, plan: LookupPlan, b: int, bp: int,
                      h: int) -> Tuple[jax.Array, jax.Array]:
        """DEVICE stage for table ``ti``: flush the plan's deferred
        scatter, bind its payload snapshot, and ship the slot block."""
        payload = self.caches[self.tables[ti].name].commit(plan)
        slots = np.pad(plan.slots.reshape(b, h), ((0, bp - b), (0, 0)),
                       constant_values=-1)
        return jnp.asarray(slots, jnp.int32), payload

    def _collect_plan(self, ti: int, plan: LookupPlan, b: int, bp: int,
                      blocks: List[np.ndarray],
                      slot_blocks: List[jax.Array],
                      payloads: List[jax.Array],
                      overflow: List[Tuple[int, np.ndarray, np.ndarray,
                                           int]]) -> jax.Array:
        """Run table ``ti``'s device stage and record its outputs — the
        per-plan bookkeeping shared by every engine variant."""
        sb, payload = self._device_stage(ti, plan, b, bp,
                                         blocks[ti].shape[1])
        slot_blocks.append(sb)
        payloads.append(payload)
        if len(plan.ov_idx):
            overflow.append((ti, plan.ov_idx, plan.ov_rows,
                             blocks[ti].shape[1]))
        return payload

    def _check_dims(self) -> int:
        dims = {t.dim for t in self.tables}
        if len(dims) != 1:
            raise ValueError(
                f"stacked lookup needs equal table dims, got {sorted(dims)}")
        return dims.pop()

    def _finalize(self, payloads: List[jax.Array],
                  slot_blocks: List[jax.Array],
                  blocks: List[np.ndarray],
                  overflow: List[Tuple[int, np.ndarray, np.ndarray, int]],
                  b: int) -> jax.Array:
        """The single jitted pooled-stack dispatch (+ rare overflow fix)."""
        combiners = tuple("mean" if t.combiner == "mean" else "sum"
                          for t in self.tables)
        stack = functools.partial(
            _pooled_stack, tuple(payloads), tuple(slot_blocks), combiners,
            shards=self.cache_shards, mesh=self.cache_mesh)
        if not overflow:
            return stack()[:b]

        # rare path: some ids exceeded L1 evictable capacity; add their
        # contribution host-side, then apply the mean denominators exactly
        out = stack(apply_mean=False)[:b]
        dim = self.tables[0].dim
        corr = np.zeros((b, len(self.tables), dim), np.float32)
        for ti, ov_idx, ov_rows, h in overflow:
            np.add.at(corr[:, ti, :], ov_idx // h, ov_rows)
        out = out + jnp.asarray(corr)
        mean_mask = np.asarray([c == "mean" for c in combiners])
        if mean_mask.any():
            denom = np.stack(
                [np.maximum((blk >= 0).sum(axis=1), 1) for blk in blocks],
                axis=1).astype(np.float32)[:, :, None]
            out = jnp.where(jnp.asarray(mean_mask)[None, :, None],
                            out / jnp.asarray(denom), out)
        return out

    def lookup(self, cat: np.ndarray, hotness: Optional[List[int]] = None,
               *, pipelined: bool = False) -> jax.Array:
        """``cat [B, T, H]`` or ``[B, sum(hotness)]`` (-1 pad) -> pooled
        ``[B, T, D]`` on device, honoring each table's combiner.

        All tables resolve before the single jitted device call; per-table
        misses are coalesced by the L1 cache into one fetch + one scatter.
        Batch sizes are bucketed to powers of two so the variable-size
        serve loop compiles O(log) pooled-gather shapes, not one per
        drained batch size.

        ``pipelined=True`` double-buffers the per-table host stage (index
        probe + miss fetch, on the HPS host worker) against the device
        stage (scatter + slot transfer, on the calling thread): table
        *t+1* is being probed while table *t*'s scatter is in flight.
        Results are identical to the sequential path — each table's plan
        carries a lock-consistent payload snapshot.
        """
        cat = np.asarray(cat)
        blocks = self._split_query(cat, hotness)
        self._check_dims()
        T = len(self.tables)
        b = cat.shape[0]
        if b == 0:
            return jnp.zeros((0, T, self.tables[0].dim), jnp.float32)
        bp = 1 << (b - 1).bit_length()

        slot_blocks: List[jax.Array] = []
        payloads: List[jax.Array] = []
        overflow: List[Tuple[int, np.ndarray, np.ndarray, int]] = []

        if pipelined and T > 1:
            pool = self._host_worker()
            futs: Dict[int, Future] = {
                ti: pool.submit(self._probe, ti, blocks)
                for ti in range(min(3, T))}          # 2 running + 1 queued
            for ti in range(T):
                plan = futs.pop(ti).result()
                if ti + 3 < T:
                    futs[ti + 3] = pool.submit(self._probe, ti + 3, blocks)
                self._collect_plan(ti, plan, b, bp, blocks, slot_blocks,
                                   payloads, overflow)
        else:
            for ti in range(T):
                self._collect_plan(ti, self._probe(ti, blocks), b, bp,
                                   blocks, slot_blocks, payloads, overflow)

        return self._finalize(payloads, slot_blocks, blocks, overflow, b)

    def lookup_stage_sync(self, cat: np.ndarray,
                          hotness: Optional[List[int]] = None) -> jax.Array:
        """Fully stage-synchronous lookup: BLOCK on each table's device
        scatter before the next host probe, and block on the pooled
        stack before returning — zero overlap of any kind, not even
        XLA's async dispatch. The no-overlap reference engine the
        pipelining benchmarks (and the ``stage_sync`` server engine)
        compare against; bit-identical outputs to :meth:`lookup`."""
        cat = np.asarray(cat)
        blocks = self._split_query(cat, hotness)
        self._check_dims()
        b = cat.shape[0]
        if b == 0:
            return jnp.zeros((0, len(self.tables), self.tables[0].dim),
                             jnp.float32)
        bp = 1 << (b - 1).bit_length()
        slot_blocks: List[jax.Array] = []
        payloads: List[jax.Array] = []
        overflow: List[Tuple[int, np.ndarray, np.ndarray, int]] = []
        for ti in range(len(self.tables)):
            payload = self._collect_plan(ti, self._probe(ti, blocks), b,
                                         bp, blocks, slot_blocks,
                                         payloads, overflow)
            jax.block_until_ready(payload)             # no overlap
        return jax.block_until_ready(
            self._finalize(payloads, slot_blocks, blocks, overflow, b))

    def _timed_probe(self, ti: int, blocks: List[np.ndarray],
                     rec: List[float]) -> LookupPlan:
        """Host stage + its wall time (pure work, queueing excluded) —
        the fetch half of the stream auto-tuner's fetch/compute ratio."""
        t0 = time.perf_counter()
        plan = self._probe(ti, blocks)
        rec.append(time.perf_counter() - t0)
        return plan

    def lookup_stream(self, cats: Iterable[np.ndarray],
                      hotness: Optional[List[int]] = None, *,
                      depth: Optional[int] = None, max_depth: int = 8,
                      materialize: bool = True) -> Iterator:
        """Serve a stream of queries through the two-stage pipeline,
        yielding ``[B, T, D]`` pooled outputs in order.

        Double-buffered on BOTH ends: the host workers run query
        *i+1*'s probes (and their L2/L3 miss fetches) while the calling
        thread handles query *i*'s device stages, and query *i*'s pooled
        output is materialized only after query *i+1*'s device work has
        been dispatched — so the device is computing one query while the
        host probes another, the serving loop of the paper's HPS.

        ``depth`` bounds the lookahead (queries whose fetched rows may
        be held in flight). The default (``None``) AUTO-TUNES it from
        the observed fetch/compute ratio: each query records its host
        stage's work time (probe + coalesced L2/L3 miss fetch) and the
        consumer-side time until the next query is taken, and the
        lookahead tracks ``ceil(fetch/compute) + 1`` within
        ``[2, max_depth]`` — a deep-RTT L2 (remote Redis-style fetches)
        admits more in-flight queries so misses overlap, while a warm
        cache stays at the classic double buffer. The depth last settled
        on (and the peak) is exposed as ``stream_depth`` /
        ``stream_depth_peak`` and in :meth:`stats`. Pass an ``int`` to
        pin the lookahead.

        ``materialize=False`` yields the un-synced DEVICE arrays instead
        of numpy, immediately after each query's device dispatch — the
        stream-fed server feeds these straight into the jitted dense net
        and owns the delay point itself, so the prediction (not the
        embedding) is what finally synchronizes the pipeline and NOTHING
        bounces through host memory between lookup and dense compute.
        """
        self._check_dims()
        pool = self._host_worker()
        it = iter(cats)
        #: (b, blocks, probe futures, probe-time record) per query
        pending: "deque" = deque()
        exhausted = False
        adaptive = depth is None
        cur_depth = 2 if adaptive else max(1, depth)
        cap = max(cur_depth, max_depth)
        workers = max(1, min(2, len(self.tables)))
        ema_fetch: Optional[float] = None
        ema_compute: Optional[float] = None
        self.stream_depth = cur_depth        # pinned or adaptive start
        self.stream_depth_peak = max(self.stream_depth_peak, cur_depth)

        def admit():
            nonlocal exhausted
            while not exhausted and len(pending) < max(1, cur_depth):
                try:
                    cat = np.asarray(next(it))
                except StopIteration:
                    exhausted = True
                    return
                blocks = self._split_query(cat, hotness)
                rec: List[float] = []
                futs = [pool.submit(self._timed_probe, ti, blocks, rec)
                        for ti in range(len(self.tables))]
                pending.append((cat.shape[0], blocks, futs, rec))

        in_flight: List[jax.Array] = []     # dispatched, not yet synced
        try:
            admit()
            while pending:
                b, blocks, futs, rec = pending.popleft()
                plans = [f.result() for f in futs]
                t0 = time.perf_counter()    # host-stage wait excluded
                bp = 1 << (b - 1).bit_length()
                slot_blocks, payloads, overflow = [], [], []
                for ti, plan in enumerate(plans):
                    self._collect_plan(ti, plan, b, bp, blocks,
                                       slot_blocks, payloads, overflow)
                out = self._finalize(payloads, slot_blocks, blocks,
                                     overflow, b)
                admit()                     # next query probes first ...
                if not materialize:         # ... caller owns the delay
                    yield out
                else:
                    in_flight.append(out)
                    if len(in_flight) > 1:  # ... then sync, one behind:
                        # the device computes query i while the host is
                        # already probing/dispatching query i+1
                        yield np.asarray(in_flight.pop(0))
                if adaptive:
                    # consume time includes the caller's work between
                    # yields (the dense net in the stream-fed server) —
                    # exactly what the fetch must overlap with
                    compute = max(time.perf_counter() - t0, 1e-6)
                    fetch = sum(rec) / workers
                    ema_fetch = fetch if ema_fetch is None \
                        else 0.5 * ema_fetch + 0.5 * fetch
                    ema_compute = compute if ema_compute is None \
                        else 0.5 * ema_compute + 0.5 * compute
                    ratio = ema_fetch / ema_compute
                    cur_depth = int(min(cap, max(
                        2, math.ceil(ratio) + 1)))
                    self.stream_depth = cur_depth
                    self.stream_depth_peak = max(self.stream_depth_peak,
                                                 cur_depth)
            for out in in_flight:
                yield np.asarray(out)
        finally:
            for _, _, futs, _ in pending:   # abandoned mid-stream
                for f in futs:
                    f.cancel()

    # -- online updates -------------------------------------------------------------

    def apply_updates(self) -> int:
        """Poll the message bus into VDB+PDB and schedule the touched L1
        rows for refresh (the hotness scheduler drains them)."""
        if self.consumer is None:
            return 0

        def apply(table, ids, rows):
            self.pdb.upsert(self.model_name, table, ids, rows)
            self.vdb.insert(self._vdb_key(table), ids, rows)
            cache = self.caches.get(table)
            if cache is not None:
                cache.mark_dirty(ids)

        return self.consumer.poll(apply)

    def schedule_refresh(self) -> int:
        """Mark every resident L1 row stale (poll-cycle fallback when no
        update stream identifies the changed rows)."""
        return sum(c.mark_all_dirty() for c in self.caches.values())

    def refresh_step(self, budget: Optional[int] = None) -> int:
        """Drain one bounded, hotness-ordered chunk of the refresh
        backlog per table — the serving loop calls this between batches."""
        return sum(c.refresh_chunk(budget) for c in self.caches.values())

    def refresh_backlog(self) -> int:
        return sum(c.refresh_backlog() for c in self.caches.values())

    def refresh_caches(self) -> int:
        """Full re-pull of every resident row (offline convenience)."""
        return sum(c.refresh_once() for c in self.caches.values())

    def resize_caches(self, capacity: int) -> int:
        """Rebuild every table's L1 at ``min(capacity, vocab)`` rows,
        keeping the hottest residents (the ensemble budget rebalancer's
        entry point). Returns total rows retained across tables."""
        kept = 0
        for t in self.tables:
            kept += self.caches[t.name].resize(min(capacity, t.vocab_size))
        self.cache_capacity = capacity
        return kept

    def start_refresh(self, interval_s: float):
        for c in self.caches.values():
            c.start_refresh(interval_s)

    def stop_refresh(self):
        for c in self.caches.values():
            c.stop_refresh()

    # -- metrics ---------------------------------------------------------------------

    def stats(self) -> Dict:
        with self._l3_stats_lock:
            l3 = {"calls": dict(self._l3_fetch_calls),
                  "rows": dict(self._l3_fetch_rows)}
        l2 = self.vdb.stats()                 # one locked L2 snapshot
        l1 = {k: c.counters() for k, c in self.caches.items()}
        return {
            "l1_hit_rate": {
                k: (c["hits"] / (c["hits"] + c["misses"])
                    if c["hits"] + c["misses"] else 0.0)
                for k, c in l1.items()},
            "l2_hits": l2["hits"],
            "l2_misses": l2["misses"],
            "l2": l2,
            "l3_fetches": l3,
            "refresh": {
                "rows_refreshed": sum(c["rows_refreshed"]
                                      for c in l1.values()),
                "chunks": sum(c["refresh_chunks"] for c in l1.values()),
                "backlog": self.refresh_backlog(),
            },
            "stream": {"depth": self.stream_depth,
                       "depth_peak": self.stream_depth_peak},
        }
