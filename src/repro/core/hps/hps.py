"""Hierarchical Parameter Server orchestration (paper §3).

Lookup path per table: L1 device cache -> L2 volatile DB -> L3 persistent
DB, with promotion on miss at every level. The online-update Consumer
applies trainer messages to L2/L3; the L1 cache's async refresh cycle then
picks them up (poll-based, configurable period — the paper's design).

Batched lookup path: ``lookup`` resolves ALL tables of a query on the
host index first (misses coalesced per table into one fetch + one payload
scatter each), then computes the stacked pooled output ``[B, T, D]`` in a
SINGLE jitted device call — the per-table slot arrays are the only
host->device transfer, and the pooled activations never bounce through
host memory. Pooling honors each table's combiner (sum or mean); the
``hotness`` argument selects the valid id columns per table (and is
validated against the query shape instead of being silently ignored).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig
from repro.core.hps.embedding_cache import DeviceEmbeddingCache
from repro.core.hps.message_bus import Consumer, MessageBus
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("combiners", "apply_mean"))
def _pooled_stack(payloads: Tuple[jax.Array, ...],
                  slots: Tuple[jax.Array, ...],
                  combiners: Tuple[str, ...],
                  apply_mean: bool = True) -> jax.Array:
    """One device dispatch: per-table pooled gathers stacked to [B, T, D]."""
    outs = []
    for p, s, comb in zip(payloads, slots, combiners):
        pooled = ops.pooled_cache_lookup(p, s)           # [B, D] sum over H
        if comb == "mean" and apply_mean:
            denom = jnp.maximum((s >= 0).sum(axis=1, keepdims=True), 1)
            pooled = pooled / denom.astype(pooled.dtype)
        outs.append(pooled)
    return jnp.stack(outs, axis=1)


class HPS:

    def __init__(self, model_name: str,
                 tables: Sequence[EmbeddingTableConfig],
                 pdb: PersistentDB, *,
                 vdb: Optional[VolatileDB] = None,
                 cache_capacity: int = 4096,
                 bus: Optional[MessageBus] = None):
        self.model_name = model_name
        self.tables = tuple(tables)
        self.pdb = pdb
        self.vdb = vdb or VolatileDB()
        self.caches: Dict[str, DeviceEmbeddingCache] = {}
        for t in tables:
            self.caches[t.name] = DeviceEmbeddingCache(
                min(cache_capacity, t.vocab_size), t.dim,
                fetch_fn=self._make_fetch(t.name))
        self.consumer = Consumer(bus, model_name) if bus else None

    # -- L2/L3 fall-through ------------------------------------------------------

    def _make_fetch(self, table: str):
        def fetch(ids: np.ndarray) -> np.ndarray:
            mask, rows = self.vdb.query(table, ids)
            if rows is None:
                rows = np.zeros((len(ids), self._dim(table)), np.float32)
            if not mask.all():
                missing = ids[~mask]
                fetched = self.pdb.fetch(self.model_name, table, missing)
                rows[~mask] = fetched
                self.vdb.insert(table, missing, fetched)  # promote
            return rows
        return fetch

    def _dim(self, table: str) -> int:
        return next(t.dim for t in self.tables if t.name == table)

    # -- public lookup ------------------------------------------------------------

    def _split_query(self, cat: np.ndarray,
                     hotness: Optional[List[int]]) -> List[np.ndarray]:
        """Validate the query shape and return per-table id blocks [B, H_t]."""
        T = len(self.tables)
        if cat.ndim == 2:
            if hotness is None:
                raise ValueError(
                    "2-D cat requires hotness=[ids per table] to split "
                    f"the {cat.shape[1]} id columns over {T} tables")
            if len(hotness) != T:
                raise ValueError(
                    f"hotness has {len(hotness)} entries for {T} tables")
            if sum(hotness) != cat.shape[1]:
                raise ValueError(
                    f"sum(hotness)={sum(hotness)} != cat.shape[1]="
                    f"{cat.shape[1]}")
            return np.split(cat, np.cumsum(hotness)[:-1], axis=1)
        if cat.ndim != 3:
            raise ValueError(f"cat must be [B, T, H] or [B, sum(hotness)]; "
                             f"got shape {cat.shape}")
        if cat.shape[1] != T:
            raise ValueError(
                f"cat.shape[1]={cat.shape[1]} does not match the "
                f"{T} tables of model '{self.model_name}'")
        blocks = [cat[:, ti, :] for ti in range(T)]
        if hotness is not None:
            if len(hotness) != T:
                raise ValueError(
                    f"hotness has {len(hotness)} entries for {T} tables")
            for ti, h in enumerate(hotness):
                if h > cat.shape[2]:
                    raise ValueError(
                        f"hotness[{ti}]={h} exceeds id columns "
                        f"{cat.shape[2]}")
                if h < cat.shape[2]:  # mask columns beyond the hotness
                    blk = blocks[ti].copy()
                    blk[:, h:] = -1
                    blocks[ti] = blk
        return blocks

    def lookup(self, cat: np.ndarray, hotness: Optional[List[int]] = None
               ) -> jax.Array:
        """``cat [B, T, H]`` or ``[B, sum(hotness)]`` (-1 pad) -> pooled
        ``[B, T, D]`` on device, honoring each table's combiner.

        All tables resolve before the single jitted device call; per-table
        misses are coalesced by the L1 cache into one fetch + one scatter.
        Batch sizes are bucketed to powers of two so the variable-size
        serve loop compiles O(log) pooled-gather shapes, not one per
        drained batch size.
        """
        cat = np.asarray(cat)
        blocks = self._split_query(cat, hotness)
        dims = {t.dim for t in self.tables}
        if len(dims) != 1:
            raise ValueError(
                f"stacked lookup needs equal table dims, got {sorted(dims)}")
        b = cat.shape[0]
        if b == 0:
            return jnp.zeros((0, len(self.tables), self.tables[0].dim),
                             jnp.float32)
        bp = 1 << (b - 1).bit_length()

        slot_blocks: List[jax.Array] = []
        payloads: List[jax.Array] = []
        overflow: List[Tuple[int, np.ndarray, np.ndarray, int]] = []
        for ti, (t, ids) in enumerate(zip(self.tables, blocks)):
            flat = np.ascontiguousarray(ids, np.int64).reshape(-1)
            slots, ov_idx, ov_rows, payload = \
                self.caches[t.name].acquire_slots(flat)
            slots = np.pad(slots.reshape(b, ids.shape[1]),
                           ((0, bp - b), (0, 0)), constant_values=-1)
            slot_blocks.append(jnp.asarray(slots, jnp.int32))
            payloads.append(payload)  # lock-consistent snapshot
            if len(ov_idx):
                overflow.append((ti, ov_idx, ov_rows, ids.shape[1]))

        combiners = tuple("mean" if t.combiner == "mean" else "sum"
                          for t in self.tables)
        if not overflow:
            return _pooled_stack(tuple(payloads), tuple(slot_blocks),
                                 combiners)[:b]

        # rare path: some ids exceeded L1 evictable capacity; add their
        # contribution host-side, then apply the mean denominators exactly
        out = _pooled_stack(tuple(payloads), tuple(slot_blocks), combiners,
                            apply_mean=False)[:b]
        dim = self.tables[0].dim
        corr = np.zeros((b, len(self.tables), dim), np.float32)
        for ti, ov_idx, ov_rows, h in overflow:
            np.add.at(corr[:, ti, :], ov_idx // h, ov_rows)
        out = out + jnp.asarray(corr)
        mean_mask = np.asarray([c == "mean" for c in combiners])
        if mean_mask.any():
            denom = np.stack(
                [np.maximum((blk >= 0).sum(axis=1), 1) for blk in blocks],
                axis=1).astype(np.float32)[:, :, None]
            out = jnp.where(jnp.asarray(mean_mask)[None, :, None],
                            out / jnp.asarray(denom), out)
        return out

    # -- online updates -------------------------------------------------------------

    def apply_updates(self) -> int:
        """Poll the message bus into VDB+PDB (L1 refresh is separate)."""
        if self.consumer is None:
            return 0

        def apply(table, ids, rows):
            self.pdb.upsert(self.model_name, table, ids, rows)
            self.vdb.insert(table, ids, rows)

        return self.consumer.poll(apply)

    def refresh_caches(self) -> int:
        return sum(c.refresh_once() for c in self.caches.values())

    def start_refresh(self, interval_s: float):
        for c in self.caches.values():
            c.start_refresh(interval_s)

    def stop_refresh(self):
        for c in self.caches.values():
            c.stop_refresh()

    # -- metrics ---------------------------------------------------------------------

    def stats(self) -> Dict:
        return {
            "l1_hit_rate": {k: c.hit_rate for k, c in self.caches.items()},
            "l2_hits": self.vdb.hits,
            "l2_misses": self.vdb.misses,
        }
