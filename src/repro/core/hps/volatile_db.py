"""Volatile database (HPS level 2) — distributed CPU-memory cache.

Stands in for the paper's Redis-cluster VDB: embedding rows live in the
system memory of (simulated) cluster nodes, sharded by id hash, each shard
bounded by a capacity with LRU eviction. Partial copies only — misses fall
through to the persistent DB.

Vectorized to match the batched L1 path: each shard keeps its rows in a
dense ``[cap, D]`` array with a sorted id index, so a whole query resolves
with one ``np.searchsorted`` per shard and inserts are one slice-assign.
The sorted index is maintained by an *incremental merge* on insert
(victim pairs dropped, the new sorted id block spliced in) — a full
re-sort only happens on the rare explicit ``evict_ids`` compaction.
Rows are **copied** on insert and on query — the store never aliases
caller arrays (the seed kept views into the caller's row buffers, so
later in-place writes by the caller silently mutated the DB).

Access is serialized by one store-wide lock: the HPS pipelined lookup
probes tables from a host worker while the serving thread may apply
online updates or refresh fetches, and all of those paths land here.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np


class _Shard:
    """One (simulated) cluster node: dense rows + sorted id index + LRU."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.rows: Optional[np.ndarray] = None     # [cap, D] lazily alloc'd
        self.id_of = np.full(capacity, -1, np.int64)
        self.tick = np.zeros(capacity, np.int64)   # LRU clock per slot
        self.n = 0
        self.sorted_ids = np.empty(0, np.int64)
        self.sorted_slots = np.empty(0, np.int64)

    def _rebuild(self) -> None:
        occ = self.id_of[:self.n]
        order = np.argsort(occ, kind="stable").astype(np.int64)
        self.sorted_ids = occ[order]
        self.sorted_slots = order

    def find(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized id -> slot (-1 missing); ``ids`` need not be unique."""
        if len(self.sorted_ids) == 0:
            return np.full(len(ids), -1, np.int64)
        pos = np.searchsorted(self.sorted_ids, ids)
        pos = np.clip(pos, 0, len(self.sorted_ids) - 1)
        return np.where(self.sorted_ids[pos] == ids,
                        self.sorted_slots[pos], -1)

    def insert(self, ids: np.ndarray, rows: np.ndarray, now: int) -> None:
        # dedup keeping the LAST occurrence: batched online updates
        # concatenate chronologically, so the newest row must win
        uniq, idx_rev = np.unique(ids[::-1], return_index=True)
        ids, rows = uniq, rows[len(rows) - 1 - idx_rev]
        if self.rows is None:
            self.rows = np.zeros((self.capacity, rows.shape[1]), np.float32)
        slots = self.find(ids)
        hit = slots >= 0
        if hit.any():  # update in place (copies — no aliasing)
            self.rows[slots[hit]] = rows[hit]
            self.tick[slots[hit]] = now
        new_ids, new_rows = ids[~hit], rows[~hit]
        k = len(new_ids)
        if k == 0:
            return
        free = min(k, self.capacity - self.n)
        dest = np.arange(self.n, self.n + free, dtype=np.int64)
        victims = np.empty(0, np.int64)
        if k > free:  # LRU eviction, all victims in one argpartition
            take = min(k - free, self.n)
            if take > 0:
                victims = np.argpartition(self.tick[:self.n],
                                          take - 1)[:take].astype(np.int64)
                dest = np.concatenate([dest, victims])
        sel = np.arange(len(dest))
        # incremental sorted merge, NOT a per-batch re-sort: drop the
        # victims' (id, slot) pairs, then splice the new id block in at
        # its searchsorted positions — O(n + b log n) per batch instead
        # of O(n log n), the dominant host cost of the L2 promote path
        # at high miss rates. new_ids is np.unique output, so the
        # spliced block is already sorted.
        base_ids, base_slots = self.sorted_ids, self.sorted_slots
        if len(victims):
            vpos = np.searchsorted(base_ids, self.id_of[victims])
            keep = np.ones(len(base_ids), bool)
            keep[vpos] = False
            base_ids, base_slots = base_ids[keep], base_slots[keep]
        add_ids = new_ids[sel]
        ins = np.searchsorted(base_ids, add_ids)
        self.sorted_ids = np.insert(base_ids, ins, add_ids)
        self.sorted_slots = np.insert(base_slots, ins, dest)
        self.n += free
        self.id_of[dest] = add_ids
        self.rows[dest] = new_rows[sel]
        self.tick[dest] = now

    def evict_ids(self, ids: np.ndarray) -> None:
        slots = self.find(np.unique(ids))
        slots = slots[slots >= 0]
        if len(slots) == 0:
            return
        # compact the occupied prefix so self.n stays the watermark
        keep = np.setdiff1d(np.arange(self.n), slots)
        m = len(keep)
        self.id_of[:m] = self.id_of[keep]
        if self.rows is not None:
            self.rows[:m] = self.rows[keep]
        self.tick[:m] = self.tick[keep]
        self.id_of[m:self.n] = -1
        self.n = m
        self._rebuild()


class VolatileDB:

    # Checked by `python -m repro.analysis`: shard state, the LRU clock
    # and the hit/miss counters are all behind the one store-wide lock.
    _GUARDED_BY = {
        "_store": "_lock", "_now": "_lock",
        "hits": "_lock", "misses": "_lock",
    }

    def __init__(self, *, shards: int = 1, capacity_per_shard: int = 100000):
        self.shards = shards
        self.capacity = capacity_per_shard
        self._store: Dict[str, List[_Shard]] = {}  # table -> shard list
        self._now = 0
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()

    def _ns_locked(self, table: str) -> List[_Shard]:
        if table not in self._store:
            self._store[table] = [_Shard(self.capacity)
                                  for _ in range(self.shards)]
        return self._store[table]

    def query(self, table: str, ids: np.ndarray
              ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Returns (found_mask, rows) — rows is None if nothing found.

        ``rows`` is freshly allocated (never a view into the store).
        """
        with self._lock:
            return self._query_locked(table, ids)

    def _query_locked(self, table: str, ids: np.ndarray
                      ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        ns = self._ns_locked(table)
        ids = np.asarray(ids, np.int64)
        self._now += 1
        mask = np.zeros(len(ids), bool)
        rows = None
        shard_of = ids % self.shards
        for s, shard in enumerate(ns):
            in_s = np.nonzero(shard_of == s)[0]
            if len(in_s) == 0 or shard.rows is None:
                continue
            slots = shard.find(ids[in_s])
            hit = slots >= 0
            if not hit.any():
                continue
            if rows is None:
                rows = np.zeros((len(ids), shard.rows.shape[1]), np.float32)
            rows[in_s[hit]] = shard.rows[slots[hit]]
            shard.tick[slots[hit]] = self._now       # LRU touch
            mask[in_s] = hit
        self.hits += int(mask.sum())
        self.misses += int((~mask).sum())
        return mask, rows

    def insert(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        with self._lock:
            ns = self._ns_locked(table)
            ids = np.asarray(ids, np.int64)
            rows = np.asarray(rows, np.float32)
            self._now += 1
            shard_of = ids % self.shards
            for s, shard in enumerate(ns):
                in_s = np.nonzero(shard_of == s)[0]
                if len(in_s):
                    shard.insert(ids[in_s], rows[in_s].copy(), self._now)

    def evict(self, table: str, ids: np.ndarray) -> None:
        with self._lock:
            ns = self._ns_locked(table)
            ids = np.asarray(ids, np.int64)
            shard_of = ids % self.shards
            for s, shard in enumerate(ns):
                in_s = np.nonzero(shard_of == s)[0]
                if len(in_s):
                    shard.evict_ids(ids[in_s])

    def size(self, table: str) -> int:
        with self._lock:
            return sum(s.n for s in self._ns_locked(table))

    def stats(self) -> Dict:
        """Per-table occupancy for the serving L1/L2/L3 picture."""
        with self._lock:
            cap = self.shards * self.capacity
            tables = {t: {"rows": sum(s.n for s in shards),
                          "fill": sum(s.n for s in shards) / cap}
                      for t, shards in self._store.items()}
            return {"hits": self.hits, "misses": self.misses,
                    "shards": self.shards, "capacity_per_shard":
                    self.capacity, "tables": tables}
