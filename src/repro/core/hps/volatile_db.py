"""Volatile database (HPS level 2) — distributed CPU-memory cache.

Stands in for the paper's Redis-cluster VDB: embedding rows live in the
system memory of (simulated) cluster nodes, sharded by id hash, each shard
bounded by a capacity with LRU eviction. Partial copies only — misses fall
through to the persistent DB.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


class VolatileDB:

    def __init__(self, *, shards: int = 1, capacity_per_shard: int = 100000):
        self.shards = shards
        self.capacity = capacity_per_shard
        # namespace (model, table) -> shard -> OrderedDict[id, row]
        self._store: Dict[str, list] = {}
        self.hits = 0
        self.misses = 0

    def _ns(self, table: str) -> list:
        if table not in self._store:
            self._store[table] = [OrderedDict() for _ in range(self.shards)]
        return self._store[table]

    def query(self, table: str, ids: np.ndarray
              ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Returns (found_mask, rows) — rows is None if nothing found."""
        ns = self._ns(table)
        mask = np.zeros(len(ids), bool)
        rows = None
        for i, id_ in enumerate(map(int, ids)):
            shard = ns[id_ % self.shards]
            row = shard.get(id_)
            if row is not None:
                shard.move_to_end(id_)
                if rows is None:
                    rows = np.zeros((len(ids), len(row)), np.float32)
                rows[i] = row
                mask[i] = True
        self.hits += int(mask.sum())
        self.misses += int((~mask).sum())
        return mask, rows

    def insert(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        ns = self._ns(table)
        for id_, row in zip(map(int, ids), rows):
            shard = ns[id_ % self.shards]
            if id_ in shard:
                shard.move_to_end(id_)
            elif len(shard) >= self.capacity:
                shard.popitem(last=False)
            shard[id_] = np.asarray(row, np.float32)

    def evict(self, table: str, ids: np.ndarray) -> None:
        ns = self._ns(table)
        for id_ in map(int, ids):
            ns[id_ % self.shards].pop(id_, None)

    def size(self, table: str) -> int:
        return sum(len(s) for s in self._ns(table))
