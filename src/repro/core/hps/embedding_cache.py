"""GPU embedding cache (HPS level 1) — batched, vectorized lookup path.

Device-resident payload ``[C, D]`` + host-side index, following HugeCTR's
split between the GDDR payload and its host-managed hash index (which is
also the only TPU-viable layout — DESIGN.md §2). Features from the paper:
optimized batched query, **dynamic insertion** (misses get cached), and an
**asynchronous refresh** thread that re-pulls resident rows from the lower
levels so online-training updates propagate without blocking queries.

Architecture (the batched-query design of the companion HPS paper,
arXiv 2210.08804):

* The host index is a pair of sorted NumPy arrays (``ids`` / ``slots``);
  a whole query resolves with ONE ``np.searchsorted`` — no per-id Python
  dict probes.
* All misses in a query are deduplicated and coalesced into ONE
  ``fetch_fn`` call and ONE scatter onto the device payload
  (``payload.at[slots].set(rows)``).
* The payload read is a single Pallas gather kernel dispatch
  (``kernels.hps_gather``), so ``query`` is one device round-trip
  regardless of batch size: O(1) device dispatches per batch.

Eviction is LFU-with-aging (hot features stick, per the paper's intent)
and **batch-aware**: victims are selected in one vectorized pass over the
pre-query index state, so a query's own insertions — and the slots it is
about to read — are never its eviction victims. If a single query holds
more unique ids than the evictable capacity, the most frequent misses are
cached and the remainder is served through a rare overflow fixup (one
extra scatter into the output), never corrupting resident rows.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class DeviceEmbeddingCache:

    def __init__(self, capacity: int, dim: int, *,
                 fetch_fn: Callable[[np.ndarray], np.ndarray],
                 decay: float = 0.99):
        """``fetch_fn(missing_ids) -> rows`` pulls from VDB/PDB."""
        self.capacity = capacity
        self.dim = dim
        self.fetch_fn = fetch_fn
        self.decay = decay
        # physical rows padded to the gather kernel's tile so the jitted
        # gather never copies the payload to pad it
        bc = min(512, _round_up(capacity, 8))
        self._phys_rows = _round_up(capacity, bc)
        self.payload = jnp.zeros((self._phys_rows, dim), jnp.float32)
        self._id_of = np.full(capacity, -1, np.int64)
        self._freq = np.zeros(capacity, np.float64)
        self._next_free = 0
        # sorted view of the occupied prefix: _sorted_ids[k] lives in slot
        # _sorted_slots[k]; rebuilt only on insert/evict (hit path is pure
        # searchsorted)
        self._sorted_ids = np.empty(0, np.int64)
        self._sorted_slots = np.empty(0, np.int64)
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()
        self._refresh_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- host index --------------------------------------------------------------

    def _find(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized id -> slot (-1 if not resident). ``ids`` unique."""
        if len(self._sorted_ids) == 0:
            return np.full(len(ids), -1, np.int64)
        pos = np.searchsorted(self._sorted_ids, ids)
        pos = np.clip(pos, 0, len(self._sorted_ids) - 1)
        found = self._sorted_ids[pos] == ids
        return np.where(found, self._sorted_slots[pos], -1)

    def _rebuild_index(self) -> None:
        occ = self._id_of[:self._next_free]
        order = np.argsort(occ, kind="stable").astype(np.int64)
        self._sorted_ids = occ[order]
        self._sorted_slots = order

    def resident_ids(self) -> np.ndarray:
        """Ids currently resident in the cache (sorted)."""
        with self._lock:
            return self._sorted_ids.copy()

    # -- query -------------------------------------------------------------------

    def acquire_slots(self, ids: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 jax.Array]:
        """Resolve ``ids [n]`` (-1 = pad) to payload slots, inserting misses.

        Returns ``(slots [n], ov_idx [m], ov_rows [m, D], payload)``:
        ``slots`` are payload row indices (-1 for pads and overflowed
        ids); overflowed ids — misses that could not be cached without
        evicting this query's own rows — are served out-of-band via
        ``ov_rows`` at positions ``ov_idx``. ``payload`` is the
        post-insertion snapshot bound under the same lock: gather from
        IT, not ``self.payload`` — a concurrent query may evict the
        returned slots and rebind ``self.payload`` before the gather
        runs (eviction only protects the evicting query's own hits).
        Performs at most ONE ``fetch_fn`` call and ONE device scatter.
        """
        with self._lock:
            slots, ov_idx, ov_rows = self._acquire_locked(
                np.asarray(ids, np.int64))
            return slots, ov_idx, ov_rows, self.payload

    def _acquire_locked(self, ids: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = len(ids)
        empty = (np.empty(0, np.int64),
                 np.empty((0, self.dim), np.float32))
        if n == 0:
            return np.empty(0, np.int64), *empty
        valid = ids >= 0
        uniq, inv = np.unique(np.where(valid, ids, -1), return_inverse=True)
        counts = np.bincount(inv, minlength=len(uniq))
        has_pad = len(uniq) > 0 and uniq[0] < 0
        slots_u = np.full(len(uniq), -1, np.int64)
        real = slice(1, None) if has_pad else slice(None)
        slots_u[real] = self._find(uniq[real])
        found = slots_u >= 0
        real_mask = uniq >= 0
        self.hits += int(counts[found].sum())
        self.misses += int(counts[real_mask & ~found].sum())
        if found.any():
            np.add.at(self._freq, slots_u[found],
                      counts[found].astype(np.float64))

        miss = real_mask & ~found
        ov_idx, ov_rows = empty
        if miss.any():
            miss_ids = uniq[miss]
            rows = np.asarray(self.fetch_fn(miss_ids), np.float32)
            k = len(miss_ids)
            n_occ = self._next_free
            free = min(k, self.capacity - n_occ)
            dest_free = np.arange(n_occ, n_occ + free, dtype=np.int64)
            victims = np.empty(0, np.int64)
            if k > free:
                # batch-aware LFU eviction: age once per batch, protect
                # the slots this query reads; victims picked in one
                # argpartition are distinct, so same-batch insertions can
                # never evict each other
                self._freq[:n_occ] *= self.decay
                cost = self._freq[:n_occ].copy()
                hit_slots = slots_u[found]
                cost[hit_slots] = np.inf
                evictable = n_occ - len(np.unique(hit_slots))
                take = min(k - free, evictable)
                if take > 0:
                    victims = np.argpartition(cost, take - 1)[:take]
                    victims = victims.astype(np.int64)
            dest = np.concatenate([dest_free, victims])
            ins = len(dest)
            if ins < k:  # cache the hottest misses, overflow the rest
                order = np.argsort(-counts[miss], kind="stable")
            else:
                order = np.arange(k)
            sel, ovf = order[:ins], order[ins:]

            self._next_free = n_occ + free
            self._id_of[dest] = miss_ids[sel]
            self._freq[dest] = counts[miss][sel].astype(np.float64)
            self._rebuild_index()
            if ins:  # the ONE device scatter for this query
                self._scatter(dest, rows[sel])
            miss_slots = np.full(k, -1, np.int64)
            miss_slots[sel] = dest
            slots_u[miss] = miss_slots

            if len(ovf):
                ov_uniq = np.full(len(uniq), -1, np.int64)
                ov_pos_u = np.nonzero(miss)[0][ovf]
                ov_uniq[ov_pos_u] = np.arange(len(ovf))
                per_elem = ov_uniq[inv]
                ov_idx = np.nonzero(per_elem >= 0)[0].astype(np.int64)
                ov_rows = rows[ovf][per_elem[ov_idx]]

        return slots_u[inv].astype(np.int64), ov_idx, ov_rows

    def _scatter(self, slots: np.ndarray, rows: np.ndarray) -> None:
        """One ``payload.at[slots].set(rows)``, size-bucketed so XLA
        compiles O(log) scatter shapes instead of one per miss count
        (padding repeats the first row — idempotent under ``set``)."""
        pad = _round_up(len(slots), 64) - len(slots)
        if pad:
            slots = np.concatenate([slots, np.full(pad, slots[0])])
            rows = np.concatenate(
                [rows, np.broadcast_to(rows[:1], (pad, rows.shape[1]))])
        self.payload = self.payload.at[
            jnp.asarray(slots, jnp.int32)].set(jnp.asarray(rows))

    def query(self, ids: np.ndarray) -> jax.Array:
        """Batched lookup ``[n] -> [n, D]`` with dynamic insertion.

        One host index pass, at most one fetch + one scatter, and exactly
        one Pallas gather dispatch for the payload read. Query lengths
        are bucketed to powers of two so XLA compiles O(log) gather
        shapes rather than one per batch size.
        """
        slots, ov_idx, ov_rows, payload = self.acquire_slots(ids)
        n = len(slots)
        if n == 0:
            return jnp.zeros((0, self.dim), jnp.float32)
        bucket = 1 << (n - 1).bit_length()
        spad = np.pad(slots, (0, bucket - n), constant_values=-1)
        out = ops.cache_gather(payload, spad)[:n]
        if len(ov_idx):  # rare: batch exceeded evictable capacity
            out = out.at[jnp.asarray(ov_idx)].set(jnp.asarray(ov_rows))
        return out

    # -- refresh (async propagation of online updates) --------------------------

    def refresh_once(self) -> int:
        """Re-pull every resident row from the lower levels (one scatter)."""
        with self._lock:
            res_ids = self._sorted_ids.copy()
            res_slots = self._sorted_slots.copy()
        if len(res_ids) == 0:
            return 0
        rows = np.asarray(self.fetch_fn(res_ids), np.float32)  # slow IO
        with self._lock:
            # ids may have been evicted/moved meanwhile; re-check
            keep = self._find(res_ids) == res_slots
            if keep.any():
                self._scatter(res_slots[keep], rows[keep])
            return int(keep.sum())

    def start_refresh(self, interval_s: float):
        def loop():
            while not self._stop.wait(interval_s):
                self.refresh_once()
        self._refresh_thread = threading.Thread(target=loop, daemon=True)
        self._refresh_thread.start()

    def stop_refresh(self):
        self._stop.set()
        if self._refresh_thread:
            self._refresh_thread.join()
            self._refresh_thread = None
        self._stop.clear()

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
