"""GPU embedding cache (HPS level 1) — batched, vectorized lookup path.

Device-resident payload + host-side index, following HugeCTR's split
between the GDDR payload and its host-managed hash index (which is also
the only TPU-viable layout — DESIGN.md §2). Features from the paper:
optimized batched query, **dynamic insertion** (misses get cached), and
an **update-propagation scheduler** that re-pulls resident rows from the
lower levels so online-training updates reach serving without blocking
queries.

Architecture (the batched-query design of the companion HPS paper,
arXiv 2210.08804):

* The host index is a pair of sorted NumPy arrays (``ids`` / ``slots``);
  a whole query resolves with ONE ``np.searchsorted`` — no per-id Python
  dict probes.
* All misses in a query are deduplicated and coalesced into ONE
  ``fetch_fn`` call and ONE scatter onto the device payload.
* Physical slot storage lives in a ``ShardedPayloadStore``: a single
  payload by default, or row-striped across a mesh (slot ``s`` on stripe
  ``s % N``) so the hot set scales past one device's HBM. The logical
  slot indirection keeps everything in this file layout-agnostic.
* The payload read is a single gather dispatch (``kernels.hps_gather``
  on TPU), so ``query`` is one device round-trip regardless of batch
  size: O(1) device dispatches per batch.

The query path is split into a **host stage** (``probe``: index probe +
coalesced miss fetch) and a **device stage** (``commit``: the one payload
scatter + snapshot binding) so a pipelined caller can overlap table
*t+1*'s probe with table *t*'s scatter. The deferred scatter is flushed
by whoever touches the cache next (probe, commit, or refresh — all under
the cache lock), so the payload is always current before any new index
decision, and each plan's snapshot is bound before any *later* query can
evict the slots it references. ``acquire_slots`` = probe + commit
back-to-back, which reproduces the unpipelined behavior exactly.

Eviction is LFU-with-aging (hot features stick, per the paper's intent)
and **batch-aware**: victims are selected in one vectorized pass over the
pre-query index state, so a query's own insertions — and the slots it is
about to read — are never its eviction victims. If a single query holds
more unique ids than the evictable capacity, the most frequent misses are
cached and the remainder is served through a rare overflow fixup (one
extra scatter into the output), never corrupting resident rows.

Refresh is **hotness-scheduled**: online updates (or a poll cycle) mark
resident rows dirty; ``refresh_chunk`` claims up to a per-cycle budget of
the dirtiest-AND-hottest rows (LFU counters order the backlog), re-pulls
them from the lower levels outside the lock, and scatters only rows whose
id->slot binding survived — so refresh interleaves with serving instead
of stopping the world. ``refresh_once`` (mark everything + drain) remains
as the full-repull convenience.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hps.payload_store import ShardedPayloadStore


class LookupPlan:
    """Host-stage output: resolved slots + out-of-band overflow rows,
    with the payload snapshot bound at device-stage time (``commit``)."""

    __slots__ = ("slots", "ov_idx", "ov_rows", "payload")

    def __init__(self, slots: np.ndarray, ov_idx: np.ndarray,
                 ov_rows: np.ndarray, payload):
        self.slots = slots
        self.ov_idx = ov_idx
        self.ov_rows = ov_rows
        self.payload = payload


class DeviceEmbeddingCache:

    # Concurrency contract, checked by `python -m repro.analysis`: every
    # listed attribute may only be touched under self._lock. fetch_fn is
    # the injected L2/L3 fall-through, which takes the VDB/PDB locks and
    # bumps the HPS L3 counters — declared so the lock-order pass sees
    # the cross-object edges.
    _GUARDED_BY = {
        "_id_of": "_lock", "_freq": "_lock", "_next_free": "_lock",
        "_sorted_ids": "_lock", "_sorted_slots": "_lock",
        "_pending": "_lock", "_pending_plan": "_lock",
        "_dirty": "_lock", "hits": "_lock", "misses": "_lock",
        "rows_refreshed": "_lock", "refresh_chunks": "_lock",
    }
    _LOCKS_OF = {
        "fetch_fn": ("VolatileDB._lock", "PersistentDB._lock",
                     "HPS._l3_stats_lock"),
    }

    def __init__(self, capacity: int, dim: int, *,
                 fetch_fn: Callable[[np.ndarray], np.ndarray],
                 decay: float = 0.99, shards: int = 1, mesh=None,
                 refresh_chunk_rows: int = 1024,
                 payload_dtype: str = "f32"):
        """``fetch_fn(missing_ids) -> rows`` pulls from VDB/PDB.

        ``shards``/``mesh`` select the striped payload layout (see
        ``payload_store``); ``shards=1`` is the classic single payload.
        ``payload_dtype`` selects the storage precision (f32/f16/int8) —
        inserts and refreshes quantize on the way in, the gather
        dequantizes in-kernel, so everything in this file stays f32.
        """
        self.capacity = capacity
        self.dim = dim
        self.fetch_fn = fetch_fn
        self.decay = decay
        self.payload_dtype = payload_dtype
        self._store = ShardedPayloadStore(capacity, dim, shards=shards,
                                          mesh=mesh,
                                          payload_dtype=payload_dtype)
        self._id_of = np.full(capacity, -1, np.int64)
        self._freq = np.zeros(capacity, np.float64)
        self._next_free = 0
        # sorted view of the occupied prefix: _sorted_ids[k] lives in slot
        # _sorted_slots[k]; rebuilt only on insert/evict (hit path is pure
        # searchsorted)
        self._sorted_ids = np.empty(0, np.int64)
        self._sorted_slots = np.empty(0, np.int64)
        self.hits = 0
        self.misses = 0
        # deferred device stage: at most one pending scatter, plus the
        # plan (if any) whose snapshot must bind when it flushes
        self._pending: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._pending_plan: Optional[LookupPlan] = None
        # refresh scheduler state
        self._dirty = np.zeros(capacity, bool)
        self.refresh_chunk_rows = refresh_chunk_rows
        self.rows_refreshed = 0
        self.refresh_chunks = 0
        self._lock = threading.RLock()
        self._refresh_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    @property
    def shards(self) -> int:
        return self._store.shards

    @property
    def payload(self):
        """Current ``(payload, scales)`` snapshot pair (pending device
        stage flushed; ``scales`` is None outside int8 mode)."""
        with self._lock:
            self._flush_pending_locked()
            return self._store.snapshot()

    # -- host index --------------------------------------------------------------

    def _find_locked(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized id -> slot (-1 if not resident). ``ids`` unique."""
        if len(self._sorted_ids) == 0:
            return np.full(len(ids), -1, np.int64)
        pos = np.searchsorted(self._sorted_ids, ids)
        pos = np.clip(pos, 0, len(self._sorted_ids) - 1)
        found = self._sorted_ids[pos] == ids
        return np.where(found, self._sorted_slots[pos], -1)

    def _rebuild_index_locked(self) -> None:
        occ = self._id_of[:self._next_free]
        order = np.argsort(occ, kind="stable").astype(np.int64)
        self._sorted_ids = occ[order]
        self._sorted_slots = order

    def resident_ids(self) -> np.ndarray:
        """Ids currently resident in the cache (sorted)."""
        with self._lock:
            return self._sorted_ids.copy()

    # -- two-stage query ---------------------------------------------------------

    def probe(self, ids: np.ndarray) -> LookupPlan:
        """HOST stage: resolve ``ids [n]`` (-1 = pad) to payload slots,
        fetching + index-inserting misses; the payload scatter is
        deferred to the device stage (``commit``).

        The snapshot for an all-hit plan binds immediately (the payload
        is already current); a plan with pending insertions gets its
        snapshot when the scatter flushes — in ``commit``, or in the
        next ``probe``/refresh on this cache, whichever comes first.
        Either way the snapshot is bound *before* any later query can
        change the index, so the plan's slots always gather correctly
        from it.
        """
        with self._lock:
            self._flush_pending_locked()
            slots, ov_idx, ov_rows = self._probe_locked(
                np.asarray(ids, np.int64))
            plan = LookupPlan(slots, ov_idx, ov_rows, None)
            if self._pending is None:
                plan.payload = self._store.snapshot()
            else:
                self._pending_plan = plan
            return plan

    def commit(self, plan: LookupPlan):
        """DEVICE stage: dispatch the plan's deferred payload scatter
        (if still pending) and return its lock-consistent snapshot.
        Gather from IT, not ``self.payload`` — a later query may evict
        the plan's slots and rebind the store before the gather runs."""
        if plan.payload is None:
            with self._lock:
                self._flush_pending_locked()
        return plan.payload

    def acquire_slots(self, ids: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, tuple]:
        """Both stages back-to-back (the unpipelined path).

        Returns ``(slots [n], ov_idx [m], ov_rows [m, D], payload)``:
        ``slots`` are logical payload slots (-1 for pads and overflowed
        ids); overflowed ids — misses that could not be cached without
        evicting this query's own rows — are served out-of-band via
        ``ov_rows`` at positions ``ov_idx``. Performs at most ONE
        ``fetch_fn`` call and ONE device scatter.
        """
        plan = self.probe(ids)
        return plan.slots, plan.ov_idx, plan.ov_rows, self.commit(plan)

    def _flush_pending_locked(self) -> None:
        """Dispatch the deferred scatter and bind the waiting plan's
        snapshot. Called on every lock acquisition that reads or mutates
        the payload, preserving the invariant: index state and payload
        content agree whenever the lock is held."""
        if self._pending is not None:
            dest, rows = self._pending
            self._pending = None
            self._scatter_locked(dest, rows)
        if self._pending_plan is not None:
            self._pending_plan.payload = self._store.snapshot()
            self._pending_plan = None

    def _probe_locked(self, ids: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = len(ids)
        empty = (np.empty(0, np.int64),
                 np.empty((0, self.dim), np.float32))
        if n == 0:
            return np.empty(0, np.int64), *empty
        valid = ids >= 0
        uniq, inv = np.unique(np.where(valid, ids, -1), return_inverse=True)
        counts = np.bincount(inv, minlength=len(uniq))
        has_pad = len(uniq) > 0 and uniq[0] < 0
        slots_u = np.full(len(uniq), -1, np.int64)
        real = slice(1, None) if has_pad else slice(None)
        slots_u[real] = self._find_locked(uniq[real])
        found = slots_u >= 0
        real_mask = uniq >= 0
        self.hits += int(counts[found].sum())
        self.misses += int(counts[real_mask & ~found].sum())
        if found.any():
            np.add.at(self._freq, slots_u[found],
                      counts[found].astype(np.float64))

        miss = real_mask & ~found
        ov_idx, ov_rows = empty
        if miss.any():
            miss_ids = uniq[miss]
            # lock-ok: LOCK002 probe fetch under the lock preserves same-table ordering; the pipelined engine keeps it off the hot thread
            rows = np.asarray(self.fetch_fn(miss_ids), np.float32)
            k = len(miss_ids)
            n_occ = self._next_free
            free = min(k, self.capacity - n_occ)
            dest_free = np.arange(n_occ, n_occ + free, dtype=np.int64)
            victims = np.empty(0, np.int64)
            if k > free:
                # batch-aware LFU eviction: age once per batch, protect
                # the slots this query reads; victims picked in one
                # argpartition are distinct, so same-batch insertions can
                # never evict each other
                self._freq[:n_occ] *= self.decay
                cost = self._freq[:n_occ].copy()
                hit_slots = slots_u[found]
                cost[hit_slots] = np.inf
                evictable = n_occ - len(np.unique(hit_slots))
                take = min(k - free, evictable)
                if take > 0:
                    victims = np.argpartition(cost, take - 1)[:take]
                    victims = victims.astype(np.int64)
            dest = np.concatenate([dest_free, victims])
            ins = len(dest)
            if ins < k:  # cache the hottest misses, overflow the rest
                order = np.argsort(-counts[miss], kind="stable")
            else:
                order = np.arange(k)
            sel, ovf = order[:ins], order[ins:]

            self._next_free = n_occ + free
            self._id_of[dest] = miss_ids[sel]
            self._freq[dest] = counts[miss][sel].astype(np.float64)
            self._dirty[dest] = False      # fresh from the lower levels
            self._rebuild_index_locked()
            if ins:  # the ONE device scatter, deferred to commit()
                self._pending = (dest, rows[sel])
            miss_slots = np.full(k, -1, np.int64)
            miss_slots[sel] = dest
            slots_u[miss] = miss_slots

            if len(ovf):
                ov_uniq = np.full(len(uniq), -1, np.int64)
                ov_pos_u = np.nonzero(miss)[0][ovf]
                ov_uniq[ov_pos_u] = np.arange(len(ovf))
                per_elem = ov_uniq[inv]
                ov_idx = np.nonzero(per_elem >= 0)[0].astype(np.int64)
                ov_rows = rows[ovf][per_elem[ov_idx]]

        return slots_u[inv].astype(np.int64), ov_idx, ov_rows

    def _scatter_locked(self, slots: np.ndarray, rows: np.ndarray) -> None:
        """The one device scatter (striping handled by the store)."""
        self._store.scatter(slots, rows)

    def query(self, ids: np.ndarray) -> jax.Array:
        """Batched lookup ``[n] -> [n, D]`` with dynamic insertion.

        One host index pass, at most one fetch + one scatter, and exactly
        one gather dispatch for the payload read. Query lengths are
        bucketed to powers of two so XLA compiles O(log) gather shapes
        rather than one per batch size.
        """
        slots, ov_idx, ov_rows, payload = self.acquire_slots(ids)
        n = len(slots)
        if n == 0:
            return jnp.zeros((0, self.dim), jnp.float32)
        bucket = 1 << (n - 1).bit_length()
        spad = np.pad(slots, (0, bucket - n), constant_values=-1)
        out = self._store.gather(payload, spad)[:n]
        if len(ov_idx):  # rare: batch exceeded evictable capacity
            out = out.at[jnp.asarray(ov_idx)].set(jnp.asarray(ov_rows))
        return out

    # -- hotness-scheduled refresh (propagation of online updates) ---------------

    def mark_dirty(self, ids: np.ndarray) -> int:
        """Schedule resident rows among ``ids`` for refresh (the lower
        levels changed under them). Returns how many were resident."""
        ids = np.unique(np.asarray(ids, np.int64))
        with self._lock:
            slots = self._find_locked(ids)
            slots = slots[slots >= 0]
            self._dirty[slots] = True
            return len(slots)

    def mark_all_dirty(self) -> int:
        """Schedule every resident row (the poll-cycle fallback when no
        update stream says which rows changed)."""
        with self._lock:
            n = self._next_free
            self._dirty[:n] = True
            return n

    def refresh_backlog(self) -> int:
        """Rows currently scheduled for refresh."""
        with self._lock:
            return int(self._dirty[:self._next_free].sum())

    def refresh_chunk(self, budget: Optional[int] = None) -> int:
        """Refresh up to ``budget`` scheduled rows, hottest first.

        Claims the selected rows (clears their dirty bit) under the lock,
        re-pulls them from the lower levels with the lock RELEASED (the
        slow IO never blocks serving), then scatters only rows whose
        id->slot binding survived the interim — an update that lands
        mid-fetch re-marks the row, so the next chunk repairs it.
        Returns the number of rows actually refreshed on device.
        """
        budget = self.refresh_chunk_rows if budget is None else budget
        if budget <= 0:
            return 0
        with self._lock:
            self._flush_pending_locked()
            occ = self._next_free
            cand = np.nonzero(self._dirty[:occ])[0]
            if len(cand) == 0:
                return 0
            if len(cand) > budget:
                hot = np.argpartition(-self._freq[cand], budget - 1)
                cand = cand[hot[:budget]]
            slots = np.sort(cand).astype(np.int64)
            self._dirty[slots] = False            # claimed
            ids = self._id_of[slots].copy()
        rows = np.asarray(self.fetch_fn(ids), np.float32)   # slow IO
        with self._lock:
            keep = self._find_locked(ids) == slots  # binding may have moved
            kept = int(keep.sum())
            if kept:
                self._scatter_locked(slots[keep], rows[keep])
            self.rows_refreshed += kept
            self.refresh_chunks += 1
            return kept

    def refresh_once(self, chunk: Optional[int] = None) -> int:
        """Re-pull every resident row from the lower levels, in
        hotness-ordered bounded chunks (the full-repull convenience)."""
        marked = self.mark_all_dirty()
        if marked == 0:
            return 0
        chunk = chunk or self.refresh_chunk_rows
        total = 0
        # enough rounds to drain what we just marked; rows re-marked
        # concurrently are the next cycle's work
        for _ in range(-(-marked // chunk) + 1):
            if self.refresh_backlog() == 0:
                break
            total += self.refresh_chunk(chunk)
        return total

    # -- capacity rebalance (ensemble budget re-split) ---------------------------

    def resize(self, new_capacity: int) -> int:
        """Rebuild the cache at ``new_capacity``, retaining the hottest
        resident rows (LFU counters order the survivors). Used by the
        ensemble budget rebalancer — a rare control-plane operation, not
        a serving-path one. Returns how many rows were retained.

        The survivors are re-pulled from the lower levels so compressed
        payloads requantize from full-precision sources, never from
        their own dequantized rows.
        """
        if new_capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {new_capacity}")
        if self._store.shards > new_capacity:
            raise ValueError(
                f"new_capacity={new_capacity} is below the store's "
                f"shard count {self._store.shards}")
        with self._lock:
            if new_capacity == self.capacity:
                return self._next_free
            self._flush_pending_locked()
            n_occ = self._next_free
            keep = min(n_occ, new_capacity)
            ids = freqs = rows = None
            if keep:
                hot = np.argsort(-self._freq[:n_occ],
                                 kind="stable")[:keep].astype(np.int64)
                ids = self._id_of[hot].copy()
                freqs = self._freq[hot].copy()
                # lock-ok: LOCK002 resize is a rare control-plane op; re-pulling survivors under the lock keeps index and payload atomic
                rows = np.asarray(self.fetch_fn(ids), np.float32)
            self._store = ShardedPayloadStore(
                new_capacity, self.dim, shards=self._store.shards,
                mesh=self._store.mesh, axis=self._store.axis,
                payload_dtype=self.payload_dtype)
            self.capacity = new_capacity
            self._id_of = np.full(new_capacity, -1, np.int64)
            self._freq = np.zeros(new_capacity, np.float64)
            self._dirty = np.zeros(new_capacity, bool)
            self._next_free = keep
            if keep:
                dest = np.arange(keep, dtype=np.int64)
                self._id_of[dest] = ids
                self._freq[dest] = freqs
                self._scatter_locked(dest, rows)
            self._rebuild_index_locked()
            return keep

    def start_refresh(self, interval_s: float):
        def loop():
            while not self._stop.wait(interval_s):
                self.refresh_once()
        self._refresh_thread = threading.Thread(target=loop, daemon=True)
        self._refresh_thread.start()

    def stop_refresh(self):
        self._stop.set()
        if self._refresh_thread:
            self._refresh_thread.join()
            self._refresh_thread = None
        self._stop.clear()

    def counters(self) -> dict:
        """Lock-consistent snapshot of the serving counters."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "rows_refreshed": self.rows_refreshed,
                    "refresh_chunks": self.refresh_chunks}

    @property
    def hit_rate(self) -> float:
        with self._lock:
            hits, misses = self.hits, self.misses
        n = hits + misses
        return hits / n if n else 0.0
