"""GPU embedding cache (HPS level 1).

Device-resident payload ``[C, D]`` + host-side index, following HugeCTR's
split between the GDDR payload and its host-managed hash index (which is
also the only TPU-viable layout — DESIGN.md §2). Features from the paper:
optimized batched query, **dynamic insertion** (misses get cached), and an
**asynchronous refresh** thread that re-pulls resident rows from the lower
levels so online-training updates propagate without blocking queries.

Eviction is LFU-with-aging (hot features stick, per the paper's intent).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class DeviceEmbeddingCache:

    def __init__(self, capacity: int, dim: int, *,
                 fetch_fn: Callable[[np.ndarray], np.ndarray],
                 decay: float = 0.99):
        """``fetch_fn(missing_ids) -> rows`` pulls from VDB/PDB."""
        self.capacity = capacity
        self.dim = dim
        self.fetch_fn = fetch_fn
        self.decay = decay
        self.payload = jnp.zeros((capacity, dim), jnp.float32)
        self._slot_of: Dict[int, int] = {}
        self._id_of = np.full(capacity, -1, np.int64)
        self._freq = np.zeros(capacity, np.float64)
        self._next_free = 0
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()
        self._refresh_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- query -----------------------------------------------------------------

    def query(self, ids: np.ndarray) -> jax.Array:
        """Batched lookup ``[n] -> [n, D]`` with dynamic insertion."""
        with self._lock:
            slots = np.empty(len(ids), np.int64)
            missing_idx = []
            for i, id_ in enumerate(map(int, ids)):
                s = self._slot_of.get(id_, -1)
                slots[i] = s
                if s < 0:
                    missing_idx.append(i)
                else:
                    self._freq[s] += 1.0
            self.hits += len(ids) - len(missing_idx)
            self.misses += len(missing_idx)
            if missing_idx:
                miss_ids = ids[missing_idx]
                rows = self.fetch_fn(miss_ids)
                ins = self._insert_locked(miss_ids, rows)
                slots[missing_idx] = ins
            return jnp.take(self.payload, jnp.asarray(slots), axis=0)

    def _insert_locked(self, ids: np.ndarray, rows: np.ndarray) -> np.ndarray:
        slots = np.empty(len(ids), np.int64)
        for k, (id_, row) in enumerate(zip(map(int, ids), rows)):
            if id_ in self._slot_of:          # raced in by another query
                slots[k] = self._slot_of[id_]
                continue
            if self._next_free < self.capacity:
                s = self._next_free
                self._next_free += 1
            else:
                self._freq *= self.decay      # aging
                s = int(self._freq.argmin())
                old = self._id_of[s]
                if old >= 0:
                    del self._slot_of[old]
            self._slot_of[id_] = s
            self._id_of[s] = id_
            self._freq[s] = 1.0
            slots[k] = s
            self.payload = self.payload.at[s].set(jnp.asarray(row))
        return slots

    # -- refresh (async propagation of online updates) --------------------------

    def refresh_once(self) -> int:
        """Re-pull every resident row from the lower levels."""
        with self._lock:
            resident = np.asarray(
                [i for i in self._id_of[:self._next_free] if i >= 0])
            if len(resident) == 0:
                return 0
            slots = np.asarray([self._slot_of[int(i)] for i in resident])
        rows = self.fetch_fn(resident)        # outside lock: slow IO
        with self._lock:
            # ids may have been evicted meanwhile; re-check
            keep = [k for k, i in enumerate(map(int, resident))
                    if self._slot_of.get(i) == slots[k]]
            if keep:
                self.payload = self.payload.at[
                    jnp.asarray(slots[keep])].set(jnp.asarray(rows[keep]))
            return len(keep)

    def start_refresh(self, interval_s: float):
        def loop():
            while not self._stop.wait(interval_s):
                self.refresh_once()
        self._refresh_thread = threading.Thread(target=loop, daemon=True)
        self._refresh_thread.start()

    def stop_refresh(self):
        self._stop.set()
        if self._refresh_thread:
            self._refresh_thread.join()
            self._refresh_thread = None
        self._stop.clear()

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
