"""Kafka-analogue online-update path (paper §3 "Online model updating").

``MessageBus`` holds one ordered queue per (model, table) topic.
``Producer`` (training side) serializes, batches and publishes update
messages; ``Consumer`` (inference side) discovers topics, subscribes with
an offset, and applies polled updates to its local VDB shard + PDB —
exactly the blue data-flow in the paper's Figure 2.
"""
from __future__ import annotations

import io
import struct
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _serialize(ids: np.ndarray, rows: np.ndarray,
               version: int = 0) -> bytes:
    """Wire format: ``<IIQ`` (rows, dim, version) + int64 ids + f32 rows.

    ``version`` is the producer-assigned update version (a monotonically
    increasing pass/window counter) — consumers surface the last version
    seen per topic so the freshness loop can measure publish->visible
    lag end to end."""
    buf = io.BytesIO()
    n, d = rows.shape
    buf.write(struct.pack("<IIQ", n, d, version))
    buf.write(np.ascontiguousarray(ids, np.int64).tobytes())
    buf.write(np.ascontiguousarray(rows, np.float32).tobytes())
    return buf.getvalue()


_HEADER = struct.calcsize("<IIQ")


def _deserialize(data: bytes) -> Tuple[np.ndarray, np.ndarray]:
    ids, rows, _ = _deserialize_versioned(data)
    return ids, rows


def _deserialize_versioned(data: bytes
                           ) -> Tuple[np.ndarray, np.ndarray, int]:
    n, d, version = struct.unpack_from("<IIQ", data, 0)
    off = _HEADER
    ids = np.frombuffer(data, np.int64, n, off)
    rows = np.frombuffer(data, np.float32, n * d, off + 8 * n).reshape(n, d)
    return ids.copy(), rows.copy(), version


class MessageBus:

    # Checked by `python -m repro.analysis`.
    _GUARDED_BY = {"_topics": "_lock"}

    def __init__(self):
        self._topics: Dict[str, List[bytes]] = {}
        self._lock = threading.Lock()

    def topic(self, model: str, table: str) -> str:
        return f"hps.{model}.{table}"

    def publish(self, topic: str, message: bytes) -> int:
        with self._lock:
            q = self._topics.setdefault(topic, [])
            q.append(message)
            return len(q) - 1

    def fetch(self, topic: str, offset: int, max_messages: int = 64
              ) -> Tuple[List[bytes], int]:
        with self._lock:
            q = self._topics.get(topic, [])
            out = q[offset:offset + max_messages]
            return out, offset + len(out)

    def topics(self) -> List[str]:
        with self._lock:
            return list(self._topics)


class Producer:
    """Message Producer API — batching + serialization (training side)."""

    def __init__(self, bus: MessageBus, model: str, *,
                 max_batch_rows: int = 4096):
        self.bus = bus
        self.model = model
        self.max_batch_rows = max_batch_rows
        self._pending: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}

    def send(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        pend = self._pending.setdefault(table, [])
        pend.append((np.asarray(ids), np.asarray(rows)))
        if sum(len(i) for i, _ in pend) >= self.max_batch_rows:
            self.flush(table)

    def flush(self, table: Optional[str] = None, *,
              version: int = 0) -> None:
        tables = [table] if table else list(self._pending)
        for t in tables:
            pend = self._pending.pop(t, [])
            if not pend:
                continue
            ids = np.concatenate([i for i, _ in pend])
            rows = np.concatenate([r for _, r in pend])
            self.bus.publish(self.bus.topic(self.model, t),
                             _serialize(ids, rows, version))


class Consumer:
    """Message Source API — subscribe + apply (inference side).

    ``last_versions`` maps each table to the highest producer version
    applied so far — the inference-side half of the freshness contract:
    once ``last_versions[table] >= v``, every row of update ``v`` has
    been applied to this consumer's L2/L3 (and its L1 rows marked
    dirty)."""

    def __init__(self, bus: MessageBus, model: str):
        self.bus = bus
        self.model = model
        self._offsets: Dict[str, int] = {}
        self.last_versions: Dict[str, int] = {}

    def discover(self) -> List[str]:
        prefix = f"hps.{self.model}."
        return [t for t in self.bus.topics() if t.startswith(prefix)]

    def poll(self, apply_fn) -> int:
        """``apply_fn(table, ids, rows)``; returns #messages applied."""
        n = 0
        for topic in self.discover():
            table = topic.rsplit(".", 1)[1]
            off = self._offsets.get(topic, 0)
            msgs, off = self.bus.fetch(topic, off)
            self._offsets[topic] = off
            for m in msgs:
                ids, rows, version = _deserialize_versioned(m)
                apply_fn(table, ids, rows)
                if version > self.last_versions.get(table, -1):
                    self.last_versions[table] = version
                n += 1
        return n
