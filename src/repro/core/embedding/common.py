"""Shared machinery for the embedding engine.

All strategies operate on a *mega-table* layout: the tables of a group are
concatenated along the row axis into one ``[sum(V_t), D]`` array with
per-table row offsets. Ids use ``-1`` padding for variable hotness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig


@dataclasses.dataclass(frozen=True)
class TableGroup:
    """A group of tables sharing one mega-table and one strategy."""
    strategy: str
    tables: Tuple[EmbeddingTableConfig, ...]
    #: row offset of each table within the mega-table
    offsets: Tuple[int, ...]
    total_rows: int
    dim: int
    #: index of each table in the *original* collection order
    table_indices: Tuple[int, ...]

    @property
    def num_tables(self) -> int:
        return len(self.tables)


def build_group(strategy: str,
                tables: Sequence[EmbeddingTableConfig],
                table_indices: Sequence[int],
                rows_fn=None) -> TableGroup:
    """Concatenate ``tables`` into one mega-table layout.

    ``rows_fn(table) -> int`` overrides the per-table row count (used by the
    hybrid strategy to build hot-only / cold-only groups).
    """
    rows_fn = rows_fn or (lambda t: t.vocab_size)
    dims = {t.dim for t in tables}
    if len(dims) != 1:
        raise ValueError(f"grouped tables must share dim, got {dims}")
    offsets, total = [], 0
    for t in tables:
        offsets.append(total)
        total += rows_fn(t)
    return TableGroup(strategy, tuple(tables), tuple(offsets), total,
                      dims.pop(), tuple(table_indices))


def init_mega_table(key: jax.Array, group: TableGroup,
                    dtype=jnp.float32) -> jax.Array:
    """Uniform(-1/sqrt(V), 1/sqrt(V)) per table, HugeCTR-style init."""
    parts = []
    keys = jax.random.split(key, max(1, group.num_tables))
    bounds = list(group.offsets) + [group.total_rows]
    for i, (t, k) in enumerate(zip(group.tables, keys)):
        n = bounds[i + 1] - bounds[i]
        scale = 1.0 / np.sqrt(max(t.vocab_size, 1))
        parts.append(jax.random.uniform(k, (n, group.dim), dtype,
                                        minval=-scale, maxval=scale))
    return jnp.concatenate(parts, axis=0) if parts else \
        jnp.zeros((0, group.dim), dtype)


def global_row_ids(ids: jax.Array, group: TableGroup) -> jax.Array:
    """Map per-table ids ``[..., T, H]`` to mega-table row ids (keep -1)."""
    offs = jnp.asarray(group.offsets, jnp.int32).reshape(
        (1,) * (ids.ndim - 2) + (group.num_tables, 1))
    return jnp.where(ids >= 0, ids + offs, -1)


def pooled_local_lookup(mega: jax.Array, rows: jax.Array,
                        combiner: str = "sum",
                        compute_dtype=None) -> jax.Array:
    """Gather + pool: ``rows [B, T, H]`` (-1 = pad) -> ``[B, T, D]``.

    Pure-jnp path. The Pallas kernel in ``repro.kernels`` implements the
    same contract for the perf-critical recsys path.
    """
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    vecs = jnp.take(mega, safe, axis=0)           # [B, T, H, D]
    if compute_dtype is not None:
        vecs = vecs.astype(compute_dtype)
    vecs = jnp.where(valid[..., None], vecs, 0)
    pooled = vecs.sum(axis=-2)                    # [B, T, D]
    if combiner == "mean":
        denom = jnp.maximum(valid.sum(axis=-1, keepdims=True), 1)
        pooled = pooled / denom.astype(pooled.dtype)
    return pooled


def masked_range_lookup(local: jax.Array, rows: jax.Array, v0: int,
                        combiner: str = "sum",
                        compute_dtype=None) -> jax.Array:
    """Partial pooled lookup against a row-range shard ``[v0, v0+len)``.

    Rows outside the shard contribute zero; summing partials across shards
    reconstructs the full pooled lookup (plus mean renorm done by caller).
    """
    vlen = local.shape[0]
    rel = rows - v0
    valid = (rows >= 0) & (rel >= 0) & (rel < vlen)
    safe = jnp.where(valid, rel, 0)
    vecs = jnp.take(local, safe, axis=0)
    if compute_dtype is not None:
        vecs = vecs.astype(compute_dtype)
    vecs = jnp.where(valid[..., None], vecs, 0)
    return vecs.sum(axis=-2)


def combiner_mask_denom(rows: jax.Array) -> jax.Array:
    """Denominator for mean-combining given padded rows ``[..., H]``."""
    return jnp.maximum((rows >= 0).sum(axis=-1, keepdims=True), 1)
