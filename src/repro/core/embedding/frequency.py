"""Frequency statistics for hot/cold splitting (hybrid sparse embedding).

HugeCTR's hybrid embedding decides hot vs cold per category by access
frequency. We keep the statistics host-side (numpy) — they are collected
from the data pipeline, not from device code — and produce either

  * a *remap* (old id -> frequency-rank id) so that ``id < hot_rows`` is the
    hot test on device (branch-free, TPU-friendly), or
  * a boolean hot-set for data that is already frequency-sorted (Criteo-style
    preprocessing emits ids sorted by frequency, which is what our synthetic
    generator produces too).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np


class FrequencyStats:
    """Streaming per-table id frequency counters."""

    def __init__(self, vocab_sizes: Sequence[int]):
        self.counts = [np.zeros(v, np.int64) for v in vocab_sizes]

    def update(self, ids_batch: np.ndarray) -> None:
        """``ids_batch``: ``[B, T, H]`` with -1 padding."""
        for t, c in enumerate(self.counts):
            ids = ids_batch[:, t, :].reshape(-1)
            ids = ids[ids >= 0]
            np.add.at(c, ids, 1)

    def hot_rows(self, table: int, hot_fraction: float) -> int:
        v = len(self.counts[table])
        return max(0, min(v, int(round(v * hot_fraction))))

    def remap(self, table: int) -> np.ndarray:
        """old id -> frequency-rank id (rank 0 = most frequent)."""
        order = np.argsort(-self.counts[table], kind="stable")
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        return inv

    def coverage(self, table: int, hot_fraction: float) -> float:
        """Fraction of accesses served by the hot set (cache-hit estimate)."""
        c = np.sort(self.counts[table])[::-1]
        k = self.hot_rows(table, hot_fraction)
        tot = c.sum()
        return float(c[:k].sum() / tot) if tot else 0.0


def apply_remap(ids: np.ndarray, remaps: Sequence[Optional[np.ndarray]]
                ) -> np.ndarray:
    """Host-side id remap, ``ids [B, T, H]`` (-1 preserved)."""
    out = ids.copy()
    for t, r in enumerate(remaps):
        if r is None:
            continue
        col = ids[:, t, :]
        out[:, t, :] = np.where(col >= 0, r[np.clip(col, 0, None)], -1)
    return out
