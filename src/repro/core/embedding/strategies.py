"""The paper's three embedding placement/communication strategies.

All functions here run *inside* ``shard_map`` over the full device mesh.
Batch is sharded over the DP axes (``("pod", "data")`` / ``("data",)``) and
replicated over ``"model"``; embedding shards use **all** mesh axes — the
paper's point is that the sparse layer consumes every device's memory.

Conventions (see DESIGN.md §4):
  - ``rows``: mega-table row ids ``[B_dp, T, H]`` int32, ``-1`` = padding.
  - distributed shards are **mod-striped** (``owner = row % N``) for the
    all-to-all path — the TPU analogue of HugeCTR's hash sharding — and
    **block-striped** for the allgather+reduce-scatter path.
  - every collective is differentiable, so table gradients flow through
    the same communication pattern in reverse (all-to-all is self-adjoint,
    all-gather <-> reduce-scatter).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.embedding.common import (
    masked_range_lookup,
    pooled_local_lookup,
)


# ---------------------------------------------------------------------------
# Distributed slot embedding — all-gather + reduce-scatter path
# ---------------------------------------------------------------------------

def distributed_ag_rs(local_table: jax.Array, rows: jax.Array, *,
                      dp_axes: Tuple[str, ...], all_axes: Tuple[str, ...],
                      model_axis: str, shard_rows: int,
                      compute_dtype=None) -> jax.Array:
    """Block-striped MP lookup.

    1. all-gather ids over ``dp_axes`` (ids are tiny: int32) — SKIPPED
       when the shard axes exclude DP (``shard_axes="model"``): each DP
       row then resolves only its own batch shard,
    2. every device resolves the (gathered) batch against its row range,
    3. reduce-scatter the partial pooled tensor over the shard axes,
    4. all-gather over the model axis to restore the DP batch block.
    """
    rows_all = jax.lax.all_gather(rows, dp_axes, axis=0, tiled=True) \
        if dp_axes else rows
    idx = jax.lax.axis_index(all_axes)
    v0 = idx * shard_rows
    partial = masked_range_lookup(local_table, rows_all, v0,
                                  compute_dtype=compute_dtype)
    summed = jax.lax.psum_scatter(partial, all_axes, scatter_dimension=0,
                                  tiled=True)
    if model_axis in all_axes:
        summed = jax.lax.all_gather(summed, model_axis, axis=0, tiled=True)
    return summed


# ---------------------------------------------------------------------------
# Distributed slot embedding — bucketed all-to-all path (HugeCTR-faithful)
# ---------------------------------------------------------------------------

def _bucket_by_owner(flat_rows: jax.Array, n_shards: int, capacity: int):
    """Assign each id a slot in a ``[n_shards, capacity]`` send buffer.

    Returns ``(send_buf, slot_of, valid)`` where ``send_buf`` holds *local*
    row ids (``row // n_shards``) with ``-1`` padding, ``slot_of[i]`` is the
    flat slot each input id landed in (or ``n_shards*capacity`` if dropped),
    and ``valid`` marks ids that were neither padding nor overflow.
    """
    m = flat_rows.shape[0]
    owner = jnp.where(flat_rows >= 0, flat_rows % n_shards, n_shards)
    order = jnp.argsort(owner, stable=True)
    sorted_owner = owner[order]
    # rank of each element within its owner bucket
    start = jnp.searchsorted(sorted_owner, jnp.arange(n_shards + 1))
    pos_sorted = jnp.arange(m) - start[sorted_owner]
    in_cap = (pos_sorted < capacity) & (sorted_owner < n_shards)
    slot_sorted = jnp.where(in_cap,
                            sorted_owner * capacity + pos_sorted,
                            n_shards * capacity)
    slot_of = jnp.zeros((m,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    local_rows = jnp.where(flat_rows >= 0, flat_rows // n_shards, -1)
    send_buf = jnp.full((n_shards * capacity,), -1, jnp.int32)
    send_buf = send_buf.at[slot_of].set(local_rows, mode="drop")
    valid = (flat_rows >= 0) & (slot_of < n_shards * capacity)
    return send_buf.reshape(n_shards, capacity), slot_of, valid


def distributed_a2a(local_table: jax.Array, rows: jax.Array, *,
                    all_axes: Tuple[str, ...], n_shards: int,
                    capacity_factor: float = 2.0,
                    compute_dtype=None) -> jax.Array:
    """Mod-striped MP lookup with bucketed all-to-all exchange.

    The faithful port of HugeCTR's distributed-slot pattern: ids are routed
    to their owner shard, the owner gathers vectors, and a second all-to-all
    returns them. Static shapes come from a capacity factor (overflow ids
    fall back to zero vectors; the planner sizes capacity so this does not
    happen for uniform batches — same trade as MoE token dropping).
    """
    b, t, h = rows.shape
    m = b * t * h
    capacity = max(1, int((m + n_shards - 1) // n_shards * capacity_factor))
    flat = rows.reshape(-1)
    send_buf, slot_of, valid = _bucket_by_owner(flat, n_shards, capacity)

    # requests travel to owners ...
    recv = jax.lax.all_to_all(send_buf, all_axes, split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(n_shards, capacity)
    req_valid = recv >= 0
    safe = jnp.where(req_valid, recv, 0)
    resp = jnp.take(local_table, safe, axis=0)
    if compute_dtype is not None:
        resp = resp.astype(compute_dtype)
    resp = jnp.where(req_valid[..., None], resp, 0)
    # ... vectors travel back to requesters
    resp_back = jax.lax.all_to_all(resp, all_axes, split_axis=0,
                                   concat_axis=0, tiled=False)
    resp_flat = resp_back.reshape(n_shards * capacity, -1)
    # pad row so dropped/overflow slots read zeros
    resp_flat = jnp.concatenate(
        [resp_flat, jnp.zeros((1, resp_flat.shape[1]), resp_flat.dtype)], 0)
    gathered = resp_flat[jnp.where(valid, slot_of, n_shards * capacity)]
    return gathered.reshape(b, t, h, -1).sum(axis=2)


# ---------------------------------------------------------------------------
# Localized slot embedding
# ---------------------------------------------------------------------------

def localized(local_tables: jax.Array, ids: jax.Array, *,
              dp_axes: Tuple[str, ...], all_axes: Tuple[str, ...],
              model_axis: str, tables_per_shard: int,
              compute_dtype=None) -> jax.Array:
    """Whole tables per device; all-to-all exchanges pooled vectors.

    ``local_tables``: ``[T/N, V_max, D]`` — this shard's tables (padded).
    ``ids``: per-table ids ``[B_dp, T, H]`` (NOT mega-row ids).

    Per the paper: intra-slot (multi-hot) reduction is entirely local; the
    only communication is one all-to-all of pooled vectors along the batch
    dimension (plus the id all-gather that stands in for HugeCTR's
    table-aware data reader).
    """
    ids_all = jax.lax.all_gather(ids, dp_axes, axis=0, tiled=True)
    idx = jax.lax.axis_index(all_axes)
    t0 = idx * tables_per_shard
    my_ids = jax.lax.dynamic_slice_in_dim(ids_all, t0, tables_per_shard,
                                          axis=1)           # [B_g, T/N, H]
    pooled = jax.vmap(
        lambda tab, r: pooled_local_lookup(tab, r[:, None, :],
                                           compute_dtype=compute_dtype)[:, 0],
        in_axes=(0, 1), out_axes=1,
    )(local_tables, my_ids)                                   # [B_g, T/N, D]
    out = jax.lax.all_to_all(pooled, all_axes, split_axis=0, concat_axis=1,
                             tiled=True)                      # [B_g/N, T, D]
    if model_axis in all_axes:
        out = jax.lax.all_gather(out, model_axis, axis=0, tiled=True)
    return out
