"""Placement planner — "next-generation embedding" (paper §4).

The paper's future-work section describes a backend that *autonomously
determines what embedding table placements grant optimal performance*. This
planner is our implementation of that idea: given the tables, the mesh and
a batch shape, it napkin-maths per-device memory and per-step communication
bytes for every strategy and picks the cheapest feasible one per table.

Cost model (per training step, per device, bytes):
  data_parallel : fwd 0, bwd all-reduce of the dense grad  ~ 2·V·D·s
  distributed   : ag_rs — RS(B_g·D·s) + AG_model(B_dp·D·s) per table
                  a2a  — 2 · B_dp·H·D·s request/response traffic
  localized     : a2a of pooled vectors ~ B_g·D·s / N + id allgather
  hybrid        : hot hits free (DP, replicated, grads all-reduced but the
                  hot set is small) + cold via distributed on (1-cov) of
                  the traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.configs.base import (
    DATA_PARALLEL, DISTRIBUTED, HYBRID, LOCALIZED,
    EmbeddingTableConfig, MeshConfig,
)


@dataclasses.dataclass
class PlacementDecision:
    table: str
    strategy: str
    comm_bytes: float          # estimated per-device per-step
    mem_bytes: float           # per-device
    note: str = ""


def plan(tables: Sequence[EmbeddingTableConfig],
         mesh: MeshConfig,
         global_batch: int,
         *,
         bytes_per_elem: int = 4,
         dp_mem_budget: float = 64 * 2 ** 20,
         hot_coverage: float = 0.9,
         ) -> Dict[str, PlacementDecision]:
    """Decide a strategy for every table whose config says ``auto``."""
    n = mesh.num_devices
    model = mesh.shape[-1]
    dp = max(1, n // model)
    b_dp = max(1, global_batch // dp)
    out: Dict[str, PlacementDecision] = {}
    for t in tables:
        if t.strategy != "auto":
            out[t.name] = PlacementDecision(t.name, t.strategy, 0.0,
                                            _mem(t, t.strategy, n,
                                                 bytes_per_elem),
                                            "pinned by config")
            continue
        s = bytes_per_elem
        d = t.dim
        cost = {
            DATA_PARALLEL: 2.0 * t.vocab_size * d * s,           # grad AR
            DISTRIBUTED: min(
                global_batch * d * s + (b_dp * d * s) * (model - 1) / model,
                2.0 * b_dp * t.hotness * d * s),
            HYBRID: (1.0 - hot_coverage) * 2.0 * b_dp * t.hotness * d * s
            + 2.0 * int(t.vocab_size * t.hot_fraction) * d * s,
        }
        mem_dp = t.vocab_size * d * s
        feasible = dict(cost)
        if mem_dp > dp_mem_budget:
            feasible.pop(DATA_PARALLEL, None)
        # localized only pays off when tables outnumber devices
        strategy = min(feasible, key=feasible.get)
        # tiny tables: replicate regardless (communication ~ 0 anyway)
        if mem_dp <= 2 ** 20:
            strategy = DATA_PARALLEL
        out[t.name] = PlacementDecision(
            t.name, strategy, feasible.get(strategy, 0.0),
            _mem(t, strategy, n, bytes_per_elem),
            f"costs={ {k: f'{v:.2e}' for k, v in cost.items()} }")
    return out


def _mem(t: EmbeddingTableConfig, strategy: str, n: int, s: int) -> float:
    full = t.vocab_size * t.dim * s
    if strategy in (DATA_PARALLEL, LOCALIZED):
        return full
    if strategy == DISTRIBUTED:
        return full / n
    if strategy == HYBRID:
        hot = int(t.vocab_size * t.hot_fraction) * t.dim * s
        return hot + (full - hot) / n
    return full


def choose_comm(tables: Sequence[EmbeddingTableConfig], *,
                threshold: int = 65536) -> str:
    """Pick the embedding-collection comm pattern for one table group.

    The hybrid recipe (Mudigere et al., cited from the paper's §4):
    ``all_to_all`` only pays off for LARGE one-hot tables, where each
    device requests exactly the rows it needs instead of allgathering a
    shard-padded block. Pooled (hotness > 1) or small tables keep
    ``allgather_rs`` — pooling happens shard-side before any exchange
    and small tables cost next to nothing to allgather.
    """
    if not tables:
        return "allgather_rs"
    if all(t.hotness == 1 for t in tables) and \
            max(t.vocab_size for t in tables) >= threshold:
        return "all_to_all"
    return "allgather_rs"


def resolve_strategies(tables: Sequence[EmbeddingTableConfig],
                       mesh: MeshConfig, global_batch: int,
                       ) -> Tuple[EmbeddingTableConfig, ...]:
    """Return tables with ``auto`` strategies replaced by planner picks."""
    decisions = plan(tables, mesh, global_batch)
    resolved = []
    for t in tables:
        strat = decisions[t.name].strategy if t.strategy == "auto" \
            else t.strategy
        resolved.append(dataclasses.replace(t, strategy=strat))
    return tuple(resolved)
