"""EmbeddingCollection — the public API of the paper's embedding engine.

Groups tables by strategy (localized / distributed / hybrid / replicated),
owns their mega-table parameters + shardings, and produces the pooled
``[B, T, D]`` activations with one ``shard_map`` over the full mesh.

Layouts
-------
Distributed (and hybrid-cold) mega-tables are stored either

  * ``block``  — contiguous row ranges per device (natural GSPMD layout),
    used with the all-gather + reduce-scatter comm strategy, or
  * ``striped`` — row ``r`` lives on device ``r % N`` at slot ``r // N``
    (HugeCTR's hash sharding, TPU-affine), used with the bucketed
    all-to-all comm strategy so hot rows spread across devices.

The physical array is always ``[R_pad, D]`` sharded over all mesh axes;
``to_logical`` / ``from_logical`` convert for checkpoints and tests.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (
    DATA_PARALLEL, DISTRIBUTED, HYBRID, LOCALIZED, EmbeddingTableConfig,
)
from repro.core.embedding import strategies
from repro.core.embedding.common import (
    TableGroup, build_group, combiner_mask_denom, global_row_ids,
    init_mega_table, pooled_local_lookup,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class EmbeddingCollection:

    def __init__(self,
                 tables: Sequence[EmbeddingTableConfig],
                 mesh: Mesh,
                 *,
                 comm: str = "allgather_rs",   # or "all_to_all"
                 capacity_factor: float = 2.0,
                 compute_dtype=None,
                 shard_axes: str = "all",      # or "model"
                 pool_fn: Optional[Callable] = None):
        """``shard_axes``:

        * ``"all"``   — rows stripe over EVERY mesh axis (maximum memory
          scaling; every device must then resolve the full global batch,
          so ids all-gather over DP and the pooled reduce-scatter spans
          all devices).
        * ``"model"`` — rows stripe over the model axis only, replicated
          across DP (HugeCTR's intra-node placement): each DP row resolves
          only its own batch shard — no id gather, and the pooled psum
          spans ``model`` instead of the world. §Perf dlrm iter 2: 16x
          less redundant lookup work, collective term 20.3 -> ~2 ms.
        """
        for t in tables:
            if t.strategy == "auto":
                raise ValueError(
                    f"table {t.name}: run planner.resolve_strategies first")
        self.tables = tuple(tables)
        self.mesh = mesh
        self.comm = comm
        self.capacity_factor = capacity_factor
        self.compute_dtype = compute_dtype
        self._pool = pool_fn or pooled_local_lookup

        axes = tuple(mesh.axis_names)
        self.all_axes = axes
        self.model_axis = "model" if "model" in axes else axes[-1]
        self.dp_axes = tuple(a for a in axes if a != self.model_axis)
        self.n_devices = int(np.prod(mesh.devices.shape))
        if shard_axes == "model":
            self.shard_axes: Tuple[str, ...] = (self.model_axis,)
            self.gather_axes: Tuple[str, ...] = ()
        else:
            self.shard_axes = axes
            self.gather_axes = self.dp_axes
        self.n_shards = int(np.prod([mesh.shape[a]
                                     for a in self.shard_axes]))

        by = lambda s: [(i, t) for i, t in enumerate(self.tables)
                        if t.strategy == s]
        self.groups: Dict[str, TableGroup] = {}

        dp = by(DATA_PARALLEL)
        if dp:
            self.groups["dp"] = build_group(
                DATA_PARALLEL, [t for _, t in dp], [i for i, _ in dp])

        dist = by(DISTRIBUTED)
        if dist:
            self.groups["dist"] = build_group(
                DISTRIBUTED, [t for _, t in dist], [i for i, _ in dist])

        loc = by(LOCALIZED)
        if loc:
            if len(loc) % self.n_devices != 0:
                raise ValueError(
                    f"localized needs #tables ({len(loc)}) divisible by "
                    f"#devices ({self.n_devices}); planner avoids this")
            self.groups["loc"] = build_group(
                LOCALIZED, [t for _, t in loc], [i for i, _ in loc])
            self._loc_vmax = max(t.vocab_size for _, t in loc)

        hyb = by(HYBRID)
        self._hot_rows: Tuple[int, ...] = ()
        if hyb:
            hot_rows = tuple(
                min(t.vocab_size,
                    max(1, int(round(t.vocab_size * t.hot_fraction))))
                for _, t in hyb)
            self._hot_rows = hot_rows
            hot_by_name = {t.name: h for (_, t), h in zip(hyb, hot_rows)}
            self.groups["hot"] = build_group(
                HYBRID, [t for _, t in hyb], [i for i, _ in hyb],
                rows_fn=lambda t: hot_by_name[t.name])
            self.groups["cold"] = build_group(
                HYBRID, [t for _, t in hyb], [i for i, _ in hyb],
                rows_fn=lambda t: t.vocab_size - hot_by_name[t.name])

        # output column permutation: concat(group outputs) -> original order
        order = []
        for name in self._group_order():
            order.extend(self.groups[name].table_indices)
        inv = np.empty(len(self.tables), np.int32)
        inv[np.asarray(order, np.int32)] = np.arange(len(order))
        self._inv_perm = inv

        self.layout = "striped" if comm == "all_to_all" else "block"

    # -- group helpers ------------------------------------------------------

    def _group_order(self):
        # hot+cold produce ONE output column set (hybrid), listed once
        names = [n for n in ("dp", "dist", "loc", "hot") if n in self.groups]
        return names

    def _padded_rows(self, g: TableGroup) -> int:
        return _round_up(max(g.total_rows, self.n_shards), self.n_shards)

    # -- params -------------------------------------------------------------

    def init(self, key: jax.Array, dtype=jnp.float32) -> Dict[str, jax.Array]:
        params = {}
        keys = jax.random.split(key, 8)
        if "dp" in self.groups:
            params["dp"] = init_mega_table(keys[0], self.groups["dp"], dtype)
        if "dist" in self.groups:
            params["dist"] = self._init_sharded(keys[1], self.groups["dist"],
                                                dtype)
        if "loc" in self.groups:
            g = self.groups["loc"]
            tabs = []
            tkeys = jax.random.split(keys[2], g.num_tables)
            for t, k in zip(g.tables, tkeys):
                scale = 1.0 / np.sqrt(t.vocab_size)
                tab = jax.random.uniform(k, (t.vocab_size, g.dim), dtype,
                                         minval=-scale, maxval=scale)
                pad = self._loc_vmax - t.vocab_size
                if pad:
                    tab = jnp.concatenate(
                        [tab, jnp.zeros((pad, g.dim), dtype)], 0)
                tabs.append(tab)
            params["loc"] = jnp.stack(tabs)
        if "hot" in self.groups:
            params["hot"] = init_mega_table(keys[3], self.groups["hot"],
                                            dtype)
            params["cold"] = self._init_sharded(keys[4], self.groups["cold"],
                                                dtype)
        return params

    def _init_sharded(self, key, g: TableGroup, dtype) -> jax.Array:
        logical = init_mega_table(key, g, dtype)
        rpad = self._padded_rows(g)
        if rpad > g.total_rows:
            logical = jnp.concatenate(
                [logical, jnp.zeros((rpad - g.total_rows, g.dim), dtype)], 0)
        if self.layout == "striped":
            logical = logical[self._logical_of_physical(rpad)]
        return logical

    def _logical_of_physical(self, rpad: int) -> jax.Array:
        n = self.n_shards
        shard = rpad // n
        p = jnp.arange(rpad)
        return (p % shard) * n + p // shard

    def _physical_of_logical(self, rpad: int) -> jax.Array:
        n = self.n_shards
        shard = rpad // n
        r = jnp.arange(rpad)
        return (r % n) * shard + r // n

    def param_specs(self) -> Dict[str, P]:
        specs = {}
        if "dp" in self.groups:
            specs["dp"] = P(None, None)
        if "dist" in self.groups:
            specs["dist"] = P(self.shard_axes, None)
        if "loc" in self.groups:
            specs["loc"] = P(self.all_axes, None, None)
        if "hot" in self.groups:
            specs["hot"] = P(None, None)
            specs["cold"] = P(self.shard_axes, None)
        return specs

    def param_shardings(self) -> Dict[str, NamedSharding]:
        return {k: NamedSharding(self.mesh, v)
                for k, v in self.param_specs().items()}

    # -- lookup -------------------------------------------------------------

    def lookup(self, params: Dict[str, jax.Array], ids: jax.Array,
               *, manual: bool = False) -> jax.Array:
        """``ids [B, T, H]`` (per-table local ids, -1 pad) -> ``[B, T, D]``.

        ``manual=True`` skips the shard_map wrapper — for callers that are
        already inside a shard_map over the full mesh (the manual train
        step); ``params``/``ids`` are then per-device blocks.
        """
        if manual:
            return self._lookup_shard(params, ids)
        fn = compat.shard_map(
            functools.partial(self._lookup_shard),
            mesh=self.mesh,
            in_specs=(self.param_specs(), P(self.dp_axes, None, None)),
            out_specs=P(self.dp_axes, None, None),
            check_vma=False,
        )
        return fn(params, ids)

    def _lookup_shard(self, params, ids):
        outs = []
        cd = self.compute_dtype
        if "dp" in self.groups:
            g = self.groups["dp"]
            rows = global_row_ids(ids[:, np.asarray(g.table_indices), :], g)
            outs.append(self._pool(params["dp"], rows, compute_dtype=cd))
        if "dist" in self.groups:
            g = self.groups["dist"]
            rows = global_row_ids(ids[:, np.asarray(g.table_indices), :], g)
            outs.append(self._dist_lookup(params["dist"], rows, g))
        if "loc" in self.groups:
            g = self.groups["loc"]
            outs.append(strategies.localized(
                params["loc"], ids[:, np.asarray(g.table_indices), :],
                dp_axes=self.dp_axes, all_axes=self.all_axes,
                model_axis=self.model_axis,
                tables_per_shard=g.num_tables // self.n_devices,
                compute_dtype=cd))
        if "hot" in self.groups:
            gh, gc = self.groups["hot"], self.groups["cold"]
            tids = ids[:, np.asarray(gh.table_indices), :]
            hot_n = jnp.asarray(self._hot_rows, jnp.int32)[None, :, None]
            hot_off = jnp.asarray(gh.offsets, jnp.int32)[None, :, None]
            cold_off = jnp.asarray(gc.offsets, jnp.int32)[None, :, None]
            is_hot = (tids >= 0) & (tids < hot_n)
            is_cold = tids >= hot_n
            hot_rows = jnp.where(is_hot, tids + hot_off, -1)
            cold_rows = jnp.where(is_cold, tids - hot_n + cold_off, -1)
            pooled = self._pool(params["hot"], hot_rows, compute_dtype=cd)
            pooled = pooled + self._dist_lookup(params["cold"], cold_rows, gc)
            outs.append(pooled)
        out = jnp.concatenate(outs, axis=1)[:, self._inv_perm, :]
        # mean combiner renorm (per original table)
        mean_mask = np.asarray(
            [t.combiner == "mean" for t in self.tables])
        if mean_mask.any():
            denom = combiner_mask_denom(ids).astype(out.dtype)
            out = jnp.where(jnp.asarray(mean_mask)[None, :, None],
                            out / denom, out)
        return out

    def _dist_lookup(self, mega, rows, g: TableGroup):
        rpad = self._padded_rows(g)
        if self.comm == "all_to_all":
            return strategies.distributed_a2a(
                mega, rows, all_axes=self.shard_axes,
                n_shards=self.n_shards,
                capacity_factor=self.capacity_factor,
                compute_dtype=self.compute_dtype)
        return strategies.distributed_ag_rs(
            mega, rows, dp_axes=self.gather_axes, all_axes=self.shard_axes,
            model_axis=self.model_axis, shard_rows=rpad // self.n_shards,
            compute_dtype=self.compute_dtype)

    # -- layout conversion (checkpoint / oracle comparison) ------------------

    def to_logical(self, params: Dict[str, jax.Array]
                   ) -> Dict[str, jax.Array]:
        if self.layout == "block":
            return dict(params)
        out = dict(params)
        for k in ("dist", "cold"):
            if k in params:
                out[k] = params[k][self._physical_of_logical(
                    params[k].shape[0])]
        return out

    def from_logical(self, params: Dict[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        if self.layout == "block":
            return dict(params)
        out = dict(params)
        for k in ("dist", "cold"):
            if k in params:
                out[k] = params[k][self._logical_of_physical(
                    params[k].shape[0])]
        return out

    def export_logical(self, params: Dict[str, jax.Array]
                       ) -> Dict[str, jax.Array]:
        """Physical -> logical *unpadded* arrays (checkpoint format).

        The result is mesh-size independent: a checkpoint written on N
        devices imports on M devices (elastic scaling).
        """
        logical = self.to_logical(params)
        out = {}
        for k, v in logical.items():
            g = {"dp": "dp", "dist": "dist", "loc": "loc",
                 "hot": "hot", "cold": "cold"}[k]
            group = self.groups["hot" if g in ("hot",) else
                                "cold" if g == "cold" else g]
            if k in ("dist", "cold"):
                out[k] = v[:group.total_rows]
            else:
                out[k] = v
        return out

    def import_logical(self, logical: Dict[str, jax.Array]
                       ) -> Dict[str, jax.Array]:
        """Inverse of :meth:`export_logical` for THIS mesh size.

        The incoming array may carry a DIFFERENT mesh's padding (a
        checkpoint is unpadded, but callers sometimes hand back a
        to_logical() from another collection): everything past the
        group's logical rows is dropped and the pad stripe is freshly
        zeroed, so stale pad garbage from the writing mesh can never
        reach a lookup on this one.
        """
        out = {}
        for k, v in logical.items():
            if k in ("dist", "cold"):
                g = self.groups[k]
                if v.shape[0] < g.total_rows:
                    raise ValueError(
                        f"embedding group {k!r}: checkpoint has "
                        f"{v.shape[0]} rows, need {g.total_rows}")
                v = v[:g.total_rows]
                rpad = self._padded_rows(g)
                v = jnp.pad(v, ((0, rpad - v.shape[0]), (0, 0)))
            out[k] = v
        return self.from_logical(out)

    # -- reference oracle (pure, single-device) ------------------------------

    def lookup_reference(self, params: Dict[str, jax.Array],
                         ids: jax.Array) -> jax.Array:
        """Strategy-free oracle on logical layouts, for tests."""
        logical = self.to_logical(params)
        outs = []
        if "dp" in self.groups:
            g = self.groups["dp"]
            rows = global_row_ids(ids[:, np.asarray(g.table_indices), :], g)
            outs.append(pooled_local_lookup(logical["dp"], rows))
        if "dist" in self.groups:
            g = self.groups["dist"]
            rows = global_row_ids(ids[:, np.asarray(g.table_indices), :], g)
            outs.append(pooled_local_lookup(logical["dist"], rows))
        if "loc" in self.groups:
            g = self.groups["loc"]
            tids = ids[:, np.asarray(g.table_indices), :]
            pooled = jax.vmap(
                lambda tab, r: pooled_local_lookup(tab, r[:, None, :])[:, 0],
                in_axes=(0, 1), out_axes=1)(logical["loc"], tids)
            outs.append(pooled)
        if "hot" in self.groups:
            gh, gc = self.groups["hot"], self.groups["cold"]
            tids = ids[:, np.asarray(gh.table_indices), :]
            hot_n = jnp.asarray(self._hot_rows, jnp.int32)[None, :, None]
            hot_off = jnp.asarray(gh.offsets, jnp.int32)[None, :, None]
            cold_off = jnp.asarray(gc.offsets, jnp.int32)[None, :, None]
            hot_rows = jnp.where((tids >= 0) & (tids < hot_n),
                                 tids + hot_off, -1)
            cold_rows = jnp.where(tids >= hot_n, tids - hot_n + cold_off, -1)
            outs.append(pooled_local_lookup(logical["hot"], hot_rows)
                        + pooled_local_lookup(logical["cold"], cold_rows))
        out = jnp.concatenate(outs, axis=1)[:, self._inv_perm, :]
        mean_mask = np.asarray([t.combiner == "mean" for t in self.tables])
        if mean_mask.any():
            denom = combiner_mask_denom(ids).astype(out.dtype)
            out = jnp.where(jnp.asarray(mean_mask)[None, :, None],
                            out / denom, out)
        return out
