from repro.core.embedding.collection import EmbeddingCollection
from repro.core.embedding.frequency import FrequencyStats, apply_remap
from repro.core.embedding.planner import plan, resolve_strategies

__all__ = [
    "EmbeddingCollection", "FrequencyStats", "apply_remap",
    "plan", "resolve_strategies",
]
