"""Embedding Training Cache (ETC) — train tables larger than device memory.

The device holds a fixed-capacity row cache per table (``[C, D]`` params +
``[C]`` row-wise optimizer state). Before each step the host:

  1. collects the batch's unique ids per table,
  2. evicts least-recently-used rows to make space (writing params+state
     back to the PS in ONE batched push),
  3. pulls missing rows from the PS in ONE batched pull into free slots
     (one device scatter),
  4. remaps batch ids -> cache slots with ONE ``np.searchsorted`` over
     the whole ``[B, H]`` block.

The staging step is fully vectorized — the per-table residency index is
a pair of sorted NumPy arrays (ids / slots) plus an LRU timestamp per
slot, the same batched-index design the HPS L1 cache uses — so staging
cost is O(uniq log C) array ops per table, not a Python loop per id.

The device step then runs on the cache arrays exactly like a normal
(small) embedding table — the trainer is oblivious. ``flush()`` writes
every resident row back, completing the incremental-training story; the
same dirty-row stream feeds the online-update publisher
(``repro.online.UpdatePublisher``).

Concurrency: the ETC is confined to the training thread (its arrays are
mutated between jitted steps); nothing here is shared with the serving
stack — published updates travel by value over the message bus.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig


class EmbeddingTrainingCache:

    def __init__(self, tables: Sequence[EmbeddingTableConfig],
                 capacity: int, ps):
        max_vocab = max(t.vocab_size for t in tables)
        if capacity > max_vocab:
            warnings.warn(
                f"ETC cache capacity {capacity} exceeds the largest "
                f"table vocab {max_vocab}; clamping to {max_vocab} — a "
                "cache row beyond a table's vocab can never be resident",
                RuntimeWarning, stacklevel=2)
            capacity = max_vocab
        else:
            small = [t.name for t in tables if t.vocab_size < capacity]
            if small:
                warnings.warn(
                    f"table(s) {small} have vocab < ETC cache capacity "
                    f"{capacity}: they fit entirely, the surplus rows "
                    "stay unused", RuntimeWarning, stacklevel=2)
        self.tables = tuple(tables)
        self.capacity = capacity
        self.ps = ps
        # per-table residency state, all array-valued:
        #   _slot_ids[ti][slot] = resident id (-1 free)
        #   _last_used[ti][slot] = LRU stamp (prepare() clock)
        #   _sorted_ids/_sorted_slots[ti] = the searchsorted index
        self._slot_ids: List[np.ndarray] = [
            np.full(capacity, -1, np.int64) for _ in tables]
        self._last_used: List[np.ndarray] = [
            np.zeros(capacity, np.int64) for _ in tables]
        self._sorted_ids: List[np.ndarray] = [
            np.empty(0, np.int64) for _ in tables]
        self._sorted_slots: List[np.ndarray] = [
            np.empty(0, np.int64) for _ in tables]
        # ids staged since the last drain_touched() — the full keyset a
        # training pass touched, INCLUDING rows evicted mid-pass (the
        # resident set alone under-reports what an online update must
        # publish)
        self._touched: List[List[np.ndarray]] = [[] for _ in tables]
        self._clock = 0
        self.evictions = 0
        self.pulls = 0

    # -- device-side params --------------------------------------------------

    def init_params(self) -> Dict[str, jax.Array]:
        d = self.tables[0].dim
        assert all(t.dim == d for t in self.tables)
        return {
            "cache": jnp.zeros((len(self.tables), self.capacity, d),
                               jnp.float32),
            "acc": jnp.zeros((len(self.tables), self.capacity),
                             jnp.float32),
        }

    # -- residency index helpers ---------------------------------------------

    def _rebuild_index(self, ti: int) -> None:
        slot_ids = self._slot_ids[ti]
        res = np.flatnonzero(slot_ids >= 0)
        order = np.argsort(slot_ids[res], kind="stable")
        self._sorted_ids[ti] = slot_ids[res][order]
        self._sorted_slots[ti] = res[order]

    def _residency(self, ti: int, uniq: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """(resident mask over ``uniq``, slots of the resident ids)."""
        sids = self._sorted_ids[ti]
        if sids.size == 0:
            return np.zeros(uniq.size, bool), np.empty(0, np.int64)
        pos = np.searchsorted(sids, uniq)
        inb = pos < sids.size
        mask = np.zeros(uniq.size, bool)
        mask[inb] = sids[pos[inb]] == uniq[inb]
        return mask, self._sorted_slots[ti][pos[mask]]

    def resident_ids(self, table_idx: int) -> np.ndarray:
        """Ids currently resident for one table (sorted)."""
        return self._sorted_ids[table_idx].copy()

    # -- the host-side staging step -------------------------------------------

    def prepare(self, params: Dict[str, jax.Array], cat: np.ndarray
                ) -> Tuple[Dict[str, jax.Array], np.ndarray]:
        """Ensure residency for ``cat [B, T, H]``; returns remapped ids."""
        cache = params["cache"]
        acc = params["acc"]
        remapped = np.full_like(cat, -1)
        self._clock += 1
        host_cache = host_acc = None  # lazy, for eviction writeback
        for ti, t in enumerate(self.tables):
            ids = np.asarray(cat[:, ti, :], np.int64)
            valid = ids >= 0
            uniq = np.unique(ids[valid])
            if uniq.size > self.capacity:
                raise ValueError(
                    f"table {t.name}: batch needs {uniq.size} unique rows "
                    f"> cache capacity {self.capacity}")
            if uniq.size:
                self._touched[ti].append(uniq)
            slot_ids = self._slot_ids[ti]
            last = self._last_used[ti]
            res_mask, res_slots = self._residency(ti, uniq)
            missing = uniq[~res_mask]
            # stamp resident ids needed by THIS batch first, so eviction
            # below can never pick them (regression: a current-batch id
            # evicted to make room broke the remap)
            last[res_slots] = self._clock
            free = np.flatnonzero(slot_ids < 0)
            need = missing.size - free.size
            if need > 0:
                if host_cache is None:
                    host_cache = np.asarray(cache)
                    host_acc = np.asarray(acc)
                evictable = np.flatnonzero(
                    (slot_ids >= 0) & (last < self._clock))
                # deterministic victim choice: oldest stamp first, slot
                # index breaking ties (lexsort: last key is primary)
                order = np.lexsort((evictable, last[evictable]))
                victims = evictable[order[:need]]
                evict_ids = slot_ids[victims]
                self.ps.push(t.name, evict_ids, host_cache[ti, victims])
                if hasattr(self.ps, "push_state"):
                    self.ps.push_state(t.name, evict_ids,
                                       host_acc[ti, victims])
                slot_ids[victims] = -1
                last[victims] = 0
                self.evictions += need
                free = np.flatnonzero(slot_ids < 0)
            if missing.size:
                slots = free[:missing.size]
                rows = self.ps.pull(t.name, missing)
                # ONE device scatter fills every pulled row
                cache = cache.at[ti, slots].set(
                    jnp.asarray(rows, jnp.float32))
                if hasattr(self.ps, "pull_state"):
                    st = self.ps.pull_state(t.name, missing)
                    acc = acc.at[ti, slots].set(
                        jnp.asarray(st, jnp.float32))
                else:
                    acc = acc.at[ti, slots].set(0.0)
                slot_ids[slots] = missing
                last[slots] = self._clock
                self.pulls += missing.size
            self._rebuild_index(ti)
            # ONE searchsorted remaps the whole [B, H] block
            sids = self._sorted_ids[ti]
            if sids.size:
                probe = np.where(valid, ids, sids[0])
                pos = np.searchsorted(sids, probe)
                slots_of = self._sorted_slots[ti][
                    np.minimum(pos, sids.size - 1)]
                remapped[:, ti, :] = np.where(valid, slots_of, -1)
        return {"cache": cache, "acc": acc}, remapped

    def flush(self, params: Dict[str, jax.Array]) -> None:
        """Write every resident row (and optimizer state) back to the PS
        — one batched push per table."""
        host = np.asarray(params["cache"])
        host_acc = np.asarray(params["acc"])
        for ti, t in enumerate(self.tables):
            ids = self._sorted_ids[ti]
            if ids.size == 0:
                continue
            slots = self._sorted_slots[ti]
            self.ps.push(t.name, ids, host[ti, slots])
            if hasattr(self.ps, "push_state"):
                self.ps.push_state(t.name, ids, host_acc[ti, slots])

    def drain_touched(self, table_idx: int) -> np.ndarray:
        """Sorted unique ids staged since the last drain — a pass's full
        keyset. After ``flush()`` the PS holds every one of these ids'
        trained value (evicted rows were written back at eviction time),
        so ``ps.pull`` over this set is the complete online-update feed."""
        if not self._touched[table_idx]:
            return np.empty(0, np.int64)
        out = np.unique(np.concatenate(self._touched[table_idx]))
        self._touched[table_idx] = []
        return out

    def dirty_rows(self, params: Dict[str, jax.Array], table_idx: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, rows) currently resident — the online-update feed."""
        host = np.asarray(params["cache"])
        ids = self._sorted_ids[table_idx]
        slots = self._sorted_slots[table_idx]
        return ids.copy(), host[table_idx, slots]


def cached_lookup(params: Dict[str, jax.Array], remapped: jax.Array
                  ) -> jax.Array:
    """Pooled lookup on the cache arrays: ``remapped [B, T, H]`` slots."""
    cache = params["cache"]                          # [T, C, D]

    def per_table(tab, rows):
        v = rows >= 0
        s = jnp.where(v, rows, 0)
        out = jnp.take(tab, s, axis=0)
        return jnp.where(v[..., None], out, 0).sum(axis=-2)
    return jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(
        cache, remapped)
