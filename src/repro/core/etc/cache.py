"""Embedding Training Cache (ETC) — train tables larger than device memory.

The device holds a fixed-capacity row cache per table (``[C, D]`` params +
``[C]`` row-wise optimizer state). Before each step the host:

  1. collects the batch's unique ids per table,
  2. evicts LRU rows to make space (writing params+state back to the PS),
  3. pulls missing rows from the PS into free slots,
  4. remaps batch ids -> cache slots.

The device step then runs on the cache arrays exactly like a normal
(small) embedding table — the trainer is oblivious. ``flush()`` writes
every resident row back, completing the incremental-training story; the
same dirty-row stream feeds the online-update Producer (HPS §3).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig


class EmbeddingTrainingCache:

    def __init__(self, tables: Sequence[EmbeddingTableConfig],
                 capacity: int, ps):
        for t in tables:
            if t.vocab_size < capacity:
                pass  # cache larger than table is fine, just wasteful
        self.tables = tuple(tables)
        self.capacity = capacity
        self.ps = ps
        # per table: id -> slot (ordered = LRU), free slot list
        self._lru: List[OrderedDict] = [OrderedDict() for _ in tables]
        self._free: List[List[int]] = [list(range(capacity))[::-1]
                                       for _ in tables]
        self.evictions = 0
        self.pulls = 0

    # -- device-side params --------------------------------------------------

    def init_params(self) -> Dict[str, jax.Array]:
        d = self.tables[0].dim
        assert all(t.dim == d for t in self.tables)
        return {
            "cache": jnp.zeros((len(self.tables), self.capacity, d),
                               jnp.float32),
            "acc": jnp.zeros((len(self.tables), self.capacity),
                             jnp.float32),
        }

    # -- the host-side staging step -------------------------------------------

    def prepare(self, params: Dict[str, jax.Array], cat: np.ndarray
                ) -> Tuple[Dict[str, jax.Array], np.ndarray]:
        """Ensure residency for ``cat [B, T, H]``; returns remapped ids."""
        cache = params["cache"]
        acc = params["acc"]
        remapped = np.full_like(cat, -1)
        host_cache = None  # lazily materialized for eviction writeback
        for ti, t in enumerate(self.tables):
            ids = cat[:, ti, :]
            uniq = np.unique(ids[ids >= 0])
            lru, free = self._lru[ti], self._free[ti]
            missing = [i for i in map(int, uniq) if i not in lru]
            if len(uniq) > self.capacity:
                raise ValueError(
                    f"table {t.name}: batch needs {len(uniq)} unique rows "
                    f"> cache capacity {self.capacity}")
            # touch resident ids needed by THIS batch first, so the LRU
            # eviction below cannot evict them (regression: KeyError on
            # remap when a current-batch id was evicted to make room)
            for i in map(int, uniq):
                if i in lru:
                    lru.move_to_end(i)
            if len(missing) > len(free):
                need = len(missing) - len(free)
                if host_cache is None:
                    host_cache = np.asarray(cache)
                    host_acc = np.asarray(acc)
                evict_ids, evict_slots = [], []
                for _ in range(need):
                    old_id, old_slot = lru.popitem(last=False)
                    evict_ids.append(old_id)
                    evict_slots.append(old_slot)
                    free.append(old_slot)
                self.ps.push(t.name, np.asarray(evict_ids),
                             host_cache[ti, evict_slots])
                if hasattr(self.ps, "push_state"):
                    self.ps.push_state(t.name, np.asarray(evict_ids),
                                       host_acc[ti, evict_slots])
                self.evictions += need
            if missing:
                slots = [free.pop() for _ in missing]
                rows = self.ps.pull(t.name, np.asarray(missing))
                cache = cache.at[ti, np.asarray(slots)].set(
                    jnp.asarray(rows))
                acc = acc.at[ti, np.asarray(slots)].set(0.0)
                for i, s in zip(missing, slots):
                    lru[i] = s
                self.pulls += len(missing)
            # touch + remap
            for b in range(ids.shape[0]):
                for h in range(ids.shape[1]):
                    v = int(ids[b, h])
                    if v >= 0:
                        lru.move_to_end(v)
                        remapped[b, ti, h] = lru[v]
        return {"cache": cache, "acc": acc}, remapped

    def flush(self, params: Dict[str, jax.Array]) -> None:
        host = np.asarray(params["cache"])
        for ti, t in enumerate(self.tables):
            lru = self._lru[ti]
            if not lru:
                continue
            ids = np.fromiter(lru.keys(), np.int64, len(lru))
            slots = np.fromiter(lru.values(), np.int64, len(lru))
            self.ps.push(t.name, ids, host[ti, slots])

    def dirty_rows(self, params: Dict[str, jax.Array], table_idx: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, rows) currently resident — the online-update feed."""
        host = np.asarray(params["cache"])
        lru = self._lru[table_idx]
        ids = np.fromiter(lru.keys(), np.int64, len(lru))
        slots = np.fromiter(lru.values(), np.int64, len(lru))
        return ids, host[table_idx, slots]


def cached_lookup(params: Dict[str, jax.Array], remapped: jax.Array
                  ) -> jax.Array:
    """Pooled lookup on the cache arrays: ``remapped [B, T, H]`` slots."""
    cache = params["cache"]                          # [T, C, D]

    def per_table(tab, rows):
        v = rows >= 0
        s = jnp.where(v, rows, 0)
        out = jnp.take(tab, s, axis=0)
        return jnp.where(v[..., None], out, 0).sum(axis=-2)
    return jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(
        cache, remapped)
