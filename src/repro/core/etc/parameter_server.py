"""Training-side parameter servers for the Embedding Training Cache.

Two tiers, mirroring the paper (§1 "Online training"):
  * ``StagedPS``  — full tables in (distributed) host memory.
  * ``CachedPS``  — full tables on disk / NFS via ``np.memmap``; host memory
    only holds what is being exchanged.

Both expose ``pull(table, ids) -> rows`` and ``push(table, ids, rows)``.
Rows not yet trained are served from the initializer so pulls never fail.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import EmbeddingTableConfig


class StagedPS:
    """Host-memory PS. ``shards`` simulates spreading over cluster nodes."""

    def __init__(self, tables: Sequence[EmbeddingTableConfig], *,
                 seed: int = 0, shards: int = 1):
        self.tables = {t.name: t for t in tables}
        self.shards = shards
        self._store: Dict[str, List[Dict[int, np.ndarray]]] = {
            t.name: [dict() for _ in range(shards)] for t in tables}
        self._rng = np.random.default_rng(seed)
        self._init_scale = {t.name: 1.0 / np.sqrt(t.vocab_size)
                            for t in tables}

    def _shard(self, id_: int) -> int:
        return id_ % self.shards

    def _default_row(self, table: str) -> np.ndarray:
        d = self.tables[table].dim
        s = self._init_scale[table]
        return self._rng.uniform(-s, s, d).astype(np.float32)

    def pull(self, table: str, ids: np.ndarray) -> np.ndarray:
        d = self.tables[table].dim
        out = np.empty((len(ids), d), np.float32)
        store = self._store[table]
        for i, id_ in enumerate(ids):
            sh = store[self._shard(int(id_))]
            row = sh.get(int(id_))
            if row is None:
                row = self._default_row(table)
                sh[int(id_)] = row
            out[i] = row
        return out

    def push(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        store = self._store[table]
        for id_, row in zip(ids, rows):
            store[self._shard(int(id_))][int(id_)] = \
                np.asarray(row, np.float32)

    def resident_rows(self, table: str) -> int:
        return sum(len(s) for s in self._store[table])


class CachedPS:
    """Disk-backed PS: one memmap per table (scales to SSD/NFS capacity)."""

    def __init__(self, tables: Sequence[EmbeddingTableConfig], root: str, *,
                 seed: int = 0):
        self.root = root
        self.tables = {t.name: t for t in tables}
        os.makedirs(root, exist_ok=True)
        self._maps: Dict[str, np.memmap] = {}
        rng = np.random.default_rng(seed)
        for t in tables:
            path = os.path.join(root, f"{t.name}.f32")
            fresh = not os.path.exists(path)
            mm = np.memmap(path, np.float32, "r+" if not fresh else "w+",
                           shape=(t.vocab_size, t.dim))
            if fresh:
                s = 1.0 / np.sqrt(t.vocab_size)
                chunk = 1 << 16
                for lo in range(0, t.vocab_size, chunk):
                    hi = min(t.vocab_size, lo + chunk)
                    mm[lo:hi] = rng.uniform(-s, s, (hi - lo, t.dim)) \
                        .astype(np.float32)
                mm.flush()
            self._maps[t.name] = mm
        with open(os.path.join(root, "meta.json"), "w") as f:
            json.dump({t.name: {"vocab": t.vocab_size, "dim": t.dim}
                       for t in tables}, f)

    def pull(self, table: str, ids: np.ndarray) -> np.ndarray:
        return np.asarray(self._maps[table][ids], np.float32)

    def push(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        self._maps[table][ids] = rows

    def flush(self):
        for mm in self._maps.values():
            mm.flush()
