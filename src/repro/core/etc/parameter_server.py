"""Training-side parameter servers for the Embedding Training Cache.

Two tiers, mirroring the paper (§1 "Online training"):
  * ``StagedPS``  — full tables in (distributed) host memory.
  * ``CachedPS``  — full tables on disk / NFS via ``np.memmap``; host memory
    only holds what is being exchanged.

Both expose batched ``pull(table, ids) -> rows`` and
``push(table, ids, rows)`` — one vectorized index operation per call, no
per-id Python loops — plus ``pull_state``/``push_state`` for the row-wise
optimizer accumulator, so an evicted-and-repulled row resumes training
with its momentum intact. Rows not yet trained are served from the
initializer so pulls never fail.

Concurrency: both PS tiers are confined to the training thread (the ETC
staging step is the only caller); the serving stack never touches them —
online updates reach inference by value over the message bus.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence

import numpy as np

from repro.configs.base import EmbeddingTableConfig


class _Shard:
    """Sorted-id row store: every batched op is one ``searchsorted``."""

    __slots__ = ("ids", "rows")

    def __init__(self, dim: int):
        self.ids = np.empty(0, np.int64)
        self.rows = np.empty((0, dim), np.float32)

    def insert(self, new_ids: np.ndarray, new_rows: np.ndarray) -> None:
        """Merge (sorted, unique, disjoint) new ids into the store."""
        pos = np.searchsorted(self.ids, new_ids)
        self.ids = np.insert(self.ids, pos, new_ids)
        self.rows = np.insert(self.rows, pos, new_rows, axis=0)

    def locate(self, ids: np.ndarray) -> np.ndarray:
        """Positions of ``ids`` (must all be present)."""
        return np.searchsorted(self.ids, ids)

    def member_mask(self, ids: np.ndarray) -> np.ndarray:
        if self.ids.size == 0:
            return np.zeros(ids.size, bool)
        pos = np.searchsorted(self.ids, ids)
        inb = pos < self.ids.size
        mask = np.zeros(ids.size, bool)
        mask[inb] = self.ids[pos[inb]] == ids[inb]
        return mask


def _dedupe_keep_last(ids: np.ndarray, rows: np.ndarray):
    """Unique ids keeping the LAST row pushed for a duplicate (matches
    the sequential-overwrite semantics of the old per-id loop)."""
    order = np.argsort(ids, kind="stable")
    sid = ids[order]
    keep = np.r_[sid[1:] != sid[:-1], True] if sid.size else \
        np.empty(0, bool)
    return sid[keep], rows[order][keep]


class StagedPS:
    """Host-memory PS. ``shards`` simulates spreading over cluster nodes."""

    def __init__(self, tables: Sequence[EmbeddingTableConfig], *,
                 seed: int = 0, shards: int = 1):
        self.tables = {t.name: t for t in tables}
        self.shards = shards
        self._shards: Dict[str, List[_Shard]] = {
            t.name: [_Shard(t.dim) for _ in range(shards)]
            for t in tables}
        # optimizer state (one f32 scalar per row), same sharding
        self._state: Dict[str, List[_Shard]] = {
            t.name: [_Shard(1) for _ in range(shards)] for t in tables}
        self._rng = np.random.default_rng(seed)
        self._init_scale = {t.name: 1.0 / np.sqrt(t.vocab_size)
                            for t in tables}

    def _default_rows(self, table: str, n: int) -> np.ndarray:
        d = self.tables[table].dim
        s = self._init_scale[table]
        return self._rng.uniform(-s, s, (n, d)).astype(np.float32)

    def pull(self, table: str, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        d = self.tables[table].dim
        out = np.empty((ids.size, d), np.float32)
        for k, sh in enumerate(self._shards[table]):
            local_idx = np.flatnonzero(ids % self.shards == k)
            if local_idx.size == 0:
                continue
            local = ids[local_idx]
            found = sh.member_mask(local)
            if not found.all():
                new = np.unique(local[~found])
                sh.insert(new, self._default_rows(table, new.size))
            out[local_idx] = sh.rows[sh.locate(local)]
        return out

    def push(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        ids = np.asarray(ids, np.int64)
        rows = np.asarray(rows, np.float32)
        self._scatter(self._shards[table], ids, rows)

    def _scatter(self, shards: List[_Shard], ids: np.ndarray,
                 rows: np.ndarray) -> None:
        for k, sh in enumerate(shards):
            local_idx = np.flatnonzero(ids % self.shards == k)
            if local_idx.size == 0:
                continue
            uid, urows = _dedupe_keep_last(ids[local_idx],
                                           rows[local_idx])
            found = sh.member_mask(uid)
            if found.any():
                sh.rows[sh.locate(uid[found])] = urows[found]
            if not found.all():
                sh.insert(uid[~found], urows[~found])

    # -- optimizer-state round-trip (rowwise accumulator) -------------------

    def pull_state(self, table: str, ids: np.ndarray) -> np.ndarray:
        """Row-wise accumulator for ``ids`` (0 for never-pushed rows)."""
        ids = np.asarray(ids, np.int64)
        out = np.zeros(ids.size, np.float32)
        for k, sh in enumerate(self._state[table]):
            local_idx = np.flatnonzero(ids % self.shards == k)
            if local_idx.size == 0:
                continue
            local = ids[local_idx]
            found = sh.member_mask(local)
            if found.any():
                out[local_idx[found]] = \
                    sh.rows[sh.locate(local[found]), 0]
        return out

    def push_state(self, table: str, ids: np.ndarray,
                   acc: np.ndarray) -> None:
        ids = np.asarray(ids, np.int64)
        acc = np.asarray(acc, np.float32).reshape(-1, 1)
        self._scatter(self._state[table], ids, acc)

    def resident_rows(self, table: str) -> int:
        return sum(s.ids.size for s in self._shards[table])


class CachedPS:
    """Disk-backed PS: one memmap per table (scales to SSD/NFS capacity).

    ``flush()`` is durability-safe: after ``memmap.flush`` (msync) every
    backing file is ``os.fsync``'d, so a crash after flush() cannot lose
    acknowledged pushes to the page cache.
    """

    def __init__(self, tables: Sequence[EmbeddingTableConfig], root: str, *,
                 seed: int = 0):
        self.root = root
        self.tables = {t.name: t for t in tables}
        os.makedirs(root, exist_ok=True)
        self._maps: Dict[str, np.memmap] = {}
        self._state_maps: Dict[str, np.memmap] = {}
        self._paths: Dict[str, str] = {}
        rng = np.random.default_rng(seed)
        for t in tables:
            path = os.path.join(root, f"{t.name}.f32")
            fresh = not os.path.exists(path)
            mm = np.memmap(path, np.float32, "r+" if not fresh else "w+",
                           shape=(t.vocab_size, t.dim))
            if fresh:
                s = 1.0 / np.sqrt(t.vocab_size)
                chunk = 1 << 16
                for lo in range(0, t.vocab_size, chunk):
                    hi = min(t.vocab_size, lo + chunk)
                    mm[lo:hi] = rng.uniform(-s, s, (hi - lo, t.dim)) \
                        .astype(np.float32)
                mm.flush()
            self._maps[t.name] = mm
            self._paths[path] = path
            spath = os.path.join(root, f"{t.name}.acc.f32")
            sfresh = not os.path.exists(spath)
            smm = np.memmap(spath, np.float32,
                            "r+" if not sfresh else "w+",
                            shape=(t.vocab_size,))
            if sfresh:
                smm[:] = 0.0
                smm.flush()
            self._state_maps[t.name] = smm
            self._paths[spath] = spath
        with open(os.path.join(root, "meta.json"), "w") as f:
            json.dump({t.name: {"vocab": t.vocab_size, "dim": t.dim}
                       for t in tables}, f)

    def pull(self, table: str, ids: np.ndarray) -> np.ndarray:
        return np.asarray(self._maps[table][ids], np.float32)

    def push(self, table: str, ids: np.ndarray, rows: np.ndarray) -> None:
        self._maps[table][ids] = rows

    def pull_state(self, table: str, ids: np.ndarray) -> np.ndarray:
        return np.asarray(self._state_maps[table][ids], np.float32)

    def push_state(self, table: str, ids: np.ndarray,
                   acc: np.ndarray) -> None:
        self._state_maps[table][ids] = np.asarray(acc, np.float32)

    def flush(self):
        for mm in (*self._maps.values(), *self._state_maps.values()):
            mm.flush()
        for path in self._paths.values():
            with open(path, "rb+") as f:
                os.fsync(f.fileno())
