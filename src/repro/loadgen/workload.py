"""Seeded open-loop request generators and the JSONL trace format.

The workload layer answers one question reproducibly: *what arrives at
the server, and when?* A :class:`Workload` is a pure function of
``(WorkloadConfig, {model: ModelShape})`` — iterating it twice, or on
another machine, yields bit-identical arrival times, model choices, id
streams and dense features. On top of that determinism:

- **Open-loop arrivals.** ``poisson`` draws exponential inter-arrival
  gaps at the target qps (the memoryless traffic of a large independent
  user population — the "millions of simulated users" regime);
  ``constant`` paces uniformly. Arrival times are *schedule offsets*:
  the driver submits at those offsets regardless of how the server is
  doing, which is what makes tail latency under overload measurable.
- **Zipf-skewed popularity with hot-set drift.** Ids are drawn by
  popularity RANK (Zipf ``zipf_a``), then mapped rank->id through a
  fixed per-table permutation so the hot set is a scattered, realistic
  id subset. ``drift_per_s`` slides the rank->id mapping over time
  (a fraction of the vocab per second), modeling trending items: the
  ids that are hot at t=0 are cold later, which is exactly the churn
  that ages L1 caches and exercises the refresh path.
- **Multi-model mixes.** ``mix`` weights route each request to one
  ensemble member; shapes come from each member's deployed config.
- **Trace record/replay.** ``record_trace`` writes one JSON object per
  request (schedule offset, model, dense, cat); ``replay_trace`` yields
  them back bit-exactly — a replayed trace IS the workload, so a
  production capture and a synthetic run drive the harness identically.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class ModelShape:
    """What one model's requests look like: per-table vocab/hotness and
    the dense feature width. Built from a deployed ``RecsysConfig``."""
    vocab_sizes: Sequence[int]
    hotness: Sequence[int]
    num_dense: int

    @classmethod
    def from_config(cls, cfg) -> "ModelShape":
        return cls(vocab_sizes=tuple(t.vocab_size for t in cfg.tables),
                   hotness=tuple(t.hotness for t in cfg.tables),
                   num_dense=cfg.num_dense_features)

    @property
    def num_tables(self) -> int:
        return len(self.vocab_sizes)

    @property
    def max_hot(self) -> int:
        return max(self.hotness)


@dataclass(frozen=True)
class WorkloadConfig:
    """Everything that determines a workload, hashable and loggable.

    ``qps`` is the *offered* rate — the server sees it whether it keeps
    up or not. ``drift_per_s`` is the fraction of each table's vocab the
    hot set shifts per second (0 = stationary popularity).
    """
    qps: float
    duration_s: float
    rows: int = 8                  # rows per request
    arrival: str = "poisson"       # "poisson" | "constant"
    seed: int = 0
    zipf_a: float = 1.2
    drift_per_s: float = 0.0
    mix: Optional[Dict[str, float]] = None   # model -> weight

    def __post_init__(self):
        if self.arrival not in ("poisson", "constant"):
            raise ValueError(f"arrival must be poisson|constant, "
                             f"got {self.arrival!r}")
        if self.qps <= 0 or self.duration_s <= 0 or self.rows <= 0:
            raise ValueError("qps, duration_s and rows must be positive")
        if self.zipf_a <= 1.0:
            raise ValueError("zipf_a must be > 1")


@dataclass
class Request:
    """One scheduled request: submit ``dense``/``cat`` to ``model`` at
    schedule offset ``t`` seconds after the run starts."""
    t: float
    model: str
    dense: np.ndarray          # [rows, num_dense] float32
    cat: np.ndarray            # [rows, T, maxH] int32, -1 padded


class Workload:
    """Deterministic open-loop request stream over one or more models."""

    def __init__(self, cfg: WorkloadConfig,
                 shapes: Dict[str, ModelShape]):
        if not shapes:
            raise ValueError("need at least one model shape")
        self.cfg = cfg
        self.shapes = dict(shapes)
        names = sorted(self.shapes)
        if cfg.mix is not None:
            unknown = sorted(set(cfg.mix) - set(names))
            if unknown:
                raise ValueError(f"mix names unknown models {unknown}; "
                                 f"shapes declare {names}")
            names = sorted(cfg.mix)
            weights = np.asarray([cfg.mix[n] for n in names], np.float64)
            if (weights <= 0).any():
                raise ValueError("mix weights must be positive")
        else:
            weights = np.ones(len(names), np.float64)
        self._names = names
        self._weights = weights / weights.sum()
        # fixed rank->id permutation per (model, table): the hot ranks
        # land on a scattered id subset, and drift slides along it
        self._perms = {
            name: [np.random.default_rng((cfg.seed, mi, ti, 0xC0FFEE))
                   .permutation(v)
                   for ti, v in enumerate(self.shapes[name].vocab_sizes)]
            for mi, name in enumerate(names)}

    # -- sampling helpers ---------------------------------------------------

    def _zipf_ranks(self, rng, vocab: int, size) -> np.ndarray:
        """Popularity ranks (0 = hottest), Zipf-drawn, folded into
        [0, vocab) like the repo's other Zipf streams."""
        return ((rng.zipf(self.cfg.zipf_a, size) - 1) % vocab) \
            .astype(np.int64)

    def _ids(self, name: str, ti: int, rng, t: float,
             size) -> np.ndarray:
        """rank -> drifted slot -> permuted id for one table."""
        vocab = self.shapes[name].vocab_sizes[ti]
        ranks = self._zipf_ranks(rng, vocab, size)
        shift = int(self.cfg.drift_per_s * t * vocab)
        return self._perms[name][ti][(ranks + shift) % vocab]

    def _request(self, t: float, name: str, rng) -> Request:
        shape = self.shapes[name]
        b = self.cfg.rows
        cat = np.full((b, shape.num_tables, shape.max_hot), -1, np.int32)
        for ti, h in enumerate(shape.hotness):
            cat[:, ti, :h] = self._ids(name, ti, rng, t, (b, h))
        dense = np.log1p(rng.lognormal(size=(b, shape.num_dense))) \
            .astype(np.float32)
        return Request(t=t, model=name, dense=dense, cat=cat)

    # -- the stream ---------------------------------------------------------

    def requests(self) -> Iterator[Request]:
        """Yield the full scheduled stream, in arrival order. One RNG
        drives arrivals, routing and payloads sequentially, so the
        stream is a pure function of (cfg, shapes)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, 0xA221))
        t = 0.0
        while True:
            if cfg.arrival == "poisson":
                t += rng.exponential(1.0 / cfg.qps)
            else:
                t += 1.0 / cfg.qps
            if t > cfg.duration_s:
                return
            name = self._names[rng.choice(len(self._names),
                                          p=self._weights)]
            yield self._request(t, name, rng)

    def __iter__(self) -> Iterator[Request]:
        return self.requests()


# ---------------------------------------------------------------------------
# trace record / replay (JSONL)
# ---------------------------------------------------------------------------
#
# One JSON object per line. Floats survive the round trip bit-exactly:
# json emits shortest-round-trip reprs, and every float32 is exactly
# representable as (and recoverable from) a python float.

TRACE_FORMAT = "repro-loadtrace-v1"


def record_trace(path: str, requests: Iterable[Request]) -> int:
    """Write the request stream as JSONL; returns the request count."""
    n = 0
    with open(path, "w") as f:
        f.write(json.dumps({"format": TRACE_FORMAT}) + "\n")
        for r in requests:
            f.write(json.dumps({
                "t": r.t, "model": r.model,
                "dense": [[float(x) for x in row] for row in r.dense],
                "cat": r.cat.tolist(),
            }) + "\n")
            n += 1
    return n


def replay_trace(path: str) -> Iterator[Request]:
    """Yield the recorded stream back, bit-exact with what was written."""
    with open(path) as f:
        header = json.loads(f.readline())
        if header.get("format") != TRACE_FORMAT:
            raise ValueError(f"{path}: not a {TRACE_FORMAT} trace "
                             f"(header {header})")
        for line in f:
            if not line.strip():
                continue
            d = json.loads(line)
            yield Request(t=d["t"], model=d["model"],
                          dense=np.asarray(d["dense"], np.float32),
                          cat=np.asarray(d["cat"], np.int32))
