"""Production traffic harness: open-loop load generation against the
serving stack.

Three layers (see the module docstrings for the contracts):

- :mod:`repro.loadgen.workload` — seeded open-loop request generators
  (Poisson / constant-rate arrivals, Zipf-skewed id popularity with
  hot-set drift, multi-model traffic mixes) and a JSONL trace
  record/replay format so any run is exactly reproducible.
- :mod:`repro.loadgen.metrics` — bounded-memory mergeable latency
  histogram (log-bucketed p50/p99/p999) and windowed delivered-qps
  counters.
- :mod:`repro.loadgen.driver` — the open-loop driver: submits on
  schedule WITHOUT waiting for completions, so late responses count
  against latency (coordinated-omission-free), and collects per-model
  delivered/shed/violation statistics.

The CLI front door is ``python -m repro.launch.loadtest``.
"""
from repro.loadgen.metrics import LatencyHistogram, WindowedRate
from repro.loadgen.workload import (ModelShape, Request, WorkloadConfig,
                                    Workload, record_trace, replay_trace)
from repro.loadgen.driver import OpenLoopDriver

__all__ = [
    "LatencyHistogram", "WindowedRate", "ModelShape", "Request",
    "WorkloadConfig", "Workload", "record_trace", "replay_trace",
    "OpenLoopDriver",
]
