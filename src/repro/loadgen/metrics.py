"""Bounded-memory serving metrics: log-bucketed latency histogram and
windowed delivered-rate counters.

``LatencyHistogram`` is the latency store for everything that measures
the serving path — the load-test driver's client-observed latencies AND
``InferenceServer``'s per-group samples (it replaced the append-only
``latencies_ms`` list). Properties that matter here:

- **Bounded memory.** A fixed array of geometrically-spaced buckets
  (default ~2% relative width over 1µs..10min) — a week-long soak test
  costs the same few KiB as a smoke run.
- **Mergeable.** Bucket counts from workers / phases / shards add
  elementwise, so per-model and fleet-wide percentiles come from the
  same structure (``merge``), and a JSON round-trip (``to_dict`` /
  ``from_dict``) is exact.
- **Quantile error is bounded by the bucket width** (~2% relative), the
  standard HDR-histogram tradeoff; the mean is exact (sum tracked
  separately).

Neither class locks internally: callers own the synchronization
(``InferenceServer`` keeps its histogram behind ``_stats_lock``; the
driver's poller is single-threaded).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np


class LatencyHistogram:
    """Log-bucketed latency histogram in milliseconds.

    Bucket 0 holds everything at or below ``lo_ms``; the last bucket is
    the overflow above ``hi_ms``; in between, bucket edges grow by
    ``growth`` per bucket, so a recorded value's bucket midpoint is
    within ~``growth - 1`` relative error of the true value.
    """

    def __init__(self, lo_ms: float = 1e-3, hi_ms: float = 6e5,
                 growth: float = 1.02):
        if not (lo_ms > 0 and hi_ms > lo_ms and growth > 1):
            raise ValueError("need lo_ms > 0, hi_ms > lo_ms, growth > 1")
        self.lo_ms = lo_ms
        self.hi_ms = hi_ms
        self.growth = growth
        self._log_g = math.log(growth)
        # bucket 0: (-inf, lo]; 1..n: log-spaced; n+1: overflow
        self._n = int(math.ceil(math.log(hi_ms / lo_ms) / self._log_g))
        self.counts = np.zeros(self._n + 2, np.int64)
        self.sum_ms = 0.0

    # -- recording ----------------------------------------------------------

    def _bucket(self, ms: float) -> int:
        if ms <= self.lo_ms:
            return 0
        idx = 1 + int(math.log(ms / self.lo_ms) / self._log_g)
        return min(idx, self._n + 1)

    def record(self, ms: float) -> None:
        self.counts[self._bucket(ms)] += 1
        self.sum_ms += ms

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    @property
    def mean(self) -> float:
        n = self.count
        return self.sum_ms / n if n else 0.0

    def reset(self) -> None:
        self.counts[:] = 0
        self.sum_ms = 0.0

    # -- quantiles ----------------------------------------------------------

    def _edge(self, idx: int) -> float:
        """Representative latency for bucket ``idx`` (geometric mid)."""
        if idx <= 0:
            return self.lo_ms
        if idx > self._n:
            return self.hi_ms
        return self.lo_ms * self.growth ** (idx - 0.5)

    def percentile(self, q: float) -> float:
        """Latency (ms) at percentile ``q`` in [0, 100]; 0.0 if empty."""
        n = self.count
        if n == 0:
            return 0.0
        rank = max(1, int(math.ceil(q / 100.0 * n)))
        cum = np.cumsum(self.counts)
        idx = int(np.searchsorted(cum, rank))
        return float(self._edge(idx))

    def summary(self) -> Dict[str, float]:
        """The standard serving picture: p50/p95/p99/p999 + exact mean."""
        return {"p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "p999": self.percentile(99.9),
                "mean": self.mean, "count": float(self.count)}

    # -- merge / persistence ------------------------------------------------

    def _compatible(self, other: "LatencyHistogram") -> bool:
        return (self.lo_ms == other.lo_ms and self.hi_ms == other.hi_ms
                and self.growth == other.growth)

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Add ``other``'s counts into this histogram (same bucketing)."""
        if not self._compatible(other):
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        self.counts += other.counts
        self.sum_ms += other.sum_ms
        return self

    def snapshot(self) -> "LatencyHistogram":
        """Independent copy (take under the owner's lock, read outside)."""
        h = LatencyHistogram(self.lo_ms, self.hi_ms, self.growth)
        h.counts = self.counts.copy()
        h.sum_ms = self.sum_ms
        return h

    def to_dict(self) -> Dict:
        nz = np.nonzero(self.counts)[0]
        return {"lo_ms": self.lo_ms, "hi_ms": self.hi_ms,
                "growth": self.growth, "sum_ms": self.sum_ms,
                "buckets": {int(i): int(self.counts[i]) for i in nz}}

    @classmethod
    def from_dict(cls, d: Dict) -> "LatencyHistogram":
        h = cls(d["lo_ms"], d["hi_ms"], d["growth"])
        for i, c in d["buckets"].items():
            h.counts[int(i)] = c
        h.sum_ms = d["sum_ms"]
        return h


class WindowedRate:
    """Delivered-throughput series over fixed time windows.

    ``record(t)`` takes seconds relative to the run start; the series
    reports one ``(window_start_s, per_second_rate)`` pair per non-empty
    window — memory is bounded by the run duration / window size, never
    by the request count.
    """

    def __init__(self, window_s: float = 1.0):
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        self.window_s = window_s
        self._counts: Dict[int, int] = {}

    def record(self, t_s: float, n: int = 1) -> None:
        self._counts[int(t_s // self.window_s)] = \
            self._counts.get(int(t_s // self.window_s), 0) + n

    def merge(self, other: "WindowedRate") -> "WindowedRate":
        if self.window_s != other.window_s:
            raise ValueError("window size mismatch")
        for w, n in other._counts.items():
            self._counts[w] = self._counts.get(w, 0) + n
        return self

    @property
    def total(self) -> int:
        return sum(self._counts.values())

    def series(self) -> List[Tuple[float, float]]:
        return [(w * self.window_s, n / self.window_s)
                for w, n in sorted(self._counts.items())]

    def peak(self) -> float:
        return max((n / self.window_s for n in self._counts.values()),
                   default=0.0)
