"""The open-loop load driver: submit on schedule, never wait.

A closed-loop benchmark (submit, block, repeat) can only ever measure a
server that is keeping up — when the server slows down, the benchmark
slows its own offered load and the tail disappears (coordinated
omission). This driver is OPEN-LOOP:

- Requests are submitted at their *scheduled* offsets regardless of
  completions; the schedule never waits for the server.
- Latency is measured from the SCHEDULED arrival time to response
  pickup, so a late submit (driver fell behind) and a late response
  both count against latency.
- Completions are collected by a single poller thread that sweeps all
  outstanding handles with non-blocking reads — no per-handle blocking
  ``get``, so one slow response never delays the measurement of the
  responses behind it (head-of-line-free collection, accurate to the
  poll period).

Per model it records delivered latency (mergeable log-bucketed
histogram: p50/p99/p999), a windowed delivered-qps series, observed
shed/rejection counts (typed :class:`~repro.serve.server.
ServerOverloaded` responses), errors, and requests lost to the drain
timeout. ``run`` returns a JSON-ready report; combine it with the
server-side counters (sheds, SLO violations, expiry drops) for the full
picture — ``launch/loadtest.py`` does exactly that and persists
``artifacts/loadtest.json``.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.loadgen.metrics import LatencyHistogram, WindowedRate
from repro.loadgen.workload import Request

# NOTE: repro.serve.server imports this package's metrics module, so the
# ServerOverloaded import lives inside _sweep (lazy) to break the cycle.

#: submit_fn(model, dense, cat) -> handle with queue.Queue semantics
SubmitFn = Callable[[str, np.ndarray, np.ndarray], "object"]


class _ModelStats:
    """Per-model accumulation, owned by the poller thread during a run."""

    def __init__(self):
        self.hist = LatencyHistogram()
        self.rate = WindowedRate()
        self.delivered = 0
        self.shed = 0
        self.errors = 0
        self.slo_violations = 0


class OpenLoopDriver:

    # Checked by `python -m repro.analysis`: the submit thread appends
    # outstanding handles while the poller sweeps and removes them.
    _GUARDED_BY = {
        "_pending": "_pend_lock",
        "_seq": "_pend_lock",
    }

    def __init__(self, submit: SubmitFn, *,
                 slo_ms: Optional[float] = None,
                 poll_s: float = 1e-3,
                 drain_timeout_s: float = 120.0):
        self.submit = submit
        #: client-side SLO: delivered responses slower than this count
        #: as violations in the report (server-side counters are kept
        #: separately by the admission controller)
        self.slo_ms = slo_ms
        self.poll_s = poll_s
        self.drain_timeout_s = drain_timeout_s
        self._pend_lock = threading.Lock()
        # keyed by submission sequence so a sweep removes completions in
        # O(done), not O(pending * done) — at overload tens of thousands
        # of handles can be outstanding, and collection delay would
        # otherwise pollute every measured latency
        self._pending: Dict[int, Tuple[str, float, object]] = {}
        self._seq = 0

    # -- collection ---------------------------------------------------------

    def _sweep(self, t0: float, stats: Dict[str, _ModelStats]) -> int:
        """One non-blocking pass over the outstanding handles; returns
        how many are still pending."""
        from repro.serve.server import ServerOverloaded
        with self._pend_lock:
            snapshot = list(self._pending.items())
        done: List[int] = []
        for key, (model, t_sched, handle) in snapshot:
            try:
                out = handle.get_nowait()
            except queue.Empty:          # still in flight
                continue
            done.append(key)
            st = stats.setdefault(model, _ModelStats())
            if isinstance(out, ServerOverloaded):
                st.shed += 1
            elif isinstance(out, BaseException):
                st.errors += 1
            else:
                now = time.perf_counter() - t0
                ms = (now - t_sched) * 1e3
                st.hist.record(ms)
                st.rate.record(now)
                st.delivered += 1
                if self.slo_ms is not None and ms > self.slo_ms:
                    st.slo_violations += 1
        with self._pend_lock:
            for key in done:
                self._pending.pop(key, None)
            return len(self._pending)

    # -- the run ------------------------------------------------------------

    def run(self, requests: Iterable[Request]) -> Dict:
        """Drive the scheduled stream open-loop; returns the report."""
        stats: Dict[str, _ModelStats] = {}
        scheduled: Dict[str, int] = {}
        stop = threading.Event()
        t0 = time.perf_counter()

        def poll_loop():
            while not stop.is_set():
                self._sweep(t0, stats)
                time.sleep(self.poll_s)
            self._sweep(t0, stats)       # final pass after stop

        poller = threading.Thread(target=poll_loop, daemon=True)
        poller.start()
        late_submit_ms = 0.0
        n_sched = 0
        try:
            for r in requests:
                now = time.perf_counter() - t0
                if r.t > now:
                    time.sleep(r.t - now)
                else:
                    late_submit_ms = max(late_submit_ms,
                                         (now - r.t) * 1e3)
                handle = self.submit(r.model, r.dense, r.cat)
                scheduled[r.model] = scheduled.get(r.model, 0) + 1
                n_sched += 1
                with self._pend_lock:
                    self._pending[self._seq] = (r.model, r.t, handle)
                    self._seq += 1
            # drain: late responses still count against latency
            deadline = time.perf_counter() + self.drain_timeout_s
            while time.perf_counter() < deadline:
                with self._pend_lock:
                    if not self._pending:
                        break
                time.sleep(self.poll_s)
        finally:
            stop.set()
            poller.join()
        with self._pend_lock:
            lost = list(self._pending.values())
            self._pending = {}
        elapsed = time.perf_counter() - t0

        report: Dict = {"elapsed_s": elapsed, "scheduled": n_sched,
                        "max_submit_lag_ms": late_submit_ms,
                        "models": {}}
        for model in sorted(set(scheduled) | set(stats)):
            st = stats.get(model, _ModelStats())
            report["models"][model] = {
                "scheduled": scheduled.get(model, 0),
                "delivered": st.delivered,
                "shed_observed": st.shed,
                "errors": st.errors,
                "lost": sum(1 for m, _, _ in lost if m == model),
                "slo_violations_observed": st.slo_violations,
                "latency_ms": st.hist.summary(),
                "delivered_qps": st.rate.series(),
                "histogram": st.hist.to_dict(),
            }
        return report
